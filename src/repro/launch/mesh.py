"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16) — the "pod"
axis carries pure data parallelism (and FSDP for the largest models) over
the inter-pod DCN/optical links; "model" stays within a pod's ICI.

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import.
"""

from __future__ import annotations

import jax

# TPU v5e-class hardware constants used by the roofline analysis.
# Single source of truth: repro.core.hw (shared with the tile autotuner).
from repro.core.hw import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

__all__ = ["HBM_BW", "ICI_BW", "PEAK_FLOPS_BF16",
           "make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Smoke-test mesh over whatever devices exist (CPU: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
