"""Collective/memory attribution: which model ops generate the traffic.

Groups collective bytes (x loop trip counts) by the jax op_name metadata so
the hillclimb can target the dominant source.

  PYTHONPATH=src python -m repro.launch.diagnose --arch qwen3-14b --shape train_4k
"""

import argparse
import os
import re
from collections import defaultdict

from repro.launch import hlo_cost


def _force_host_devices(n: int = 512) -> None:
    """Expose `n` fake host devices so dryrun can build many-device meshes
    on CPU.  Must run before jax initializes its backend — main() calls
    this ahead of the dryrun import.  Kept out of module scope on purpose:
    importing this module (e.g. from tests or other launchers) must not
    mutate the process environment."""
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n} "
        + os.environ.get("XLA_FLAGS", "")
    )


def attribute(text: str, top: int = 15):
    comps = hlo_cost.parse_module(text)
    entry = next(c for c in comps.values() if c.is_entry)

    # multipliers (same walk as hlo_cost.analyze)
    refs = {}
    for comp in comps.values():
        out = []
        for op in comp.ops:
            if op.opcode == "while":
                mw = re.search(r"body=%?([\w.\-]+)", op.rest)
                mt = re.search(r'known_trip_count":\{"n":"(\d+)"', op.rest)
                t = int(mt.group(1)) if mt else 1
                if mw:
                    out.append((mw.group(1), t))
            for attr in ("calls", "to_apply", "true_computation", "false_computation"):
                ma = re.search(attr + r"=%?([\w.\-]+)", op.rest)
                if ma:
                    out.append((ma.group(1), 1))
        refs[comp.name] = out
    mult = defaultdict(float)
    stack = [(entry.name, 1.0)]
    while stack:
        name, m = stack.pop()
        mult[name] += m
        for callee, k in refs.get(name, []):
            stack.append((callee, m * k))

    by_name = defaultdict(float)
    count = defaultdict(int)
    for comp in comps.values():
        m = mult.get(comp.name, 0)
        if not m:
            continue
        for op in comp.ops:
            base = None
            for c in hlo_cost.COLLECTIVES:
                if op.opcode == c or op.opcode.startswith(c + "-start"):
                    base = c
                    break
            if not base:
                continue
            _, nbytes = hlo_cost._type_elems_bytes(op.type_str)
            mo = re.search(r'op_name="([^"]*)"', op.rest)
            tag = mo.group(1) if mo else "?"
            # strip indices for grouping
            tag = re.sub(r"\[\d+\]", "", tag)
            by_name[f"{base} :: {tag}"] += nbytes * m
            count[f"{base} :: {tag}"] += int(m)
    rows = sorted(by_name.items(), key=lambda kv: -kv[1])
    return rows[:top], count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--hlo", default=None, help="reuse a dumped HLO file")
    args = ap.parse_args()

    if args.hlo and os.path.exists(args.hlo):
        text = open(args.hlo).read()
    else:
        _force_host_devices()
        import repro.launch.dryrun as dr

        dump = args.hlo or f"/tmp/hlo_{args.arch}_{args.shape}_{args.mesh}.txt"
        dr.run_cell(args.arch, args.shape, args.mesh, verbose=True,
                    dump_hlo=dump)
        text = open(dump).read()
    rows, count = attribute(text, args.top)
    print("\ntop collective sources (bytes/device x trips):")
    for k, v in rows:
        print(f"  {v/1e9:9.2f} GB  x{count[k]:<6d} {k[:120]}")


if __name__ == "__main__":
    main()
