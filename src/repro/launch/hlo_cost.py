"""Loop-aware cost analysis of post-optimization HLO text.

`compiled.cost_analysis()` counts each while-loop body ONCE, but our models
scan over layer groups / KV blocks / loss chunks, so flops, bytes and
collective traffic inside loops must be multiplied by the trip count (XLA
annotates `backend_config={"known_trip_count":{"n":...}}` on CPU/TPU).

This module parses the HLO module into computations, attributes costs:

  flops       — dot ops: 2 * |result| * contracted extent (per computation)
  bytes       — per *executed* op: operand + result bytes (fusion internals
                excluded — fused ops don't touch HBM; DUS/DS counted at
                slice granularity, matching TPU in-place semantics)
  collectives — result bytes of all-gather/all-reduce/reduce-scatter/
                all-to-all/collective-permute

then propagates multipliers over the call graph: while bodies x trip count,
fusion/call/conditional x caller's multiplier.

Validated against cost_analysis() on loop-free modules (tests).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f8e4m3fn|f8e5m2|[suf]\d+|c\d+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$"
)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_NO_TRAFFIC = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "iota", "partition-id", "replica-id",
    # control-flow ops: their carried tuples alias in place (donated
    # buffers); the real traffic is the ops *inside* their bodies, which are
    # counted with the body's multiplier.
    "while", "conditional", "call",
}


def _type_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES.get(dt, 4)
    if elems == 0 and "[]" in type_str:
        elems, nbytes = 1, 4
    return elems, nbytes


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str   # args + attrs


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_entry: bool = False


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = Computation(name=mc.group(2), ops=[], is_entry=bool(mc.group(1)))
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            cur.ops.append(Op(mo.group(1), mo.group(2), mo.group(3), mo.group(4)))
    return comps


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    res_elems, _ = _type_elems_bytes(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if not m:
        return 2.0 * res_elems  # degenerate dot
    # XLA emits operands either typed — dot(f32[64,128]{1,0} %a, ...) — or
    # bare — dot(%a, %b).  In the typed form the lhs shape is inline (the
    # first shape in rest); in the bare form resolve %a through the symbol
    # table.
    dims = _shape_dims(op.rest)
    if not dims:
        margs = re.match(r"%([\w.\-]+)", op.rest.strip())
        lhs_type = symbols.get(margs.group(1), "") if margs else ""
        dims = _shape_dims(lhs_type)
    contracted = 1
    if m.group(1):
        for d in m.group(1).split(","):
            i = int(d)
            if i < len(dims):
                contracted *= dims[i]
    return 2.0 * res_elems * contracted


@dataclasses.dataclass
class LoopAwareCost:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collective_bytes_by_op: Dict[str, float]
    collective_count: Dict[str, int]
    trip_counts: Dict[str, int]


def analyze(text: str) -> LoopAwareCost:
    comps = parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # fall back: treat the largest computation as entry
        entry = max(comps.values(), key=lambda c: len(c.ops))

    # Fusions that wrap a dynamic-update-slice alias their big operand in
    # place on TPU: charge them at update-slice granularity, not the full
    # buffer (KV-cache writes would otherwise count the whole cache/step).
    dus_fusions = {
        c.name for c in comps.values()
        if any(op.opcode == "dynamic-update-slice" for op in c.ops)
    }

    # per-computation raw costs + outgoing references
    flops_c: Dict[str, float] = {}
    bytes_c: Dict[str, float] = {}
    coll_c: Dict[str, Dict[str, float]] = {}
    coll_n: Dict[str, Dict[str, int]] = {}
    refs: Dict[str, List[Tuple[str, int, str]]] = {}  # comp -> [(callee, mult, kind)]
    trip_counts: Dict[str, int] = {}

    for comp in comps.values():
        symbols = {op.name: op.type_str for op in comp.ops}
        f = 0.0
        b = 0.0
        cb: Dict[str, float] = {}
        cn: Dict[str, int] = {}
        out: List[Tuple[str, int, str]] = []
        for op in comp.ops:
            if op.opcode == "dot":
                f += _dot_flops(op, symbols)
            base = None
            for c in COLLECTIVES:
                if op.opcode == c or op.opcode.startswith(c + "-start"):
                    base = c
                    break
            _, res_bytes = _type_elems_bytes(op.type_str)
            if base:
                cb[base] = cb.get(base, 0.0) + res_bytes
                cn[base] = cn.get(base, 0) + 1
            # traffic
            if op.opcode not in _NO_TRAFFIC and not op.opcode.endswith("-done"):
                fused_dus = False
                if op.opcode == "fusion":
                    mc = re.search(r"calls=%?([\w.\-]+)", op.rest)
                    fused_dus = bool(mc) and mc.group(1) in dus_fusions
                if op.opcode == "dynamic-update-slice" or fused_dus:
                    # in-place update: charge the (small) update operand x2
                    ops_bytes = []
                    for a in re.findall(r"%([\w.\-]+)", op.rest.split(" metadata=")[0]):
                        if a in symbols:
                            _, ab = _type_elems_bytes(symbols[a])
                            if ab > 4:
                                ops_bytes.append(ab)
                    b += 2 * (min(ops_bytes) if ops_bytes else res_bytes)
                elif op.opcode == "dynamic-slice":
                    b += 2 * res_bytes
                else:
                    b += res_bytes
                    for a in re.findall(r"%([\w.\-]+)", op.rest.split(" metadata=")[0]):
                        if a in symbols:
                            _, ab = _type_elems_bytes(symbols[a])
                            b += ab
            # call graph
            mw = re.search(r"body=%?([\w.\-]+), ", op.rest) or re.search(
                r"body=%?([\w.\-]+)", op.rest)
            if op.opcode == "while" and mw:
                trip = 1
                mt = re.search(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)', op.rest)
                if not mt:
                    mt = re.search(r'known_trip_count":\{"n":"(\d+)"', op.rest)
                if mt:
                    trip = int(mt.group(1))
                trip_counts[mw.group(1)] = trip
                out.append((mw.group(1), trip, "body"))
                mcnd = re.search(r"condition=%?([\w.\-]+)", op.rest)
                if mcnd:
                    out.append((mcnd.group(1), trip, "body"))
            for attr, kind in (("calls", "fusion"), ("to_apply", "apply"),
                               ("true_computation", "body"),
                               ("false_computation", "body")):
                ma = re.search(attr + r"=%?([\w.\-]+)", op.rest)
                if ma:
                    k = kind
                    if attr == "calls" and op.opcode == "call":
                        k = "body"
                    out.append((ma.group(1), 1, k))
            mb = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
            if mb:
                for nm in re.findall(r"%?([\w.\-]+)", mb.group(1)):
                    out.append((nm, 1, "body"))
        flops_c[comp.name] = f
        bytes_c[comp.name] = b
        coll_c[comp.name] = cb
        coll_n[comp.name] = cn
        refs[comp.name] = out

    # propagate multipliers from entry
    mult: Dict[str, float] = {}
    kind_of: Dict[str, str] = {entry.name: "body"}
    stack = [(entry.name, 1.0)]
    while stack:
        name, m = stack.pop()
        if name not in comps:
            continue
        mult[name] = mult.get(name, 0.0) + m
        for callee, k, kind in refs.get(name, []):
            kind_of[callee] = kind
            stack.append((callee, m * k))

    total_f = 0.0
    total_b = 0.0
    total_cb: Dict[str, float] = {}
    total_cn: Dict[str, int] = {}
    for name, m in mult.items():
        kind = kind_of.get(name, "body")
        if kind == "apply":
            continue
        total_f += flops_c[name] * m
        if kind != "fusion":
            total_b += bytes_c[name] * m
        for k, v in coll_c[name].items():
            total_cb[k] = total_cb.get(k, 0.0) + v * m
            total_cn[k] = total_cn.get(k, 0) + int(coll_n[name][k] * m)

    return LoopAwareCost(
        flops=total_f,
        bytes_accessed=total_b,
        collective_bytes=sum(total_cb.values()),
        collective_bytes_by_op=total_cb,
        collective_count=total_cn,
        trip_counts=trip_counts,
    )
