import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective analysis.

This is the proof that the distribution config is coherent: a sharding
mismatch, compile-time OOM, or unsupported collective fails here.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/results
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as rl
from repro.launch import steps as steps_lib
from repro.parallel import sharding as shard_lib
from repro.parallel.logical import use_rules
from jax.sharding import NamedSharding, PartitionSpec as P


def run_cell(arch: str, shape_name: str, mesh_kind: str, verbose: bool = True,
             dump_hlo: str | None = None):
    cfg = configs.get(arch)
    shape = configs.SHAPES[shape_name]
    multi = mesh_kind == "multi"
    mesh = mesh_lib.make_production_mesh(multi_pod=multi)
    chips = mesh.size
    plan = shard_lib.make_plan(
        mesh, cfg.param_count(), n_kv_heads=cfg.n_kv_heads,
        serving=(shape["kind"] != "train"),
        force_attn_seq=False if shape["kind"] == "decode" else None,
    )
    rules = plan.activation_rules()

    p_struct = steps_lib.params_struct(cfg)
    p_shard = shard_lib.param_sharding(p_struct, mesh, plan)
    specs = steps_lib.input_specs(cfg, shape)

    t0 = time.time()
    with use_rules(mesh, rules):
        if shape["kind"] == "train":
            opt_cfg = steps_lib.optimizer_config(cfg)
            o_struct = steps_lib.opt_state_struct(cfg, p_struct, opt_cfg)
            o_shard = {
                "m": shard_lib.param_sharding(o_struct["m"], mesh, plan),
                "v": shard_lib.param_sharding(o_struct["v"], mesh, plan),
                "count": NamedSharding(mesh, P()),
            }
            b_shard = shard_lib.batch_sharding(specs["batch"], mesh, plan)
            step = steps_lib.make_train_step(cfg, opt_cfg)
            with mesh:
                lowered = jax.jit(
                    step, in_shardings=(p_shard, o_shard, b_shard),
                    donate_argnums=(0, 1),
                ).lower(p_struct, o_struct, specs["batch"])
        elif shape["kind"] == "prefill":
            b_shard = shard_lib.batch_sharding(specs["batch"], mesh, plan)
            step = steps_lib.make_prefill_step(cfg)
            with mesh:
                lowered = jax.jit(step, in_shardings=(p_shard, b_shard)).lower(
                    p_struct, specs["batch"]
                )
        else:  # decode
            d_struct = steps_lib.decode_state_struct(
                cfg, p_struct, shape["global_batch"], specs["max_seq"]
            )
            d_shard = shard_lib.cache_sharding(d_struct, mesh, plan)
            tok = specs["tokens"]
            t_shard = shard_lib.batch_sharding({"t": tok}, mesh, plan)["t"]
            step = steps_lib.make_serve_step(cfg)
            with mesh:
                lowered = jax.jit(
                    step, in_shardings=(p_shard, d_shard, t_shard),
                    donate_argnums=(1,),
                ).lower(p_struct, d_struct, tok)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    from repro.launch import hlo_cost
    hlo_text = compiled.as_text()
    if dump_hlo:
        with open(dump_hlo, "w") as f:
            f.write(hlo_text)
    lac = hlo_cost.analyze(hlo_text)
    roof = rl.analyze(
        arch=arch, shape_name=shape_name, shape=shape,
        mesh_name=mesh_kind, chips=chips, cfg=cfg, compiled=compiled, lac=lac,
    )
    mem = compiled.memory_analysis()
    result = roof.row()
    result.update(
        lower_s=t_lower, compile_s=t_compile,
        memory_analysis=str(mem),
        collectives={k: int(v) for k, v in lac.collective_bytes_by_op.items()},
        collective_counts=lac.collective_count,
    )
    if verbose:
        print(f"== {arch} x {shape_name} x {mesh_kind} ({chips} chips) ==")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  collectives: {result['collectives']}")
        print(f"  roofline: compute {roof.compute_s*1e3:.2f}ms "
              f"memory {roof.memory_s*1e3:.2f}ms "
              f"collective {roof.collective_s*1e3:.2f}ms -> {roof.bound}-bound, "
              f"MFU {roof.mfu*100:.1f}%, useful/HLO {roof.useful_flops_ratio:.2f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [
            (a, s) for a in configs.list_archs()
            if a not in ("bert-base", "vit-b-16")
            for s in configs.shapes_for(a)
        ]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        for mk in meshes:
            if args.out:
                fn = os.path.join(args.out, f"{arch}__{shape}__{mk}.json")
                if os.path.exists(fn):
                    print(f"skip (done): {arch} x {shape} x {mk}")
                    continue
            try:
                res = run_cell(arch, shape, mk)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fn = os.path.join(args.out, f"{arch}__{shape}__{mk}.json")
                    with open(fn, "w") as f:
                        json.dump(res, f, indent=1, default=str)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, mk, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"dry-run OK: {len(cells)} cells x {meshes}")


if __name__ == "__main__":
    main()
