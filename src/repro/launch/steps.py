"""Step builders: train_step / prefill_step / serve_step, plus the
ShapeDtypeStruct input_specs for every (arch x shape) dry-run cell.

These are the functions the dry-run lowers and the real launchers execute —
one definition, both uses.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine

S32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
F32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float32)


def optimizer_config(cfg: ArchConfig) -> AdamWConfig:
    # Sub-fp32 moments for models whose fp32 state would not fit HBM.
    big = cfg.param_count() > 8e9
    return AdamWConfig(state_dtype="bfloat16" if big else "float32")


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig | None = None,
                    base_lr: float = 3e-4, total_steps: int = 100_000):
    opt_cfg = opt_cfg or optimizer_config(cfg)
    sched = warmup_cosine(base_lr, warmup=min(2000, total_steps // 10), total=total_steps)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(M.loss_fn)(params, cfg, batch)
        lr = sched(opt_state["count"])
        new_params, new_state = adamw_update(grads, opt_state, params, lr, opt_cfg)
        metrics = {"loss": loss, "lr": lr}
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        # Serving prefill: only the next-token logits leave the step.
        return M.forward(params, cfg, batch, last_only=True)

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, state: M.DecodeState, tokens):
        return M.decode_step(params, cfg, state, tokens)

    return serve_step


def make_paged_serve_step(cfg: ArchConfig):
    """Decode step over the paged KV cache: every slot at its own length;
    `active` masks slots that are idle or mid-prefill this step."""

    def paged_serve_step(params, state: M.PagedDecodeState, tokens, active):
        return M.paged_decode_step(params, cfg, state, tokens, active)

    return paged_serve_step


def make_paged_verify_step(cfg: ArchConfig):
    """Speculative-decoding verification: score (B, S) drafted tokens — the
    last committed token plus S-1 draft guesses per slot — in one paged
    forward pass and greedily accept the longest matching prefix.

    The same python callable serves every draft bucket S — jit (or the
    engine's warmup) specializes per shape, exactly like the prefill-chunk
    buckets."""

    def paged_verify_step(params, state: M.PagedDecodeState, tokens, active,
                          limits, eos):
        return M.paged_verify_step(params, cfg, state, tokens, active,
                                   limits, eos)

    return paged_verify_step


def make_paged_sample_step(cfg: ArchConfig):
    """Decode step + on-device temperature/top-k/top-p sampling: same trunk
    as the paged serve step, but the head draws from the per-(seed, index)
    PRNG stream instead of handing logits back for a host argmax.  Engaged
    only when a batch contains a non-greedy request — all-greedy batches
    keep dispatching the plain serve step (bitwise-identical paths)."""

    def paged_sample_step(params, state: M.PagedDecodeState, tokens, active,
                          temperature, top_k, top_p, seeds, gen_idx):
        return M.paged_decode_sample_step(params, cfg, state, tokens, active,
                                          temperature, top_k, top_p, seeds,
                                          gen_idx)

    return paged_sample_step


def make_paged_verify_sample_step(cfg: ArchConfig):
    """Speculative verification under stochastic sampling (rejection
    sampling against the drafted point mass); bucketed per draft width S
    exactly like the greedy verify step."""

    def paged_verify_sample_step(params, state: M.PagedDecodeState, tokens,
                                 active, limits, eos, temperature, top_k,
                                 top_p, seeds, gen_idx):
        return M.paged_verify_sample_step(params, cfg, state, tokens, active,
                                          limits, eos, temperature, top_k,
                                          top_p, seeds, gen_idx)

    return paged_verify_sample_step


def make_prefill_chunk_step(cfg: ArchConfig):
    """Multi-token prefill: advance one slot by a (1, C) chunk of prompt.

    The same python callable serves every chunk size C — jit (or the
    engine's AOT bucket compiles) specializes per shape."""

    def prefill_chunk_step(params, state: M.PagedDecodeState, tokens, slot):
        return M.prefill_chunk(params, cfg, state, tokens, slot)

    return prefill_chunk_step


# ---------------------------------------------------------------------------
# ShapeDtypeStruct inputs per (arch x shape) cell — no device allocation.
# ---------------------------------------------------------------------------

def _batch_extras(cfg: ArchConfig, batch: int) -> Dict[str, Any]:
    extras: Dict[str, Any] = {}
    if cfg.family == "encdec":
        extras["frames"] = F32((batch, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        extras["patches"] = F32((batch, cfg.prefix_len, M.VISION_DIM))
    return extras


def params_struct(cfg: ArchConfig):
    return jax.eval_shape(lambda: M.init_model(jax.random.PRNGKey(0), cfg))


def opt_state_struct(cfg: ArchConfig, p_struct, opt_cfg: AdamWConfig):
    return jax.eval_shape(lambda: adamw_init(
        jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), p_struct),
        opt_cfg,
    ))


def decode_state_struct(cfg: ArchConfig, p_struct, batch: int, max_seq: int):
    def build():
        params = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), p_struct)
        enc = None
        if cfg.family == "encdec":
            enc = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), cfg.jax_dtype)
        return M.init_decode_state(params, cfg, batch, max_seq, encoder_out=enc)

    return jax.eval_shape(build)


def input_specs(cfg: ArchConfig, shape: Dict[str, Any]) -> Dict[str, Any]:
    """Spec dict for one shape cell: what the lowered step consumes.

    train  -> {"batch": {tokens, labels, ...}}
    prefill-> {"batch": {tokens, ...}}
    decode -> {"tokens": (B,1), "max_seq": S}  (DecodeState built separately)
    """
    kind, S, B = shape["kind"], shape["seq_len"], shape["global_batch"]
    if kind == "train":
        return {
            "batch": {
                "tokens": S32((B, S)),
                "labels": S32((B, S)),
                **_batch_extras(cfg, B),
            }
        }
    if kind == "prefill":
        return {"batch": {"tokens": S32((B, S)), **_batch_extras(cfg, B)}}
    if kind == "decode":
        return {"tokens": S32((B, 1)), "max_seq": S}
    raise ValueError(kind)
