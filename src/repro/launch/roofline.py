"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step on the target
TPU v5e-class hardware (the compiled module is the per-device SPMD program,
so cost_analysis numbers are already per-chip):

  compute    = HLO_FLOPs / peak_FLOP/s
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / link_bw

collective_bytes is parsed from the post-SPMD optimized HLO text: the summed
result sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (loop bodies multiplied by trip count when inside a
while; XLA CPU keeps scans as loops, so we scale collectives inside the
layer-scan body by the trip count parsed from the loop condition — a
conservative estimate documented in EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.launch import mesh as mesh_lib

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c\d+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO result type (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int]
    count_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result bytes of every collective in post-optimization HLO.

    Collectives inside while-loop bodies are multiplied by the trip count
    when it is statically recoverable from the loop-bound constant pattern.
    """
    bytes_by_op: Dict[str, int] = {}
    count_by_op: Dict[str, int] = {}

    # Identify computations and their trip-count multipliers.
    # XLA names scan loop bodies e.g. "%body.123"; trip counts are hard to
    # recover robustly, so we use a simpler correct-by-construction approach:
    # collect collectives over the whole module; each while body appears once
    # in the text, so scan-internal collectives are counted once per step and
    # we additionally report the loop multiplier when found.
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+([\w-]+)\(", s)
        if not m:
            continue
        opname = m.group(2)
        base = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-"):
                base = c
                break
        if base is None:
            continue
        nbytes = _shape_bytes(m.group(1))
        bytes_by_op[base] = bytes_by_op.get(base, 0) + nbytes
        count_by_op[base] = count_by_op.get(base, 0) + 1
    return CollectiveStats(bytes_by_op, count_by_op)


def loop_trip_counts(hlo_text: str) -> Dict[str, int]:
    """Best-effort extraction of while-loop trip counts (layer scans)."""
    trips = {}
    for m in re.finditer(
        r'while\(.*?\), condition=%?([\w.\-]+).*?body=%?([\w.\-]+)', hlo_text
    ):
        trips[m.group(2)] = -1  # present but unknown
    # constant-bound comparisons inside conditions: "compare(x, c), direction=LT"
    return trips


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    model_flops_global: float
    chips: int
    peak_memory_bytes: Optional[float] = None

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / mesh_lib.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / mesh_lib.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / mesh_lib.ICI_BW

    @property
    def bound(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three terms (full overlap model)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/padding/redundancy waste."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        t = self.step_time_s
        if not t:
            return 0.0
        return self.model_flops_global / (t * self.chips * mesh_lib.PEAK_FLOPS_BF16)

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "useful_flops_ratio": self.useful_flops_ratio, "mfu": self.mfu,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes": self.collective_bytes,
            "peak_memory_bytes": self.peak_memory_bytes,
        }


def model_flops(cfg, shape: Dict) -> float:
    """MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D for inference."""
    n = cfg.active_param_count()
    kind, S, B = shape["kind"], shape["seq_len"], shape["global_batch"]
    if kind == "train":
        return 6.0 * n * S * B
    if kind == "prefill":
        return 2.0 * n * S * B
    # decode: one token per sequence
    return 2.0 * n * 1 * B


def analyze(
    *, arch: str, shape_name: str, shape: Dict, mesh_name: str, chips: int,
    cfg, compiled, lac=None,
) -> Roofline:
    from repro.launch import hlo_cost

    if lac is None:
        text = compiled.as_text()
        lac = hlo_cost.analyze(text)  # loop-aware: scan bodies x trip count
    flops = float(lac.flops)
    nbytes = float(lac.bytes_accessed)
    coll = CollectiveStats(
        {k: int(v) for k, v in lac.collective_bytes_by_op.items()},
        dict(lac.collective_count),
    )
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0)) + float(
            getattr(ma, "argument_size_in_bytes", 0)
        ) + float(getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name,
        flops_per_device=flops, bytes_per_device=nbytes,
        collective_bytes=float(coll.total_bytes),
        model_flops_global=model_flops(cfg, shape),
        chips=chips, peak_memory_bytes=mem,
    )
