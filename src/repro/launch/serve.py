"""Serving launcher: thin CLI over the serving engine (repro.serving).

The engine maps the paper's three utilization mechanisms onto the request
path — warmup (autotune + AOT compile) as configuration pre-loading, chunked
prefill interleaved with decode as input pre-fetching with output buffering,
and the paged KV cache as programmable strided memory access.  See
EXPERIMENTS.md §Serving for the mechanism table and measured speedups.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --requests 8 \
      --autotune --compare-prefill

``--compare-prefill`` additionally times the legacy token-by-token prefill
loop (decode steps over a padded batch) against the engine's chunked prefill
on the same prompts and prints the wall-clock speedup.

``--precision w8a8`` serves through the paper's int8 deployment datapath:
warmup calibrates/quantizes the weights int8-resident and compiles int8
decode/prefill steps (see repro.quant and EXPERIMENTS.md §Quantization).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.serving.engine import (  # re-exported for back-compat
    Engine,
    autotune_for_serving,
    serving_gemm_shapes,
)
from repro.serving.request import PRIORITIES, RequestSpec, SamplingParams

__all__ = ["Engine", "autotune_for_serving", "serving_gemm_shapes",
           "token_by_token_prefill", "serve_cluster", "main"]


def _parse_class_mix(spec: str):
    """'interactive=0.7,batch=0.3' -> (('interactive', 0.7), ('batch', 0.3));
    empty string -> None (all-interactive traffic)."""
    if not spec:
        return None
    mix = []
    for part in spec.split(","):
        name, _, w = part.partition("=")
        name = name.strip()
        if name not in PRIORITIES:
            raise SystemExit(f"--priority-classes: unknown class {name!r}; "
                             f"expected one of {PRIORITIES}")
        mix.append((name, float(w) if w else 1.0))
    return tuple(mix)


def _sampling_from_args(args) -> SamplingParams:
    """CLI sampling knobs -> SamplingParams (temperature 0 = greedy)."""
    return SamplingParams(temperature=args.temperature, top_k=args.top_k,
                          top_p=args.top_p,
                          seed=args.seed if args.seed >= 0 else None)


def warm_token_by_token(cfg, params, slots: int, max_seq: int):
    """Compile the baseline's decode step and build its initial state
    *before* any timed region — the same footing the engine gets from
    Engine.warmup().  Returns (jitted step, initial decode state) to pass
    into token_by_token_prefill."""
    serve_step = jax.jit(steps_lib.make_serve_step(cfg))
    state = M.init_decode_state(params, cfg, slots, max_seq)
    out, _ = serve_step(params, state, jnp.zeros((slots, 1), jnp.int32))
    jax.block_until_ready(out)
    return serve_step, state


def token_by_token_prefill(cfg, params, prompts: List[np.ndarray], *,
                           max_seq: int, warmed=None):
    """The pre-engine prefill path, kept as the comparison baseline: pad all
    prompts to the batch max and feed them through the decode step one token
    at a time (short prompts burn dead steps on their padding positions).

    Pass `warmed` from warm_token_by_token() when timing this, so the
    measurement is steady-state dispatch — not the jit trace+compile or the
    dense cache allocation.  Returns (last logits, state, step call count).
    """
    slots = len(prompts)
    if warmed is None:
        warmed = warm_token_by_token(cfg, params, slots, max_seq)
    serve_step, state = warmed
    maxlen = max(len(p) for p in prompts)
    padded = np.zeros((slots, maxlen), np.int32)
    for i, p in enumerate(prompts):
        padded[i, :len(p)] = p
    last = None
    for t in range(maxlen):
        last, state = serve_step(params, state, jnp.asarray(padded[:, t:t + 1]))
    jax.block_until_ready(last)
    return last, state, maxlen


def compare_prefill(cfg, params, prompts: List[np.ndarray], *, slots: int,
                    max_seq: int, block_size: int = 16, num_blocks=None,
                    max_chunk: int = 64, iters: int = 3):
    """Time legacy token-by-token prefill vs the engine's chunked prefill on
    the same prompts; returns (t_legacy_s, t_chunked_s).

    Both paths are pre-compiled (warm_token_by_token / Engine.warmup) and
    the iterations *interleave* legacy/chunked runs, each side reported as
    its best-of-`iters` — so shared-host load spikes hit both paths alike
    and the ratio measures steady-state step-count/batching effects.
    Engine iterations after the first refill previously-used slots —
    steady-state serving, slot resets included.  The one comparison harness
    behind both the ``--compare-prefill`` CLI flag and
    benchmarks/serving_bench.py.
    """
    if params is None:
        params = M.init_model(jax.random.PRNGKey(0), cfg)
    warmed = warm_token_by_token(cfg, params, slots, max_seq)
    eng = Engine(cfg, params=params, slots=slots, max_seq=max_seq,
                 block_size=block_size, num_blocks=num_blocks,
                 max_chunk=max_chunk)
    eng.warmup()

    def legacy():
        token_by_token_prefill(cfg, params, prompts[:slots],
                               max_seq=max_seq, warmed=warmed)

    def chunked():
        # max_new=1: the first token falls out of the final chunk, so each
        # run is pure prefill.
        for p in prompts[:slots]:
            eng.submit(RequestSpec(prompt=p, max_new=1))
        eng.run()

    t_legacy, t_chunked = float("inf"), float("inf")
    for _ in range(iters):
        t_legacy = min(t_legacy, _timed(legacy))
        t_chunked = min(t_chunked, _timed(chunked))
    return t_legacy, t_chunked


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _build_recorder(args, *, metadata=None):
    """FlightRecorder for --incident-dir (None when the flag is off)."""
    if not getattr(args, "incident_dir", ""):
        return None
    from repro.obs import FlightRecorder

    # min_interval_s: a shed storm writes one bundle per reason per second,
    # not one per refused request.
    return FlightRecorder(args.incident_dir, min_interval_s=1.0,
                          metadata=metadata)


def _evaluate_slo(args, snapshot, recorder, engines):
    """--slo post-run evaluation: print the burn-rate report, capture
    breach bundles, and run the built-in engine pressure triggers."""
    report = None
    if getattr(args, "slo", ""):
        from repro.obs import SloMonitor, parse_slo_spec

        monitor = SloMonitor(parse_slo_spec(args.slo))
        report = monitor.observe(snapshot)
        print(f"slo: {report.summary()}")
        if recorder is not None:
            recorder.record_breaches(report)
    if recorder is not None:
        for e in engines:
            recorder.check_engine(e)
        if recorder.incidents:
            print(f"incidents: {len(recorder.incidents)} bundle(s) -> "
                  + ", ".join(recorder.incidents))
    return report


def serve_cluster(cfg, args) -> None:
    """Multi-replica serving (repro.cluster): pool + router + traffic."""
    from repro import cluster

    max_seq = args.prompt_len + args.gen_len + 1
    sampling = _sampling_from_args(args)
    class_mix = _parse_class_mix(args.priority_classes)
    pool = cluster.ReplicaPool(
        cfg, args.replicas, slots=args.slots or 2, max_seq=max_seq,
        block_size=args.block_size, num_blocks=args.kv_blocks or None,
        max_chunk=args.chunk, autotune=args.autotune,
        tune_mode=args.tune_mode, precision=args.precision,
        kv_precision=args.kv_precision,
        prefix_cache=args.prefix_cache,
        speculative=args.draft_k if args.speculative else False,
        sampling=not sampling.is_greedy, preempt=args.preempt,
        trace=bool(args.trace_out))
    # Router lane for the distributed trace: admission/shed/route events
    # live on their own pid above the replica lanes, and every request's
    # flow arrow starts here.
    router_tracer = None
    if args.trace_out:
        from repro.obs import Tracer

        router_tracer = Tracer(name="router", pid=args.replicas)
    recorder = _build_recorder(
        args, metadata={"arch": cfg.name, "replicas": args.replicas})
    if recorder is not None:
        if router_tracer is not None:
            recorder.add_tracer(router_tracer)
        for i, e in enumerate(pool.engines):
            recorder.attach_engine(e, name=f"replica{i}")
    t0 = time.time()
    pool.warmup(verbose=True)
    print(f"warmup: {args.replicas} replicas in {time.time() - t0:.1f}s "
          f"(steps compiled once, shared)")
    trace = cluster.mixed_traffic(
        cfg.vocab, n=args.requests, seed=0,
        max_prompt=args.prompt_len, max_new=(2, args.gen_len),
        class_mix=class_mix, tenants=args.tenants)
    pool.start()
    router = cluster.Router(pool, policy=args.router_policy,
                            max_pending=args.max_pending or None,
                            tracer=router_tracer, recorder=recorder)
    t0 = time.time()
    handles, shed = cluster.replay(
        trace, router.submit,
        sampling=None if sampling.is_greedy else sampling)
    router.drain()
    elapsed = time.time() - t0
    m = cluster.aggregate(pool, router, elapsed_s=elapsed)
    print(f"cluster[{args.router_policy}]: {m.summary()}")
    for i, e in enumerate(pool.engines):
        print(f"  replica[{i}]: {e.metrics.summary()}")
    router.close()
    pool.stop()     # replica threads must be parked before reading the rings
    _evaluate_slo(args, cluster.slo_snapshot(m), recorder, pool.engines)
    if args.trace_out:
        doc = pool.export_trace(
            args.trace_out, metadata={"arch": cfg.name,
                                      "replicas": args.replicas},
            extra_tracers=[router_tracer] if router_tracer else ())
        print(f"trace: {len(doc['traceEvents'])} events -> {args.trace_out} "
              f"(open in https://ui.perfetto.dev)")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump({"cluster": m.as_dict(),
                       "replicas": [e.metrics.as_dict()
                                    for e in pool.engines]}, f, indent=2)
        print(f"metrics: {args.metrics_json}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=configs.list_archs())
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=0,
                    help="decode batch slots (default: --requests)")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=64,
                    help="max prefill chunk (power-of-two buckets)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV cache block size in tokens")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="KV pool blocks (default: worst-case for --slots)")
    ap.add_argument("--autotune", action="store_true",
                    help="pre-tune this model's GeMM tiles before serving")
    ap.add_argument("--tune-mode", default="analytic",
                    choices=["analytic", "wallclock"])
    ap.add_argument("--precision", default="float",
                    choices=["float", "w8a8", "w8a8-calibrated"],
                    help="execution precision: w8a8 quantizes weights "
                         "int8-resident at warmup and serves through the "
                         "paper's int8 datapath (repro.quant)")
    ap.add_argument("--kv-precision", default="float",
                    choices=["float", "int8"],
                    help="KV pool residency: int8 keeps the paged pool "
                         "int8-resident (per-block scales, in-kernel "
                         "dequant) — ~half the pool bytes per token")
    ap.add_argument("--compare-prefill", action="store_true",
                    help="time legacy token-by-token prefill vs the engine")
    ap.add_argument("--replicas", type=int, default=1,
                    help=">1 serves through repro.cluster: a replica pool "
                         "behind an async router")
    ap.add_argument("--router-policy", default="round-robin",
                    choices=["round-robin", "least-loaded", "prefix-affinity"],
                    help="cluster load-balancing policy (with --replicas)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="reuse prefilled KV blocks across requests sharing "
                         "a prompt prefix (attention-only archs)")
    ap.add_argument("--speculative", action="store_true",
                    help="self-speculative decoding: a prompt-lookup n-gram "
                         "drafter proposes tokens and one batched verify "
                         "step scores them (greedy-token-identical; see "
                         "README §Speculative)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="max drafted tokens per request per tick "
                         "(with --speculative)")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="cluster backpressure: in-flight request bound "
                         "(0 = unbounded; overflow is shed)")
    ap.add_argument("--priority-classes", default="",
                    help="SLO class mix for generated traffic, e.g. "
                         "'interactive=0.7,batch=0.3' (empty = all "
                         "interactive); classes drive admission order, "
                         "class-aware shedding, and --preempt victims")
    ap.add_argument("--tenants", type=int, default=1,
                    help="spread generated traffic over N synthetic tenant "
                         "ids (per-tenant fairness accounting in the router)")
    ap.add_argument("--preempt", action="store_true",
                    help="let interactive arrivals preempt decoding batch "
                         "requests: the victim's KV blocks swap to host "
                         "memory and restore on re-admission (attention-only "
                         "archs)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax, the "
                         "default; >0 samples on-device with per-request "
                         "PRNG streams)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest-probability tokens "
                         "(0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = disabled)")
    ap.add_argument("--seed", type=int, default=-1,
                    help="sampling PRNG seed shared by all requests "
                         "(-1 = derive per-request from the request id)")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(per-request lifecycle spans + per-tick phases; "
                         "see README §Observability)")
    ap.add_argument("--metrics-json", default="",
                    help="write the metrics snapshot (scalar gauges, "
                         "percentile histograms, per-phase MFU) as JSON")
    ap.add_argument("--slo", default="",
                    help="SLO spec evaluated after the run, e.g. "
                         "'ttft_p95=0.25,latency_p95=1.0,shed_rate=0.05,"
                         "mfu_floor=1e-6' (multi-window burn rates; see "
                         "README §Observability)")
    ap.add_argument("--incident-dir", default="",
                    help="flight-recorder output directory: sheds, SLO "
                         "breaches, and allocator/spec pressure write "
                         "self-contained JSON incident bundles here")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch)
    if args.replicas > 1:
        return serve_cluster(cfg, args)
    sampling = _sampling_from_args(args)
    class_mix = _parse_class_mix(args.priority_classes)
    slots = args.slots or args.requests
    max_seq = args.prompt_len + args.gen_len + 1
    eng = Engine(
        cfg, slots=slots, max_seq=max_seq,
        block_size=args.block_size,
        num_blocks=args.kv_blocks or None,
        max_chunk=args.chunk,
        autotune=args.autotune, tune_mode=args.tune_mode,
        precision=args.precision,
        kv_precision=args.kv_precision,
        prefix_cache=args.prefix_cache,
        speculative=args.draft_k if args.speculative else False,
        sampling=not sampling.is_greedy, preempt=args.preempt,
        trace=bool(args.trace_out),
        verbose=True,
    )
    t0 = time.time()
    eng.warmup()
    t_warm = time.time() - t0

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab, size=rng.integers(4, args.prompt_len + 1))
        for _ in range(args.requests)
    ]
    # Class assignment draws from its own stream so labelling never
    # perturbs the prompt draws above (same rule as cluster.traffic).
    crng = np.random.default_rng(0x5EED)
    names = [c for c, _ in (class_mix or ())]
    weights = np.asarray([w for _, w in (class_mix or ())], np.float64)
    if names:
        weights = weights / weights.sum()
    for p in prompts:
        prio = (PRIORITIES[0] if not names
                else names[int(crng.choice(len(names), p=weights))])
        eng.submit(RequestSpec(prompt=p, max_new=args.gen_len,
                               sampling=sampling, priority=prio))
    t0 = time.time()
    results = eng.run()
    t_serve = time.time() - t0

    gen = np.stack([results[rid] for rid in sorted(results)])
    pool_tokens = (eng.num_blocks - 1) * eng.block_size
    dense_tokens = slots * max_seq
    print(f"arch={cfg.name} slots={slots} precision={args.precision} "
          f"warmup {t_warm*1e3:.0f}ms serve {t_serve*1e3:.0f}ms")
    print(f"engine: {eng.metrics.summary()}")
    print(f"kv pool: {eng.num_blocks - 1} blocks x {eng.block_size} tokens "
          f"= {pool_tokens} tokens shared "
          f"(dense would pin {dense_tokens} = slots x max_seq per layer)")
    print("sample continuations:", gen[:2, :8].tolist())

    recorder = _build_recorder(args, metadata={"arch": cfg.name})
    if recorder is not None:
        recorder.attach_engine(eng)
    from repro.obs import engine_snapshot

    _evaluate_slo(args, engine_snapshot(eng), recorder, [eng])

    if args.trace_out:
        from repro.obs import write_chrome_trace

        doc = write_chrome_trace(args.trace_out, [eng.tracer],
                                 metadata={"arch": cfg.name})
        print(f"trace: {len(doc['traceEvents'])} events -> {args.trace_out} "
              f"(open in https://ui.perfetto.dev)")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(eng.metrics.as_dict(), f, indent=2)
        print(f"metrics: {args.metrics_json}")

    if args.compare_prefill:
        t_legacy, t_chunked = compare_prefill(
            cfg, eng.params, prompts, slots=slots, max_seq=max_seq,
            block_size=args.block_size, num_blocks=args.kv_blocks or None,
            max_chunk=args.chunk)
        print(f"prefill: token-by-token {t_legacy*1e3:.0f}ms vs chunked "
              f"{t_chunked*1e3:.0f}ms -> {t_legacy / t_chunked:.1f}x speedup")
    return gen


if __name__ == "__main__":
    main()
