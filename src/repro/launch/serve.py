"""Serving launcher: batched request decoding with continuous batching.

A minimal production-shaped server loop: requests arrive with prompts of
different lengths, get packed into a fixed decode batch, prefill fills the
KV/SSM caches, and decode steps retire tokens for all active slots; finished
slots are refilled from the queue (continuous batching).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --requests 8
"""

from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as steps_lib
from repro.models import model as M


class BatchedServer:
    def __init__(self, cfg, params, *, slots: int = 4, max_seq: int = 256):
        self.cfg, self.params = cfg, params
        self.slots, self.max_seq = slots, max_seq
        self.serve_step = jax.jit(steps_lib.make_serve_step(cfg))
        self.state = M.init_decode_state(params, cfg, slots, max_seq)
        self.tokens = jnp.zeros((slots, 1), jnp.int32)

    def prefill_prompts(self, prompts: List[np.ndarray]):
        """Feed prompts token-by-token through decode (cache warmup)."""
        assert len(prompts) <= self.slots
        maxlen = max(len(p) for p in prompts)
        padded = np.zeros((self.slots, maxlen), np.int32)
        for i, p in enumerate(prompts):
            padded[i, :len(p)] = p
        last = None
        for t in range(maxlen):
            last, self.state = self.serve_step(
                self.params, self.state, jnp.asarray(padded[:, t:t + 1])
            )
        return last

    def decode(self, steps: int, greedy: bool = True):
        outs = []
        logits, state = None, self.state
        tok = self.tokens
        for _ in range(steps):
            logits, state = self.serve_step(self.params, state, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok[:, 0]))
        self.state = state
        return np.stack(outs, axis=1)  # (slots, steps)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=configs.list_archs())
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(cfg, params, slots=args.requests,
                           max_seq=args.prompt_len + args.gen_len + 1)

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab, size=rng.integers(4, args.prompt_len + 1))
        for _ in range(args.requests)
    ]
    t0 = time.time()
    server.prefill_prompts(prompts)
    t_pre = time.time() - t0
    t0 = time.time()
    gen = server.decode(args.gen_len)
    t_dec = time.time() - t0
    tps = args.requests * args.gen_len / t_dec
    print(f"arch={cfg.name} slots={args.requests} "
          f"prefill {t_pre*1e3:.0f}ms decode {t_dec*1e3:.0f}ms "
          f"({tps:.1f} tok/s aggregate)")
    print("sample continuations:", gen[:2, :8].tolist())
    return gen


if __name__ == "__main__":
    main()
