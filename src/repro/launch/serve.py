"""Serving launcher: batched request decoding with continuous batching.

A minimal production-shaped server loop: requests arrive with prompts of
different lengths, get packed into a fixed decode batch, prefill fills the
KV/SSM caches, and decode steps retire tokens for all active slots; finished
slots are refilled from the queue (continuous batching).

With ``--autotune`` the server pre-tunes the model's GeMM shapes before
taking traffic: the tile autotuner (repro.tuning) searches (TM, TK, TN) per
projection once, persists the winners, and every spec-less `ops.gemm` call
dispatches through the cached result — no hand-picked tiles in the serving
path.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --requests 8 \
      --autotune
"""

from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.dataflow import GemmShape
from repro.launch import steps as steps_lib
from repro.models import model as M


class BatchedServer:
    def __init__(self, cfg, params, *, slots: int = 4, max_seq: int = 256):
        self.cfg, self.params = cfg, params
        self.slots, self.max_seq = slots, max_seq
        self.serve_step = jax.jit(steps_lib.make_serve_step(cfg))
        self.state = M.init_decode_state(params, cfg, slots, max_seq)
        self.tokens = jnp.zeros((slots, 1), jnp.int32)

    def prefill_prompts(self, prompts: List[np.ndarray]):
        """Feed prompts token-by-token through decode (cache warmup)."""
        assert len(prompts) <= self.slots
        maxlen = max(len(p) for p in prompts)
        padded = np.zeros((self.slots, maxlen), np.int32)
        for i, p in enumerate(prompts):
            padded[i, :len(p)] = p
        last = None
        for t in range(maxlen):
            last, self.state = self.serve_step(
                self.params, self.state, jnp.asarray(padded[:, t:t + 1])
            )
        return last

    def decode(self, steps: int, greedy: bool = True):
        outs = []
        logits, state = None, self.state
        tok = self.tokens
        for _ in range(steps):
            logits, state = self.serve_step(self.params, state, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok[:, 0]))
        self.state = state
        return np.stack(outs, axis=1)  # (slots, steps)


def serving_gemm_shapes(cfg, *, slots: int) -> List[GemmShape]:
    """The per-step *dense-projection* GeMMs of a decode batch: the shapes
    to pre-tune.

    One decode step runs, per attention layer, the separate q/k/v and
    output projections (models/attention.py: wq (d, hq*hd), wk/wv
    (d, hkv*hd), wo (hq*hd, d)) and — for dense-FFN archs — the two FFN
    matmuls over `slots` token rows, plus the vocab head.  MoE expert
    matmuls (einsum over stacked expert weights) and SSM scans do not
    route through spec-dispatched ops.gemm, so they are not warmed here.
    """
    d, ff, vocab = cfg.d_model, cfg.d_ff, cfg.vocab
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    shapes = []
    if cfg.family != "ssm":              # archs with attention layers
        shapes += [
            GemmShape(slots, d, hq * hd),    # q projection
            GemmShape(slots, d, hkv * hd),   # k / v projections
            GemmShape(slots, hq * hd, d),    # attention output projection
        ]
    if cfg.moe is None:                  # dense FFN (MoE experts run via einsum)
        shapes += [
            GemmShape(slots, d, ff),         # FFN up (and swiglu gate)
            GemmShape(slots, ff, d),         # FFN down
        ]
    shapes.append(GemmShape(slots, d, vocab))  # LM head
    # dedupe, preserving order
    seen, out = set(), []
    for s in shapes:
        if s not in seen:
            seen.add(s)
            out.append(s)
    return out


def autotune_for_serving(cfg, *, slots: int, mode: str = "analytic") -> None:
    """Warm the tuner cache for this model's shapes and enable tuned dispatch."""
    from repro import tuning

    tuner = tuning.Autotuner(mode=mode)
    tuning.set_tuner(tuner)
    shapes = serving_gemm_shapes(cfg, slots=slots)
    print(f"autotune[{mode}]: {len(shapes)} GeMM shapes for {cfg.name}")
    for r, s in zip(tuner.warmup(shapes, dtype=cfg.dtype), shapes):
        hit = "cache" if r.from_cache else r.source
        print(f"  {s.M}x{s.K}x{s.N}: tile=({r.spec.tm},{r.spec.tk},{r.spec.tn}) "
              f"[{hit}]")
    tuning.enable()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=configs.list_archs())
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--autotune", action="store_true",
                    help="pre-tune this model's GeMM tiles before serving")
    ap.add_argument("--tune-mode", default="analytic",
                    choices=["analytic", "wallclock"])
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch)
    if args.autotune:
        autotune_for_serving(cfg, slots=args.requests, mode=args.tune_mode)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(cfg, params, slots=args.requests,
                           max_seq=args.prompt_len + args.gen_len + 1)

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab, size=rng.integers(4, args.prompt_len + 1))
        for _ in range(args.requests)
    ]
    t0 = time.time()
    server.prefill_prompts(prompts)
    t_pre = time.time() - t0
    t0 = time.time()
    gen = server.decode(args.gen_len)
    t_dec = time.time() - t0
    tps = args.requests * args.gen_len / t_dec
    print(f"arch={cfg.name} slots={args.requests} "
          f"prefill {t_pre*1e3:.0f}ms decode {t_dec*1e3:.0f}ms "
          f"({tps:.1f} tok/s aggregate)")
    print("sample continuations:", gen[:2, :8].tolist())
    return gen


if __name__ == "__main__":
    main()
