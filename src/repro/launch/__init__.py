"""launch subpackage."""
