"""Launchers and step builders: the stable ``repro.launch`` API surface.

Everything is lazy (mirroring repro.serving's ``__getattr__`` table):
``from repro.launch import serve`` or ``repro.launch.Engine`` resolves on
first touch without importing every launcher — train pulls in the
optimizer stack, dryrun fakes 512 devices, and none of that should load
just to reach the serving CLI.
"""

import importlib

_SUBMODULES = (
    "diagnose", "dryrun", "hlo_cost", "mesh", "roofline", "serve", "steps",
    "train",
)

_LAZY = {
    # steps: the one-definition step builders (dry-run and real launchers)
    "make_train_step": ("repro.launch.steps", "make_train_step"),
    "make_prefill_step": ("repro.launch.steps", "make_prefill_step"),
    "make_serve_step": ("repro.launch.steps", "make_serve_step"),
    "make_paged_serve_step": ("repro.launch.steps", "make_paged_serve_step"),
    "make_prefill_chunk_step": ("repro.launch.steps", "make_prefill_chunk_step"),
    "input_specs": ("repro.launch.steps", "input_specs"),
    "optimizer_config": ("repro.launch.steps", "optimizer_config"),
    # serve: engine facade + comparison harness
    "Engine": ("repro.launch.serve", "Engine"),
    "autotune_for_serving": ("repro.launch.serve", "autotune_for_serving"),
    "serving_gemm_shapes": ("repro.launch.serve", "serving_gemm_shapes"),
    "compare_prefill": ("repro.launch.serve", "compare_prefill"),
    "serve_cluster": ("repro.launch.serve", "serve_cluster"),
    # meshes
    "make_local_mesh": ("repro.launch.mesh", "make_local_mesh"),
    "make_production_mesh": ("repro.launch.mesh", "make_production_mesh"),
}

__all__ = sorted(set(_SUBMODULES) | set(_LAZY))


def __getattr__(name: str):
    if name in _LAZY:
        module, attr = _LAZY[name]
        return getattr(importlib.import_module(module), attr)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.launch.{name}")
    raise AttributeError(f"module 'repro.launch' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
