"""Training launcher: end-to-end driver usable both for the CPU example
(~100M-param model, a few hundred steps) and as the template for a real
multi-pod job (same step function the dry-run lowers).

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --preset 100m \
      --steps 300 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax

from repro import configs
from repro.data import SyntheticLMData
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.optim import adamw_init
from repro.parallel import sharding as shard_lib
from repro.parallel.logical import use_rules
from repro.runtime import Supervisor, TrainLoopConfig


def preset_config(arch: str, preset: str):
    cfg = configs.get(arch)
    if preset == "full":
        return cfg
    if preset == "smoke":
        return configs.get_smoke(arch)
    if preset == "100m":
        # ~100M-param member of the same family (CPU-trainable).
        kw = dict(
            n_layers=8, d_model=512, n_heads=8,
            n_kv_heads=min(cfg.n_kv_heads, 4), head_dim=64,
            d_ff=2048 if cfg.d_ff else 0, vocab=min(cfg.vocab, 32768),
            group_size=1, dtype="float32",
        )
        if cfg.family == "hybrid":
            kw["attn_every"] = 4
            kw["group_size"] = 4
        if cfg.family == "ssm":
            kw["slstm_every"] = 4
            kw["group_size"] = 4
            kw["d_ff"] = 0
        if cfg.moe:
            kw["moe"] = dataclasses.replace(cfg.moe, num_experts=8, d_ff_expert=1024)
        if cfg.local_ratio:
            kw["group_size"] = cfg.local_ratio + 1
            kw["n_layers"] = 2 * (cfg.local_ratio + 1)
        if cfg.family == "encdec":
            kw["encoder_layers"] = 4
            kw["encoder_seq"] = 64
        if cfg.family == "vlm":
            kw["prefix_len"] = 16
        return dataclasses.replace(cfg, **kw)
    raise ValueError(preset)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=configs.list_archs())
    ap.add_argument("--preset", default="100m", choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (fault-tolerance demo)")
    ap.add_argument("--quant", default=None, choices=[None, "int8"])
    args = ap.parse_args(argv)

    cfg = preset_config(args.arch, args.preset)
    mesh = make_local_mesh(args.model_parallel)
    plan = shard_lib.make_plan(mesh, cfg.param_count(), force_fsdp=False)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    key = jax.random.PRNGKey(0)
    with use_rules(mesh, plan.activation_rules()):
        params = M.init_model(key, cfg)
        opt_cfg = steps_lib.optimizer_config(cfg)
        opt_state = adamw_init(params, opt_cfg)
        train_step = steps_lib.make_train_step(
            cfg, opt_cfg, base_lr=args.lr, total_steps=args.steps
        )
        p_shard = shard_lib.param_sharding(params, mesh, plan)
        params = jax.device_put(params, p_shard)

        extras = {}
        if cfg.family == "encdec":
            extras["frames"] = (cfg.encoder_seq, cfg.d_model)
        if cfg.family == "vlm":
            extras["patches"] = (cfg.prefix_len, M.VISION_DIM)
        data = SyntheticLMData(cfg.vocab, args.batch, args.seq, extras=extras)

        jstep = jax.jit(train_step, donate_argnums=(0, 1))
        sup = Supervisor(
            jstep,
            data_at=data.batch_at,
            loop_cfg=TrainLoopConfig(
                total_steps=args.steps, ckpt_every=args.ckpt_every,
                ckpt_dir=args.ckpt_dir,
            ),
            simulate_failure_at=args.fail_at,
        )
        if args.resume:
            restored = sup.restore(params, opt_state)
            if restored:
                params, opt_state, start = restored
                print(f"resumed from step {start}")
        t0 = time.time()
        out = sup.run(params, opt_state)
        dt = time.time() - t0

    losses = [m["loss"] for m in out["metrics"]]
    print(json.dumps({
        "steps": out["step"], "restarts": out["restarts"],
        "straggler_steps": out["straggler_steps"],
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "wall_s": round(dt, 1),
    }, indent=1))
    return out


if __name__ == "__main__":
    main()
