"""Cycle-level performance model of the OpenGeMM platform.

Models the timing behaviour described in Sec. 3 / Fig. 4 of the paper:

  * a GeMM call = CSR configuration + launch handshake + tile pipeline,
  * streamers fetch one A'+B' tile pair per `input_fetch_cycles` and drain one
    C' tile per `output_write_cycles` (derived from R_mem/W_mem/P_word),
  * bank conflicts multiply streamer latency when the layout is not
    interleaved (no SMA),
  * configuration pre-loading (CPL) overlaps the CSR routine of call i+1 with
    the compute of call i,
  * input pre-fetch buffers of depth D hide a fraction (D-1)/D of streamer
    latency jitter; output buffers let write-back overlap the next
    accumulation group.

The model is deliberately closed-form per call (the tile pipeline is regular,
so an event-driven simulation collapses to arithmetic); the free constants
(`csr_cycles`, `bank_conflict_factor`) are calibrated once against the
paper's Fig. 5 median ratios — see benchmarks/fig5_ablation.py and
EXPERIMENTS.md.

Utilization definitions match the paper (Table 2 footnotes):
  SU = useful MACs / padded MACs,  TU = busy cycles / total cycles,
  OU = SU * TU = useful MACs / (total cycles * peak MACs/cycle).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Sequence, Tuple

from repro.core.dataflow import GemmShape, aggregate_utilization
from repro.core.generator import OpenGeMMConfig


@dataclasses.dataclass(frozen=True)
class CallTiming:
    """Cycle breakdown of one GeMM call on the accelerator."""

    shape: GemmShape
    config_cycles: int          # exposed (non-hidden) configuration time
    fill_cycles: int            # pipeline fill (first fetches)
    compute_cycles: int         # MAC-array busy cycles (incl. padding tiles)
    input_stall_cycles: int     # array idle waiting on operand streamers
    output_stall_cycles: int    # array idle waiting on write-back
    total_cycles: int
    padded_shape: GemmShape     # shape rounded up to the (Mu, Ku, Nu) tiles

    @property
    def busy_cycles(self) -> int:
        return self.compute_cycles

    @property
    def temporal_utilization(self) -> float:
        return self.compute_cycles / self.total_cycles

    @property
    def spatial_utilization(self) -> float:
        """SU = useful MACs / MACs issued on the tile-padded problem; < 1
        whenever M, K or N is not a multiple of the array dims (edge tiles
        run with part of the array idle)."""
        return self.shape.macs / self.padded_shape.macs

    @property
    def overall_utilization(self) -> float:
        return self.spatial_utilization * self.temporal_utilization


@dataclasses.dataclass(frozen=True)
class WorkloadReport:
    """Aggregated utilization over a sequence of calls (one model / workload)."""

    su: float
    tu: float
    ou: float
    total_cycles: int
    calls: int
    macs: int

    def gops(self, freq_hz: float = 200e6) -> float:
        return 2 * self.macs / (self.total_cycles / freq_hz) / 1e9


class OpenGeMMSimulator:
    """Performance model for a generated OpenGeMM instance."""

    def __init__(self, config: OpenGeMMConfig | None = None):
        self.cfg = config or OpenGeMMConfig()
        df = self.cfg.dataflow
        self.spatial = df.spatial
        self.df = df

    # -- single call --------------------------------------------------------

    def simulate_call(
        self, shape: GemmShape, *, first_call: bool = True, prev_busy_cycles: int = 0
    ) -> CallTiming:
        cfg = self.cfg
        m, k, n = self.spatial.tile_counts(shape)
        compute = m * k * n

        conflict = 1.0 if cfg.strided_access else float(cfg.bank_conflict_factor)
        f_eff = cfg.input_fetch_cycles * conflict      # streamer cycles / tile pair
        w_eff = cfg.output_write_cycles * conflict     # streamer cycles / C' tile

        if cfg.input_prefetch:
            # Depth-D buffer hides (D-1)/D of the above-1-cycle fetch latency:
            # the streamer runs ahead while the array computes, and only the
            # un-hidable residue stalls the array.
            tile_t = 1.0 + max(0.0, f_eff - 1.0) / cfg.D_stream
            fill = int(math.ceil(f_eff + cfg.spm_latency - 1))  # first fetch exposed
            # Output buffers drain while the next accumulation group runs;
            # stall only if draining outlasts the group (small-K workloads),
            # plus the SPM pipeline restart bubble per group, which deeper
            # buffers progressively hide (paper: depth 3/4 keep improving).
            group_cycles = k * tile_t
            restart_bubble = (cfg.spm_latency - 1.0) / max(1, cfg.D_stream - 1)
            out_stall_per_group = max(0.0, w_eff - (group_cycles - 1.0)) + restart_bubble
            input_stall = int(math.ceil(compute * (tile_t - 1.0)))
        else:
            # Fetch and compute fully serialize (Fig. 4(a) case 2).
            tile_t = f_eff + 1.0
            fill = 0
            out_stall_per_group = w_eff  # write-back blocks the array (case 3)
            input_stall = int(math.ceil(compute * (tile_t - 1.0)))

        output_stall = int(math.ceil(m * n * out_stall_per_group))

        csr = cfg.csr_cycles
        if cfg.cfg_preload and not first_call:
            # CSR routine for this call ran during the previous call's busy
            # time (Fig. 4(b) case 1); only the un-hidden residue is exposed.
            csr = max(0, csr - prev_busy_cycles)
        config_cycles = csr + cfg.launch_cycles

        total = config_cycles + fill + compute + input_stall + output_stall
        return CallTiming(
            shape=shape,
            config_cycles=config_cycles,
            fill_cycles=fill,
            compute_cycles=compute,
            input_stall_cycles=input_stall,
            output_stall_cycles=output_stall,
            total_cycles=total,
            padded_shape=self.spatial.padded_shape(shape),
        )

    # -- call sequences ------------------------------------------------------

    def simulate_sequence(self, shapes: Sequence[GemmShape]) -> List[CallTiming]:
        """Simulate back-to-back GeMM calls (a layer list / repeated workload)."""
        out: List[CallTiming] = []
        prev_busy = 0
        for i, s in enumerate(shapes):
            t = self.simulate_call(s, first_call=(i == 0), prev_busy_cycles=prev_busy)
            out.append(t)
            prev_busy = t.total_cycles - t.config_cycles
        return out

    def report(self, shapes: Sequence[GemmShape]) -> WorkloadReport:
        timings = self.simulate_sequence(shapes)
        pairs = [(t.shape, t.total_cycles) for t in timings]
        su, tu, ou, total = aggregate_utilization(self.df, pairs)
        # Per-call SU must reproduce the MAC-weighted aggregate: the same
        # padding arithmetic through two code paths (CallTiming vs dataflow).
        per_call_su = (sum(t.shape.macs for t in timings)
                       / sum(t.padded_shape.macs for t in timings))
        assert abs(per_call_su - su) < 1e-12, (per_call_su, su)
        return WorkloadReport(
            su=su,
            tu=tu,
            ou=ou,
            total_cycles=total,
            calls=len(timings),
            macs=sum(t.shape.macs for t in timings),
        )

    def utilization(self, shape: GemmShape, repeats: int = 1) -> float:
        """Overall utilization of one workload repeated back-to-back (Fig. 5)."""
        rep = self.report([shape] * repeats)
        return rep.ou

    def report_grouped(
        self, calls: Sequence[Tuple[GemmShape, int]]
    ) -> WorkloadReport:
        """Aggregate over (shape, count) groups without materializing every call.

        Identical back-to-back calls reach a steady state after the first
        (CPL hides the CSR routine behind the previous call's busy time), so a
        group of `count` calls costs t_first + (count-1) * t_steady.  The very
        first call of the whole workload pays the full configuration time.
        """
        total_cycles = 0
        total_macs = 0
        padded_macs = 0
        compute_cycles = 0
        ncalls = 0
        prev_busy = 0
        first = True
        for shape, count in calls:
            if count < 1:
                raise ValueError(f"count must be >= 1, got {count} for {shape}")
            t_first = self.simulate_call(
                shape, first_call=first, prev_busy_cycles=prev_busy
            )
            busy = t_first.total_cycles - t_first.config_cycles
            t_steady = self.simulate_call(shape, first_call=False, prev_busy_cycles=busy)
            total_cycles += t_first.total_cycles + (count - 1) * t_steady.total_cycles
            compute_cycles += count * t_first.compute_cycles
            total_macs += count * shape.macs
            padded_macs += count * self.spatial.padded_shape(shape).macs
            ncalls += count
            prev_busy = t_steady.total_cycles - t_steady.config_cycles
            first = False
        return WorkloadReport(
            su=total_macs / padded_macs,
            tu=compute_cycles / total_cycles,
            ou=total_macs / (total_cycles * self.spatial.macs_per_cycle),
            total_cycles=total_cycles,
            calls=ncalls,
            macs=total_macs,
        )


# ---------------------------------------------------------------------------
# Fig. 5 ablation architectures
# ---------------------------------------------------------------------------

def ablation_architectures(
    base: OpenGeMMConfig | None = None,
) -> "dict[str, OpenGeMMConfig]":
    """The four platform variants of the paper's Fig. 5 (+ depth sweeps)."""
    base = base or OpenGeMMConfig()
    return {
        "arch1_baseline": base.with_mechanisms(cpl=False, prefetch=False, sma=False),
        "arch2_cpl": base.with_mechanisms(cpl=True, prefetch=False, sma=False),
        "arch3_cpl_buf2": base.with_mechanisms(cpl=True, prefetch=True, sma=False, depth=2),
        "arch4_all_buf2": base.with_mechanisms(cpl=True, prefetch=True, sma=True, depth=2),
        "arch4_all_buf3": base.with_mechanisms(cpl=True, prefetch=True, sma=True, depth=3),
        "arch4_all_buf4": base.with_mechanisms(cpl=True, prefetch=True, sma=True, depth=4),
    }


def random_fig5_shapes(count: int = 500, seed: int = 0) -> List[GemmShape]:
    """500 random (M,K,N), each dim drawn from {8, 16, ..., 256} (Sec. 4.2)."""
    import random as _random

    rng = _random.Random(seed)
    choices = list(range(8, 257, 8))
    return [
        GemmShape(rng.choice(choices), rng.choice(choices), rng.choice(choices))
        for _ in range(count)
    ]


def fig5_median_utilizations(
    shapes: Iterable[GemmShape] | None = None,
    base: OpenGeMMConfig | None = None,
    repeats: int = 10,
) -> "dict[str, float]":
    """Median overall utilization per ablation arch (the paper's box medians)."""
    shapes = list(shapes) if shapes is not None else random_fig5_shapes()
    meds: dict[str, float] = {}
    for name, cfg in ablation_architectures(base).items():
        sim = OpenGeMMSimulator(cfg)
        utils = sorted(sim.utilization(s, repeats=repeats) for s in shapes)
        mid = len(utils) // 2
        meds[name] = (
            utils[mid] if len(utils) % 2 else 0.5 * (utils[mid - 1] + utils[mid])
        )
    return meds
