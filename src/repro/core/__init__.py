"""OpenGeMM core: the paper's contribution as a composable library.

  dataflow      - 6-loop GeMM dataflow, tiling math, utilization definitions
  generator     - OpenGeMMConfig design-time parameterization (paper Table 1)
  simulator     - cycle model of the platform + Fig. 5 ablation harness
  workloads     - im2col GeMM extraction for the paper's four DNNs
  gemmini_model - Gemmini baseline for the Fig. 7 comparison
"""

from repro.core.dataflow import (
    Dataflow,
    GemmShape,
    SpatialUnrolling,
    TemporalUnrolling,
    aggregate_utilization,
)
from repro.core.generator import CASE_STUDY, OpenGeMMConfig, TpuGemmSpec
from repro.core.simulator import (
    OpenGeMMSimulator,
    WorkloadReport,
    ablation_architectures,
    fig5_median_utilizations,
    random_fig5_shapes,
)

__all__ = [
    "Dataflow",
    "GemmShape",
    "SpatialUnrolling",
    "TemporalUnrolling",
    "aggregate_utilization",
    "OpenGeMMConfig",
    "TpuGemmSpec",
    "CASE_STUDY",
    "OpenGeMMSimulator",
    "WorkloadReport",
    "ablation_architectures",
    "fig5_median_utilizations",
    "random_fig5_shapes",
]
