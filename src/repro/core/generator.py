"""OpenGeMM accelerator *generator*: design-time parameterization.

The paper's Table 1 enumerates the design-time parameters of the Chisel
generator.  `OpenGeMMConfig` mirrors them exactly, plus the three run-time
utilization mechanisms as feature flags (for the ablation of Fig. 5).

An `OpenGeMMConfig` can be turned into:
  * a cycle-accurate simulator instance   -> core/simulator.py
  * a TPU Pallas kernel specialization    -> kernels/gemm.py (via tpu_kernel_spec)

This is the "hardware generator" re-instantiated in software: one config,
many backends.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.dataflow import (
    Dataflow,
    GemmShape,
    SpatialUnrolling,
    TemporalUnrolling,
)

# ---------------------------------------------------------------------------
# TPU tile legality — the MXU analogue of the paper's (Mu, Ku, Nu) legality.
# Shared by `tpu_kernel_spec` (the fixed design-point mapping) and
# `repro.tuning` (the search over design points).
# ---------------------------------------------------------------------------

MXU_LANES = 128          # last-dim tile quantum (TN, TK)
MXU_SUBLANES = 8         # second-minor quantum for float32 (TM)
VMEM_BUDGET_BYTES = 96 * 1024 * 1024   # working-set ceiling used repo-wide


def sublane_multiple(bits: int) -> int:
    """Minimum efficient second-minor tile multiple for an operand width.

    The TPU packs narrower dtypes deeper per sublane: float32 tiles are
    (8, 128), bfloat16 (16, 128), int8 (32, 128).  TM below this multiple is
    still *legal* (the kernel only requires TM % 8 == 0) but wastes sublanes.
    """
    return {32: MXU_SUBLANES, 16: 2 * MXU_SUBLANES, 8: 4 * MXU_SUBLANES}.get(
        bits, MXU_SUBLANES
    )


@dataclasses.dataclass(frozen=True)
class OpenGeMMConfig:
    """Design-time parameters (paper Table 1) + mechanism flags (Sec. 3)."""

    # --- GeMM core ---------------------------------------------------------
    Mu: int = 8            # rows of the DotProd mesh
    Nu: int = 8            # columns of the DotProd mesh
    Ku: int = 8            # lanes per DotProd unit
    P_A: int = 8           # operand A precision (bits)
    P_B: int = 8           # operand B precision (bits)
    P_C: int = 32          # accumulator / result precision (bits)

    # --- memory system -----------------------------------------------------
    D_stream: int = 3      # pre-fetch / output buffer depth
    R_mem: int = 16        # input memory ports
    W_mem: int = 32        # output memory ports
    P_word: int = 64       # memory port width (bits)
    N_bank: int = 32       # scratchpad banks
    D_mem: int = 1056      # bank depth (words)

    # --- run-time mechanism flags (Fig. 5 ablation axes) --------------------
    cfg_preload: bool = True       # CPL  (Sec. 3.2)
    input_prefetch: bool = True    # pre-fetch + output buffering (Sec. 3.3)
    strided_access: bool = True    # SMA  (Sec. 3.4)

    # --- control-path model constants (calibrated, see EXPERIMENTS.md) ------
    # The configuration routine on the Snitch host (computing loop bounds,
    # addresses and strides, then writing the consolidated CSRs at
    # 32 bits/cycle) -> modeled as csr_cycles per (re)configuration, plus a
    # fixed launch handshake.  Calibrated against Fig. 5's median ratios.
    csr_cycles: int = 2600
    launch_cycles: int = 6
    # Bank-conflict penalty multiplier on SPM accesses when the layout is NOT
    # interleaved (no SMA): tiles mapping to the same bank serialize on a
    # fraction of accesses.  Calibrated against Fig. 5.
    bank_conflict_factor: float = 1.5
    # SPM read pipeline latency (cycles); deeper pre-fetch buffers hide it.
    spm_latency: int = 2

    def __post_init__(self) -> None:
        if min(self.Mu, self.Nu, self.Ku) < 1:
            raise ValueError("array dims must be positive")
        if self.D_stream < 1:
            raise ValueError("D_stream must be >= 1")
        for p in (self.P_A, self.P_B, self.P_C):
            if p not in (2, 4, 8, 16, 32):
                raise ValueError(f"unsupported precision {p}")

    # -- derived hardware facts ----------------------------------------------

    @property
    def dataflow(self) -> Dataflow:
        return Dataflow(
            spatial=SpatialUnrolling(self.Mu, self.Ku, self.Nu),
            temporal=TemporalUnrolling(),  # output stationary (Sec. 2.3)
        )

    @property
    def peak_macs_per_cycle(self) -> int:
        return self.Mu * self.Ku * self.Nu

    def peak_gops(self, freq_hz: float = 200e6) -> float:
        """Peak throughput; paper: 8x8x8 @ 200MHz = 204.8 GOPS."""
        return 2 * self.peak_macs_per_cycle * freq_hz / 1e9

    @property
    def a_tile_bits(self) -> int:
        return self.Mu * self.Ku * self.P_A

    @property
    def b_tile_bits(self) -> int:
        return self.Ku * self.Nu * self.P_B

    @property
    def c_tile_bits(self) -> int:
        return self.Mu * self.Nu * self.P_C

    @property
    def read_bw_bits(self) -> int:
        """Input SPM bandwidth (bits / cycle)."""
        return self.R_mem * self.P_word

    @property
    def write_bw_bits(self) -> int:
        """Output SPM bandwidth (bits / cycle)."""
        return self.W_mem * self.P_word

    @property
    def input_fetch_cycles(self) -> int:
        """Cycles to fetch one A' + one B' tile at full input bandwidth."""
        return max(1, -(-(self.a_tile_bits + self.b_tile_bits) // self.read_bw_bits))

    @property
    def output_write_cycles(self) -> int:
        """Cycles to drain one C' tile at full output bandwidth."""
        return max(1, -(-self.c_tile_bits // self.write_bw_bits))

    @property
    def spm_bytes(self) -> int:
        """Scratchpad capacity; case-study config = 270 KiB."""
        return self.N_bank * self.D_mem * self.P_word // 8

    # -- ablation helpers ------------------------------------------------------

    def with_mechanisms(
        self, *, cpl: bool, prefetch: bool, sma: bool, depth: int | None = None
    ) -> "OpenGeMMConfig":
        return dataclasses.replace(
            self,
            cfg_preload=cpl,
            input_prefetch=prefetch,
            strided_access=sma,
            D_stream=self.D_stream if depth is None else depth,
        )

    # -- TPU kernel specialization ---------------------------------------------

    def tpu_kernel_spec(
        self, shape: GemmShape | None = None, *, vmem_budget: int = VMEM_BUDGET_BYTES
    ) -> "TpuGemmSpec":
        """Scale the (Mu,Ku,Nu) design point to MXU-native block sizes.

        The paper's array is 8x8x8 because its SPM feeds 1024 b/cycle; the TPU
        MXU wants (8,128)-aligned tiles and VMEM-resident working sets.  We
        preserve the *ratios* of the design point but clamp each dim to
        [128, 512] and to the problem size, keeping
        A-tile + B-tile (double buffered) + C-accumulator within VMEM.
        """
        scale = 128 // min(self.Mu, self.Ku, self.Nu) if min(self.Mu, self.Ku, self.Nu) < 128 else 1
        tm, tk, tn = self.Mu * scale, self.Ku * scale, self.Nu * scale
        clamp = lambda v: max(128, min(512, v))
        tm, tk, tn = clamp(tm), clamp(tk), clamp(tn)
        if shape is not None:
            align = lambda v, a: max(a, -(-v // a) * a)
            tm = min(tm, align(shape.M, 8))
            tk = min(tk, align(shape.K, 128))
            tn = min(tn, align(shape.N, 128))
        # shrink TK first (streamed most often) until double-buffered footprint fits
        bytes_in = lambda: 2 * (tm * tk + tk * tn) * max(self.P_A, self.P_B) // 8
        acc_bytes = lambda: tm * tn * 4
        while bytes_in() + acc_bytes() > vmem_budget and tk > 128:
            tk //= 2
        while bytes_in() + acc_bytes() > vmem_budget and tn > 128:
            tn //= 2
        return TpuGemmSpec(
            tm=tm, tk=tk, tn=tn, depth=self.D_stream,
            int8=(self.P_A == 8 and self.P_B == 8 and self.P_C == 32),
        )


@dataclasses.dataclass(frozen=True)
class TpuGemmSpec:
    """Pallas specialization of a design point: BlockSpec tile sizes."""

    tm: int
    tk: int
    tn: int
    depth: int = 2          # pipeline buffer depth (D_stream analogue)
    int8: bool = True

    def __post_init__(self) -> None:
        # MXU alignment: lanes = 128, sublanes = 8.
        if self.tn % MXU_LANES or self.tk % MXU_LANES:
            raise ValueError(f"tk/tn must be multiples of {MXU_LANES}: {self}")
        if self.tm % MXU_SUBLANES:
            raise ValueError(f"tm must be a multiple of {MXU_SUBLANES}: {self}")

    def vmem_bytes(self, operand_bits: int = 8) -> int:
        """Buffered A/B blocks plus the f32/i32 accumulator tile.

        The buffering factor is `depth`: the pipelined kernel allocates
        `depth` ring-buffer slots per operand (gemm_pipelined.py), and the
        plain kernel's grid pipelining double-buffers (depth-2 lower bound).
        """
        bufs = max(2, self.depth)
        return (
            bufs * (self.tm * self.tk + self.tk * self.tn) * operand_bits // 8
            + self.tm * self.tn * 4
        )

    @property
    def grid_for(self):
        def grid(shape: GemmShape) -> Tuple[int, int, int]:
            return (-(-shape.M // self.tm), -(-shape.N // self.tn), -(-shape.K // self.tk))
        return grid


# The paper's case-study instance (Table 1, "Case study values").
CASE_STUDY = OpenGeMMConfig()
