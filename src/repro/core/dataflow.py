"""Dataflow representation for the OpenGeMM accelerator generator.

The paper (Sec. 2.1) represents a GeMM C[M,N] = A[M,K] @ B[K,N] as six
nested loops: three *spatial* unrollings (the (Mu, Nu) DotProd mesh, each
DotProd of length Ku) executed in a single clock cycle, and three *temporal*
unrollings (the tile schedule).  The output-stationary schedule keeps the
K-tile loop innermost so the int32 partial sum stays in the accumulator
register of each DotProd (Sec. 2.3).

This module is the pure-math layer: tiling arithmetic, loop orders and the
analytic spatial / temporal / overall utilization definitions used throughout
the simulator, the benchmarks and the TPU kernel generator.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence, Tuple

# ---------------------------------------------------------------------------
# Problem and tiling descriptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """A single GeMM problem C[M,N] = A[M,K] @ B[K,N]."""

    M: int
    K: int
    N: int

    def __post_init__(self) -> None:
        if min(self.M, self.K, self.N) < 1:
            raise ValueError(f"GeMM dims must be >= 1, got {self}")

    @property
    def macs(self) -> int:
        """Useful multiply-accumulates."""
        return self.M * self.K * self.N

    @property
    def flops(self) -> int:
        return 2 * self.macs

    def operand_bytes(self, p_a: int = 8, p_b: int = 8, p_c: int = 32) -> int:
        """Total operand traffic in bytes for one read of A,B and write of C."""
        return (
            self.M * self.K * p_a + self.K * self.N * p_b + self.M * self.N * p_c
        ) // 8


@dataclasses.dataclass(frozen=True)
class SpatialUnrolling:
    """The three innermost (spatial) loops: the (Mu, Nu) x Ku MAC array."""

    Mu: int = 8
    Ku: int = 8
    Nu: int = 8

    def __post_init__(self) -> None:
        if min(self.Mu, self.Ku, self.Nu) < 1:
            raise ValueError(f"array dims must be >= 1, got {self}")

    @property
    def macs_per_cycle(self) -> int:
        return self.Mu * self.Ku * self.Nu

    @property
    def peak_ops_per_cycle(self) -> int:
        # 1 MAC = 2 ops (mul + add): the paper's 8x8x8 @ 200MHz = 204.8 GOPS.
        return 2 * self.macs_per_cycle

    def tile_counts(self, g: GemmShape) -> Tuple[int, int, int]:
        """Temporal tile counts (m, k, n) = ceil(M/Mu), ceil(K/Ku), ceil(N/Nu)."""
        return (
            -(-g.M // self.Mu),
            -(-g.K // self.Ku),
            -(-g.N // self.Nu),
        )

    def padded_shape(self, g: GemmShape) -> GemmShape:
        m, k, n = self.tile_counts(g)
        return GemmShape(m * self.Mu, k * self.Ku, n * self.Nu)


# Canonical loop orders.  Following the paper, the innermost temporal loop is
# the K-tile loop (output stationary); weight stationary keeps the B' tile
# fixed by iterating M-tiles innermost.
OUTPUT_STATIONARY = ("m1", "n1", "k1")  # outer -> inner
WEIGHT_STATIONARY = ("k1", "n1", "m1")


@dataclasses.dataclass(frozen=True)
class TemporalUnrolling:
    """The three outermost (temporal) loops: the tile schedule."""

    order: Tuple[str, str, str] = OUTPUT_STATIONARY

    def __post_init__(self) -> None:
        if sorted(self.order) != ["k1", "m1", "n1"]:
            raise ValueError(f"order must be a permutation of (m1,n1,k1): {self.order}")

    @property
    def is_output_stationary(self) -> bool:
        return self.order[-1] == "k1"

    @property
    def is_weight_stationary(self) -> bool:
        return self.order[-1] == "m1"

    def iterate(
        self, counts: Tuple[int, int, int]
    ) -> Iterator[Tuple[int, int, int]]:
        """Yield (m1, k1, n1) tile indices in schedule order."""
        m, k, n = counts
        bounds = {"m1": m, "k1": k, "n1": n}
        o0, o1, o2 = self.order
        for i0 in range(bounds[o0]):
            for i1 in range(bounds[o1]):
                for i2 in range(bounds[o2]):
                    idx = {o0: i0, o1: i1, o2: i2}
                    yield idx["m1"], idx["k1"], idx["n1"]


@dataclasses.dataclass(frozen=True)
class Dataflow:
    """The full 6-loop nest of Fig. 2."""

    spatial: SpatialUnrolling = SpatialUnrolling()
    temporal: TemporalUnrolling = TemporalUnrolling()

    def compute_cycles(self, g: GemmShape) -> int:
        """Ideal MAC-array-busy cycles: one (Mu,Ku,Nu) tile per cycle."""
        m, k, n = self.spatial.tile_counts(g)
        return m * k * n

    def output_tiles(self, g: GemmShape) -> int:
        m, _, n = self.spatial.tile_counts(g)
        return m * n

    # -- utilization definitions (paper Table 2 footnotes) ------------------

    def spatial_utilization(self, g: GemmShape) -> float:
        """SU: useful MACs over MACs issued on the padded (tile-aligned) problem.

        SU < 1 whenever M, K or N is not a multiple of Mu, Ku, Nu: edge tiles
        run with part of the array idle.
        """
        return g.macs / self.spatial.padded_shape(g).macs

    def temporal_utilization(self, compute_cycles: int, total_cycles: int) -> float:
        """TU: fraction of cycles the MAC array is busy (not stalled/configuring)."""
        if total_cycles < compute_cycles:
            raise ValueError(
                f"total cycles {total_cycles} < compute cycles {compute_cycles}"
            )
        return compute_cycles / total_cycles if total_cycles else 1.0

    def overall_utilization(self, g: GemmShape, total_cycles: int) -> float:
        """OU = SU * TU: useful MACs over peak MACs in the elapsed time."""
        return g.macs / (total_cycles * self.spatial.macs_per_cycle)


def aggregate_utilization(
    df: Dataflow,
    shapes_cycles: Sequence[Tuple[GemmShape, int]],
) -> Tuple[float, float, float, int]:
    """MAC-weighted SU / TU / OU and total cycles over a workload list.

    This matches how the paper aggregates per-layer numbers into the per-model
    Table 2 entries: big layers dominate.
    """
    if not shapes_cycles:
        raise ValueError("empty workload")
    total_cycles = sum(c for _, c in shapes_cycles)
    total_macs = sum(g.macs for g, _ in shapes_cycles)
    padded_macs = sum(df.spatial.padded_shape(g).macs for g, _ in shapes_cycles)
    compute_cycles = sum(df.compute_cycles(g) for g, _ in shapes_cycles)
    su = total_macs / padded_macs
    tu = compute_cycles / total_cycles
    ou = total_macs / (total_cycles * df.spatial.macs_per_cycle)
    # OU == SU * TU by construction: macs/(cyc*peak) == (macs/padded) * (padded/ (cyc*peak))
    return su, tu, ou, total_cycles


def roofline_time_s(
    g: GemmShape,
    *,
    peak_flops: float,
    mem_bw: float,
    p_a: int = 8,
    p_b: int = 8,
    p_c: int = 32,
) -> Tuple[float, float]:
    """(compute_s, memory_s) roofline terms for one GeMM on an abstract device."""
    return g.flops / peak_flops, g.operand_bytes(p_a, p_b, p_c) / mem_bw


def arithmetic_intensity(g: GemmShape, p_a: int = 8, p_b: int = 8, p_c: int = 32) -> float:
    """FLOPs per byte of operand traffic."""
    return g.flops / g.operand_bytes(p_a, p_b, p_c)


def choose_loop_order(g: GemmShape, spatial: SpatialUnrolling) -> TemporalUnrolling:
    """Pick the stationarity that minimizes operand traffic (paper Sec. 2.3).

    Output-stationary saves traffic when the K extent (partial-sum reuse,
    wide P_C accumulators) dominates; this is essentially always true for
    im2col'd convolutions and transformer projections, matching the paper's
    fixed choice.  We keep the DSE hook for completeness.
    """
    m, k, n = spatial.tile_counts(g)
    # Partial-sum write traffic if NOT output stationary: every K-tile step
    # spills + reloads a 32b C' tile; if output stationary, C' written once.
    os_traffic = m * n * (k * 0 + 1)
    ws_traffic = m * n * k
    return TemporalUnrolling(OUTPUT_STATIONARY if os_traffic <= ws_traffic else WEIGHT_STATIONARY)
