"""TPU hardware constants shared across the roofline and tuning models.

Single source of truth (jax-free, so the analytic autotuner path never pays
the jax import): `launch/mesh.py` re-exports these for the mesh-level
roofline, `tuning/model.py` derives its cycle-model units from them.
Retarget the chip here and every consumer moves together.
"""

# TPU v5e-class, per chip.
PEAK_FLOPS_BF16 = 197e12      # bf16 FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~per-axis usable)
CLOCK_HZ = 940e6              # core clock used to convert cycles <-> seconds
