"""DNN workload extraction: models -> lists of GeMM calls (paper Sec. 4.3).

The paper benchmarks the energy/latency-dominant blocks of MobileNetV2,
ResNet18, ViT-B-16 and BERT-base: convolutions via im2col [21], attention,
MLP and FC layers.  This module reproduces those layer tables as
``(GemmShape, call_count)`` lists that the simulator consumes.

Batch sizes are chosen so the simulated total cycle counts land in the same
regime as the paper's Table 2 (the paper does not state its batch size; the
reported cycle counts imply batch ~512 for the CNNs/BERT-seq512 and ~1024 for
ViT — see EXPERIMENTS.md for the back-derivation).
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core.dataflow import GemmShape

GemmCalls = List[Tuple[GemmShape, int]]  # (shape, number of identical calls)


def _out(hw: int, k: int, s: int, p: int) -> int:
    return (hw + 2 * p - k) // s + 1


def conv_gemm(
    batch: int, hw: int, cin: int, cout: int, k: int, s: int = 1, p: int | None = None
) -> Tuple[GemmShape, int]:
    """Standard conv as one im2col GeMM per image: M = OH*OW, K = k*k*Cin, N = Cout.

    Per-image calls (rather than one batched GeMM) match the paper's
    back-derived cycle counts and its reported spatial utilizations: M stays
    at the per-image spatial extent, so late CNN stages (e.g. ResNet18's
    7x7 = 49-row layer4) pad M to Mu multiples and pull SU below 1.
    """
    p = (k // 2) if p is None else p
    o = _out(hw, k, s, p)
    return GemmShape(o * o, k * k * cin, cout), batch


def depthwise_gemm(
    batch: int, hw: int, c: int, k: int = 3, s: int = 1, group: int = 8
) -> Tuple[GemmShape, int]:
    """Depthwise conv as grouped-channel im2col GeMMs.

    The paper attributes MobileNetV2's low SU/TU to depthwise layers ("tick
    channels", small K).  Its exact depthwise-to-GeMM mapping is not
    specified; a per-channel (OH*OW, 9, 1) mapping would give SU ~= 7% per
    layer (far below the reported model-level 87.36%), so we model the
    streamer batching `group`=Nu channels per call: timing-wise
    (M=OH*OW, K=k*k, N=group), which keeps the small-K TU penalty the paper
    describes while matching the overall SU regime.  See EXPERIMENTS.md.

    The channel loop is folded into a single accelerator call per (image,
    layer) through the strided-AGU hardware loops (Sec. 3.4): timing- and
    padding-wise this is a GeMM with M = OH*OW * ceil(C/group) channel-group
    rows, K = k*k, N = group -- small K is what drags TU down, exactly the
    effect the paper describes.
    """
    o = _out(hw, k, s, k // 2)
    return GemmShape(o * o * (-(-c // group)), k * k, group), batch


def linear_gemm(batch: int, tokens: int, din: int, dout: int) -> Tuple[GemmShape, int]:
    """One GeMM per sequence/image: M = tokens."""
    return GemmShape(tokens, din, dout), batch


def attention_gemms(batch: int, heads: int, seq: int, head_dim: int) -> GemmCalls:
    """Per-(image/sequence, head) score and AV GeMMs."""
    return [
        (GemmShape(seq, head_dim, seq), batch * heads),   # Q @ K^T
        (GemmShape(seq, seq, head_dim), batch * heads),   # P @ V
    ]


def transformer_encoder_gemms(
    batch: int, layers: int, seq: int, d_model: int, heads: int, d_ff: int
) -> GemmCalls:
    calls: GemmCalls = []
    for _ in range(layers):
        calls.append(linear_gemm(batch, seq, d_model, 3 * d_model))  # fused QKV
        calls.extend(attention_gemms(batch, heads, seq, d_model // heads))
        calls.append(linear_gemm(batch, seq, d_model, d_model))      # output proj
        calls.append(linear_gemm(batch, seq, d_model, d_ff))         # FFN up
        calls.append(linear_gemm(batch, seq, d_ff, d_model))         # FFN down
    return calls


# ---------------------------------------------------------------------------
# The paper's four benchmark models
# ---------------------------------------------------------------------------

def resnet18(batch: int = 256) -> GemmCalls:
    """ResNet18 @ 224x224 (conv layers via im2col + final FC)."""
    calls: GemmCalls = [conv_gemm(batch, 224, 3, 64, 7, s=2, p=3)]
    # (hw_in, cin, cout, stride, blocks)
    stages = [(56, 64, 64, 1, 2), (56, 64, 128, 2, 2), (28, 128, 256, 2, 2), (14, 256, 512, 2, 2)]
    for hw, cin, cout, s, blocks in stages:
        for b in range(blocks):
            s_b = s if b == 0 else 1
            cin_b = cin if b == 0 else cout
            hw_b = hw if b == 0 else hw // s
            calls.append(conv_gemm(batch, hw_b, cin_b, cout, 3, s=s_b))
            calls.append(conv_gemm(batch, hw_b // s_b, cout, cout, 3))
            if b == 0 and (s != 1 or cin != cout):
                calls.append(conv_gemm(batch, hw_b, cin_b, cout, 1, s=s_b, p=0))
    calls.append(linear_gemm(batch, 1, 512, 1000))
    return calls


# MobileNetV2 inverted-residual stage table: (expansion t, c_out, repeats, stride)
_MBV2_STAGES = [
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
    (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
]


def mobilenet_v2(batch: int = 512) -> GemmCalls:
    calls: GemmCalls = [conv_gemm(batch, 224, 3, 32, 3, s=2)]
    hw, cin = 112, 32
    for t, cout, n, s in _MBV2_STAGES:
        for i in range(n):
            stride = s if i == 0 else 1
            hidden = cin * t
            if t != 1:
                calls.append(conv_gemm(batch, hw, cin, hidden, 1, p=0))  # expand
            calls.append(depthwise_gemm(batch, hw, hidden, 3, s=stride))
            hw_out = hw // stride
            calls.append(conv_gemm(batch, hw_out, hidden, cout, 1, p=0))  # project
            hw, cin = hw_out, cout
    calls.append(conv_gemm(batch, 7, 320, 1280, 1, p=0))
    calls.append(linear_gemm(batch, 1, 1280, 1000))
    return calls


def vit_b_16(batch: int = 512) -> GemmCalls:
    """ViT-B/16 @ 224x224: 196 patches + cls = 197 tokens (odd M -> SU < 1)."""
    seq, d, layers, heads, d_ff = 197, 768, 12, 12, 3072
    calls: GemmCalls = [linear_gemm(batch, 196, 16 * 16 * 3, d)]  # patch embed
    calls.extend(transformer_encoder_gemms(batch, layers, seq, d, heads, d_ff))
    calls.append(linear_gemm(batch, 1, d, 1000))  # classification head (cls token)
    return calls


def bert_base(batch: int = 512, seq: int = 512) -> GemmCalls:
    d, layers, heads, d_ff = 768, 12, 12, 3072
    calls = transformer_encoder_gemms(batch, layers, seq, d, heads, d_ff)
    calls.append(linear_gemm(batch, 1, d, d))  # pooler (cls token)
    return calls


TABLE2_MODELS = {
    "MobileNetV2": mobilenet_v2,
    "ResNet18": resnet18,
    "ViT-B-16": vit_b_16,
    "BERT-Base": bert_base,
}

# Paper Table 2 reference values: (SU %, TU %, OU %, cycles).
TABLE2_PAPER = {
    "MobileNetV2": (87.36, 93.74, 81.89, 3.33e8),
    "ResNet18": (96.01, 99.72, 95.74, 9.29e8),
    "ViT-B-16": (98.41, 99.75, 98.16, 1.79e10),
    "BERT-Base": (99.54, 99.80, 99.34, 4.93e10),
}


def total_macs(calls: GemmCalls) -> int:
    return sum(g.macs * c for g, c in calls)
