"""Gemmini baseline cycle model for the Fig. 7 comparison.

The paper compares OpenGeMM's area-normalized throughput (GOPS/mm^2) against
Gemmini [12] in output-stationary and weight-stationary modes, using silicon
measurements from [32] (avg. temporal utilization ~6.25% on matrices from
(8,8,8) to (128,128,128), dominated by memory stalls and RoCC command
overhead).

We model Gemmini's published 16x16 systolic array at 1 GHz / 1.03 mm^2 in
22 nm, with the first-order timing of its software-tiled execution:
  * per-call RoCC configuration instruction sequence,
  * mvin/mvout DMA transfers issued row-by-row through the L2 with a fixed
    latency per command and limited bandwidth, not overlapped with compute
    in the baseline loop,
  * compute: one (16,16,16) tile per `dim` cycles (systolic pipeline),
    plus array fill/drain per tile group.

The two free constants (`dma_latency`, `cmd_overhead`) are calibrated so the
model lands on the measured ~6% average utilization of [32]; see
benchmarks/fig7_gemmini.py.  This is a model of *another group's* silicon, so
we target the paper's reported speedup band (3.58x-16.40x), not exact cycle
parity.
"""

from __future__ import annotations

import dataclasses

from repro.core.dataflow import GemmShape


@dataclasses.dataclass(frozen=True)
class GemminiConfig:
    dim: int = 16                 # systolic array dimension (16x16 PEs)
    freq_hz: float = 1e9
    area_mm2: float = 1.03
    input_bits: int = 8
    acc_bits: int = 32
    dma_latency: int = 50         # cycles per DMA command (row granularity)
    dma_bw_bytes: int = 8         # sustained bytes/cycle through the SoC bus
    cmd_overhead: int = 300       # RoCC config instruction sequence per call
    # Per-call software cost of the gemmini tiled_matmul C routine on the
    # Rocket host (loop-bound computation, fences, flushes) — dominant at
    # small sizes in the silicon measurements of [32].  Calibrated so the
    # area-normalized speedup band matches Fig. 7 (3.58x-16.40x).
    software_overhead: int = 28000
    weight_stationary: bool = True

    @property
    def peak_macs_per_cycle(self) -> int:
        return self.dim * self.dim

    @property
    def peak_gops(self) -> float:
        return 2 * self.peak_macs_per_cycle * self.freq_hz / 1e9


class GemminiModel:
    def __init__(self, cfg: GemminiConfig | None = None):
        self.cfg = cfg or GemminiConfig()

    def _tile_counts(self, g: GemmShape):
        d = self.cfg.dim
        return -(-g.M // d), -(-g.K // d), -(-g.N // d)

    def _mv_cycles(self, rows: int, row_bytes: int) -> int:
        """DMA move of a tile issued row-by-row (Gemmini mvin granularity)."""
        c = self.cfg
        return rows * (c.dma_latency + -(-row_bytes // c.dma_bw_bytes))

    def cycles(self, g: GemmShape) -> int:
        c = self.cfg
        m, k, n = self._tile_counts(g)
        d = c.dim
        in_bytes = d * c.input_bits // 8       # one tile row, int8
        out_bytes = d * c.acc_bits // 8        # one result row, int32

        mvin_a = self._mv_cycles(min(g.M, d), in_bytes)   # per A tile
        mvin_b = self._mv_cycles(min(g.K, d), in_bytes)   # per B tile
        mvout_c = self._mv_cycles(min(g.M, d), out_bytes)  # per C tile

        # Tile compute: systolic pipeline, `dim` cycles per tile plus fill.
        tile_compute = d
        fill = 2 * d

        if c.weight_stationary:
            # Preload each B tile once; stream A tiles against it; partial sums
            # accumulate in the accumulator SRAM; C moved out once per (m,n).
            loads = m * k * mvin_a + k * n * (mvin_b + d)
            compute = m * k * n * tile_compute + m * n * fill
            stores = m * n * mvout_c
        else:
            # Output stationary: C tile resident; A and B tiles streamed per
            # k step (B re-fetched per (m,n) group).
            loads = m * k * n * (mvin_a + mvin_b) // max(1, min(m, n))  # A row reuse
            loads = m * k * mvin_a + m * k * n * mvin_b // max(1, m)
            compute = m * k * n * tile_compute + m * n * fill
            stores = m * n * mvout_c
        return c.software_overhead + c.cmd_overhead + loads + compute + stores

    def hardware_cycles(self, g: GemmShape) -> int:
        """Cycles between accelerator start and stop (excl. host software)."""
        return self.cycles(g) - self.cfg.software_overhead

    def temporal_utilization(self, g: GemmShape) -> float:
        """Hardware-only TU (the counter-based measure of [32])."""
        m, k, n = self._tile_counts(g)
        ideal = m * k * n * self.cfg.dim
        return ideal / self.hardware_cycles(g)

    def gops(self, g: GemmShape) -> float:
        t = self.cycles(g) / self.cfg.freq_hz
        return 2 * g.macs / t / 1e9

    def gops_per_mm2(self, g: GemmShape) -> float:
        return self.gops(g) / self.cfg.area_mm2
