"""Symmetric int8 quantization kernels (the OpenGeMM deployment precision).

Per-row absmax quantization: x (M, K) float -> (q int8, scale f32 (M, 1)).
Tiled over M so arbitrarily tall activations stream through VMEM.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def quantize_rows(
    x: jax.Array, *, block_m: int = 256, interpret: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization; rows must divide into block_m."""
    M, K = x.shape
    bm = min(block_m, M)
    assert M % bm == 0, (M, bm)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, K), jnp.int8),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s
