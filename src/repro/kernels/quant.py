"""Symmetric int8 quantization kernels (the OpenGeMM deployment precision).

Per-row absmax quantization: x (M, K) float -> (q int8, scale f32 (M, 1)).
Tiled over M so arbitrarily tall activations stream through VMEM; ragged M
is padded to the tile grid and sliced back (the padding rows quantize to
zeros and never leave this module).

`make_w8a8_gemm` composes this with the fused dequant GeMM into the full
w8a8 deployment kernel — float activations in, f32 out, weights
int8-resident — registered as the "w8a8" variant in kernels/registry.py.
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.generator import TpuGemmSpec


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def quantize_rows(
    x: jax.Array, *, block_m: int = 256, interpret: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization; any M (ragged rows are padded to
    the block grid and the outputs sliced back)."""
    M, K = x.shape
    bm = min(block_m, M)
    pad = (-M) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    Mp = M + pad
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(Mp // bm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, K), jnp.int8),
            jax.ShapeDtypeStruct((Mp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    if pad:
        q, s = q[:M], s[:M]
    return q, s


def make_w8a8_gemm(spec: TpuGemmSpec, *, interpret: bool = False) -> Callable:
    """Generate the int8-resident-weight deployment GeMM for one design point.

    gemm(a, b_q, sb) with a (M, K) float, b_q (K, N) int8, sb (1, N) f32
    per-column weight scales -> (M, N) f32.  Activations are row-quantized
    by the Pallas quantization kernel above, then the fused dequant GeMM
    (kernels/gemm.py) applies both scale sets on write-back.  Operands must
    be pre-padded to the tile grid (ops.py pads, as for every variant).
    """
    from repro.kernels.gemm import make_dequant_gemm

    dequant = make_dequant_gemm(spec, interpret=interpret)
    quant = functools.partial(
        quantize_rows, block_m=spec.tm, interpret=interpret)

    def gemm(a: jax.Array, b_q: jax.Array, sb: jax.Array) -> jax.Array:
        a_q, sa = quant(a)
        return dequant(a_q, b_q, sa, sb)

    return gemm
