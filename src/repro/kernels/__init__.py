"""TPU hot-spot kernels for the OpenGeMM framework.

  gemm            output-stationary tiled GeMM (the paper's core, on MXU)
  gemm_pipelined  explicit depth-D ring-buffer variant (D_stream knob)
  quant           int8 row quantization
  ops             jit'd public wrappers + backend dispatch
  ref             pure-jnp oracles
"""

from repro.kernels.ops import (
    gemm,
    gemm_int8_dequant,
    linear,
    quantize,
    set_default_backend,
    get_default_backend,
)

__all__ = [
    "gemm",
    "gemm_int8_dequant",
    "linear",
    "quantize",
    "set_default_backend",
    "get_default_backend",
]
