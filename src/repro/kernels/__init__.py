"""TPU hot-spot kernels for the OpenGeMM framework.

  gemm            output-stationary tiled GeMM (the paper's core, on MXU)
  gemm_pipelined  explicit depth-D ring-buffer variant (D_stream knob)
  quant           int8 row quantization + the fused "w8a8" deployment GeMM
  flash_decode    paged decode attention: block-table walking + split-K +
                  in-kernel int8-KV dequant (serving's hot per-token op)
  ops             jit'd public wrappers + backend dispatch (incl. the
                  precision-mode hook consumed from repro.quant)
  registry        named kernel factories (backend -> Pallas specialization)
  ref             pure-jnp oracles

`tuned_gemm` dispatches through the tile autotuner (repro.tuning): the best
known (TM, TK, TN) for the problem, searched once and cached.
"""

from repro.kernels.flash_decode import (
    FlashDecodeSpec,
    decode_backend,
    flash_decode_attention,
    get_decode_backend,
    get_decode_spec,
    paged_decode_attention,
    ref_paged_decode,
    set_decode_backend,
    set_decode_spec,
)
from repro.kernels.ops import (
    gemm,
    gemm_int8_dequant,
    gemm_w8a8,
    linear,
    quantize,
    set_default_backend,
    get_default_backend,
)
from repro.kernels.registry import make_kernel, register_kernel, registered_kernels


def tuned_gemm(a, b, **kwargs):
    """C = A @ B with the autotuned tile spec (see repro.tuning).

    Lazy wrapper: the tuning package (and its cache I/O) loads on first use,
    so plain `gemm` callers never pay for it.
    """
    from repro.tuning import tuned_gemm as _tuned_gemm

    return _tuned_gemm(a, b, **kwargs)


__all__ = [
    "gemm",
    "tuned_gemm",
    "gemm_int8_dequant",
    "gemm_w8a8",
    "linear",
    "quantize",
    "set_default_backend",
    "get_default_backend",
    "make_kernel",
    "register_kernel",
    "registered_kernels",
    # paged flash-decode (kernels/flash_decode.py)
    "FlashDecodeSpec",
    "flash_decode_attention",
    "paged_decode_attention",
    "ref_paged_decode",
    "decode_backend",
    "set_decode_backend",
    "get_decode_backend",
    "set_decode_spec",
    "get_decode_spec",
]
