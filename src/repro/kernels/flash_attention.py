"""Fused flash attention (Pallas TPU): the framework's second hot-spot kernel.

The roofline hillclimb (EXPERIMENTS.md §Perf, qwen3 iterations 3-4) showed
that with attention expressed as XLA ops, the f32 score/probability tensors
dominate per-device HBM traffic (~69% of a training step).  This kernel keeps
the (block_q, block_kv) score tile, the online-softmax statistics and the
output accumulator in VMEM — HBM traffic reduces to the q/k/v/o tensors, the
same transformation the paper applies at SPM scale with its output buffer.

Supports causal masking, sliding windows and GQA (kv-head indexed per
q-head).  Validated in interpret mode against the dense oracle
(tests/test_flash_attention.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, kv_steps: int, block_q: int, block_kv: int, scale: float,
    causal: bool, window: Optional[int], seq_kv: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_kv
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                                # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v_ref.dtype).astype(jnp.float32), v,
        preferred_element_type=jnp.float32,
    )

    @pl.when(ik == kv_steps - 1)
    def _flush():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                 # (B, Sq, Hq, D)
    k: jax.Array,                 # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    groups = Hq // Hkv
    scale = D ** -0.5

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    pad_q = (-Sq) % block_q
    pad_kv = (-Skv) % block_kv

    qt = jnp.moveaxis(q, 2, 1)                            # (B, Hq, Sq, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    nq = qt.shape[2] // block_q
    nk = kt.shape[2] // block_kv

    kernel = functools.partial(
        _flash_kernel, kv_steps=nk, block_q=block_q, block_kv=block_kv,
        scale=scale, causal=causal, window=window, seq_kv=Skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, g=groups: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, g=groups: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    if pad_q:
        out = out[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)                        # (B, Sq, Hq, D)
