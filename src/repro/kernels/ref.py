"""Pure-jnp oracles for every kernel in repro.kernels.

These are the ground truth the Pallas kernels are validated against
(tests/test_kernels.py sweeps shapes and dtypes with assert_allclose).
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with the OpenGeMM accumulation rule:

    int8 x int8 accumulates in int32 (paper P_A=P_B=8, P_C=32); float paths
    keep their input dtype on the MXU and accumulate in float32 (never
    upcast the operands — bf16 x bf16 -> f32 is the native mode and half the
    operand traffic).
    """
    if a.dtype == jnp.int8 and b.dtype == jnp.int8:
        return jax.lax.dot(a, b, preferred_element_type=jnp.int32)
    if a.dtype != b.dtype:
        b = b.astype(a.dtype)
    return jax.lax.dot(a, b, preferred_element_type=jnp.float32)


def gemm_dequant_ref(
    a: jax.Array, b: jax.Array, scale_a: jax.Array, scale_b: jax.Array
) -> jax.Array:
    """int8 GeMM with fused per-tensor/per-channel dequantization.

    scale_a: scalar or (M, 1) row scales; scale_b: scalar or (1, N) column
    scales.  Output float32 = (A @ B) * scale_a * scale_b.
    """
    acc = jax.lax.dot(a, b, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * scale_a * scale_b


def quantize_ref(x: jax.Array, axis: int = -1) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-channel int8 quantization along `axis`.

    Returns (q, scale) with x ~= q * scale; scale shaped like x with `axis`
    reduced to 1.
    """
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def gemm_bias_act_ref(
    a: jax.Array, b: jax.Array, bias: jax.Array | None = None, act: str = "none"
) -> jax.Array:
    """GeMM with fused bias-add and activation epilogue (float path)."""
    c = gemm_ref(a, b)
    if bias is not None:
        c = c + bias
    if act == "relu":
        c = jnp.maximum(c, 0)
    elif act == "gelu":
        c = jax.nn.gelu(c)
    elif act == "silu":
        c = jax.nn.silu(c)
    elif act != "none":
        raise ValueError(f"unknown act {act!r}")
    return c
