"""OpenGeMM Pallas kernel: output-stationary tiled GeMM for TPU.

TPU-native re-instantiation of the paper's GeMM core (Sec. 2):

  * the (Mu, Ku, Nu) 3D MAC array becomes an MXU-aligned (TM, TK, TN)
    BlockSpec tile — the *generator* (`make_gemm`) specializes the kernel per
    `TpuGemmSpec`, exactly as the Chisel generator elaborates per config;
  * the output-stationary dataflow (paper Sec. 2.3) becomes a float32/int32
    accumulator held in VMEM scratch across the innermost K grid dimension —
    partial sums never travel to HBM, only the (narrow) A/B operands stream;
  * input pre-fetch / output buffering (paper Sec. 3.3) is provided by
    Pallas' grid pipelining, which double-buffers the A/B blocks
    (HBM->VMEM DMA for block i+1 overlaps compute on block i).  The
    configurable-depth variant lives in gemm_pipelined.py.

Grid layout: (M/TM, N/TN, K/TK) with K innermost ("arbitrary" semantics on
the K axis because of the accumulator carry; M and N are parallel).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.generator import TpuGemmSpec


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int, out_dtype):
    """One (TM, TN) output tile; accumulates over the K grid dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    # int8 x int8 -> int32 on the MXU; float paths accumulate in f32.
    acc_ref[...] += jax.lax.dot(
        a, b, preferred_element_type=acc_ref.dtype,
        precision=jax.lax.Precision.DEFAULT,
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _dequant_gemm_kernel(
    a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref, *, k_steps: int, out_dtype
):
    """int8 GeMM with fused per-row/per-column scale dequant on write-back."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        scaled = acc_ref[...].astype(jnp.float32) * sa_ref[...] * sb_ref[...]
        o_ref[...] = scaled.astype(out_dtype)


def make_gemm(spec: TpuGemmSpec, *, interpret: bool = False) -> Callable:
    """Generate a GeMM for one design point (the TPU 'hardware generator').

    Returns gemm(a, b) for a:(M, K), b:(K, N) with M % TM == K % TK ==
    N % TN == 0 (ops.py pads ragged problems — the TPU analogue of the
    paper's spatial-utilization padding).
    """

    def gemm(a: jax.Array, b: jax.Array) -> jax.Array:
        M, K = a.shape
        K2, N = b.shape
        assert K == K2, (a.shape, b.shape)
        assert M % spec.tm == 0 and K % spec.tk == 0 and N % spec.tn == 0, (
            f"unpadded problem ({M},{K},{N}) for tile ({spec.tm},{spec.tk},{spec.tn})"
        )
        int_path = a.dtype == jnp.int8 and b.dtype == jnp.int8
        acc_dtype = jnp.int32 if int_path else jnp.float32
        out_dtype = jnp.int32 if int_path else acc_dtype
        k_steps = K // spec.tk
        grid = (M // spec.tm, N // spec.tn, k_steps)

        kernel = functools.partial(
            _gemm_kernel, k_steps=k_steps, out_dtype=out_dtype
        )
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((spec.tm, spec.tk), lambda i, j, k: (i, k)),
                pl.BlockSpec((spec.tk, spec.tn), lambda i, j, k: (k, j)),
            ],
            out_specs=pl.BlockSpec((spec.tm, spec.tn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
            scratch_shapes=[pltpu.VMEM((spec.tm, spec.tn), acc_dtype)],
            interpret=interpret,
        )(a, b)

    return gemm


def make_dequant_gemm(spec: TpuGemmSpec, *, interpret: bool = False) -> Callable:
    """int8 GeMM with fused dequant epilogue: C_f32 = (A@B) * sa * sb.

    sa: (M, 1) float32 row scales, sb: (1, N) float32 column scales — the
    paper's P_C=32 write-back path extended with the int8 deployment scales.
    """

    def gemm(a, b, sa, sb):
        M, K = a.shape
        _, N = b.shape
        assert M % spec.tm == 0 and K % spec.tk == 0 and N % spec.tn == 0
        assert sa.shape == (M, 1) and sb.shape == (1, N), (sa.shape, sb.shape)
        k_steps = K // spec.tk
        grid = (M // spec.tm, N // spec.tn, k_steps)

        kernel = functools.partial(
            _dequant_gemm_kernel, k_steps=k_steps, out_dtype=jnp.float32
        )
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((spec.tm, spec.tk), lambda i, j, k: (i, k)),
                pl.BlockSpec((spec.tk, spec.tn), lambda i, j, k: (k, j)),
                pl.BlockSpec((spec.tm, 1), lambda i, j, k: (i, 0)),
                pl.BlockSpec((1, spec.tn), lambda i, j, k: (0, j)),
            ],
            out_specs=pl.BlockSpec((spec.tm, spec.tn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
            scratch_shapes=[pltpu.VMEM((spec.tm, spec.tn), jnp.int32)],
            interpret=interpret,
        )(a, b, sa, sb)

    return gemm
