"""Depth-D pre-fetch GeMM: the paper's D_stream knob, TPU-native.

The baseline kernel (gemm.py) gets depth-2 input pre-fetching for free from
Pallas grid pipelining.  The paper's Sec. 3.3 makes the buffer depth a
design-time parameter (D_stream = 2/3/4 in Fig. 5); this kernel reproduces
that degree of freedom with an explicit VMEM ring buffer of `depth` slots per
operand, filled by manual HBM->VMEM async copies that run ahead of compute —
the "dynamic producer-consumer mechanism" of the paper, with the DMA engine
as producer and the MXU as consumer.

Grid: (M/TM, N/TN); the K-tile loop is an in-kernel fori_loop so the ring
buffer and the output-stationary accumulator both persist across it.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.generator import TpuGemmSpec


def _pipelined_kernel(
    a_hbm, b_hbm, o_ref, a_buf, b_buf, acc_ref, a_sem, b_sem,
    *, k_steps: int, depth: int, tm: int, tk: int, tn: int, out_dtype,
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    def a_copy(slot, k):
        return pltpu.make_async_copy(
            a_hbm.at[pl.ds(i * tm, tm), pl.ds(k * tk, tk)],
            a_buf.at[slot],
            a_sem.at[slot],
        )

    def b_copy(slot, k):
        return pltpu.make_async_copy(
            b_hbm.at[pl.ds(k * tk, tk), pl.ds(j * tn, tn)],
            b_buf.at[slot],
            b_sem.at[slot],
        )

    # Warm-up: launch the first `depth` fetches (config pre-loading for the
    # streamers: they start before any compute).
    for d in range(depth):

        @pl.when(d < k_steps)
        def _start(d=d):
            a_copy(d, d).start()
            b_copy(d, d).start()

    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(k, _):
        slot = jax.lax.rem(k, depth)
        a_copy(slot, k).wait()
        b_copy(slot, k).wait()
        acc_ref[...] += jax.lax.dot(
            a_buf[slot], b_buf[slot], preferred_element_type=acc_ref.dtype
        )
        # Re-arm this slot for tile k+depth while the MXU keeps computing.
        nxt = k + depth

        @pl.when(nxt < k_steps)
        def _prefetch():
            a_copy(slot, nxt).start()
            b_copy(slot, nxt).start()

        return ()

    jax.lax.fori_loop(0, k_steps, body, ())
    o_ref[...] = acc_ref[...].astype(out_dtype)


def make_pipelined_gemm(
    spec: TpuGemmSpec, *, interpret: bool = False
) -> Callable:
    """Generate a depth-`spec.depth` explicitly-pipelined GeMM kernel."""
    depth = max(2, spec.depth)

    def gemm(a: jax.Array, b: jax.Array) -> jax.Array:
        M, K = a.shape
        K2, N = b.shape
        assert K == K2
        assert M % spec.tm == 0 and K % spec.tk == 0 and N % spec.tn == 0
        int_path = a.dtype == jnp.int8 and b.dtype == jnp.int8
        acc_dtype = jnp.int32 if int_path else jnp.float32
        k_steps = K // spec.tk
        kernel = functools.partial(
            _pipelined_kernel,
            k_steps=k_steps, depth=min(depth, k_steps) if k_steps else depth,
            tm=spec.tm, tk=spec.tk, tn=spec.tn, out_dtype=acc_dtype,
        )
        return pl.pallas_call(
            kernel,
            grid=(M // spec.tm, N // spec.tn),
            in_specs=[
                pl.BlockSpec(memory_space=pl.ANY),
                pl.BlockSpec(memory_space=pl.ANY),
            ],
            out_specs=pl.BlockSpec((spec.tm, spec.tn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((M, N), acc_dtype),
            scratch_shapes=[
                pltpu.VMEM((depth, spec.tm, spec.tk), a.dtype),
                pltpu.VMEM((depth, spec.tk, spec.tn), b.dtype),
                pltpu.VMEM((spec.tm, spec.tn), acc_dtype),
                pltpu.SemaphoreType.DMA((depth,)),
                pltpu.SemaphoreType.DMA((depth,)),
            ],
            interpret=interpret,
        )(a, b)

    return gemm
