"""Public GeMM ops: jit'd wrappers with padding, backend dispatch and the
int8 OpenGeMM deployment path.

Every dense matmul in repro.models routes through `gemm`/`linear`, so the
paper's technique is a first-class feature of the framework, not a demo:

  backend="pallas"     TPU kernel (gemm.py) — production path
  backend="pipelined"  TPU kernel with explicit depth-D ring buffer
  backend="interpret"  Pallas interpret mode — CPU-correctness path (tests)
  backend="xla"        jnp.einsum — dry-run / baseline path
  backend="auto"       pallas on TPU, xla elsewhere

Kernel variants resolve through `kernels/registry.py`; tile specs resolve,
in order, from the explicit `spec=` argument, the autotuner (when
`repro.tuning` is enabled — see `tuning.enable()` / REPRO_AUTOTUNE=1), or
the config's fixed `tpu_kernel_spec` mapping.

Ragged problems are padded to the tile grid, the TPU analogue of the paper's
spatial-utilization padding: the padding fraction *is* (1 - SU).
"""

from __future__ import annotations

import sys
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dataflow import GemmShape
from repro.core.generator import CASE_STUDY, OpenGeMMConfig, TpuGemmSpec
from repro.kernels import ref
from repro.kernels.registry import make_kernel

_DEFAULT_BACKEND = "auto"


def set_default_backend(backend: str) -> None:
    """Process-wide default ('auto'|'pallas'|'pipelined'|'interpret'|'xla')."""
    global _DEFAULT_BACKEND
    if backend not in ("auto", "pallas", "pipelined", "interpret", "xla"):
        raise ValueError(backend)
    _DEFAULT_BACKEND = backend


def get_default_backend() -> str:
    return _DEFAULT_BACKEND


def _resolve(backend: Optional[str]) -> str:
    backend = backend or _DEFAULT_BACKEND
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def _pad2(x: jax.Array, m: int, n: int) -> jax.Array:
    pm, pn = (-x.shape[0]) % m, (-x.shape[1]) % n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _dispatch_spec(
    cfg: OpenGeMMConfig, shape: GemmShape, dtype, backend: str
) -> TpuGemmSpec:
    """Tile spec for a spec-less call: autotuned if tuning is enabled.

    An explicitly passed non-default `config` is designer intent — its
    `tpu_kernel_spec` mapping is honored verbatim and the tuner (whose
    cache is keyed against the default design point) stays out of the way.

    `repro.tuning` is only consulted if it is already imported (someone
    called `tuning.enable()`) or requested via REPRO_AUTOTUNE — a plain
    `gemm` call never pays the import, keeping the default path inert.
    """
    if cfg is not CASE_STUDY:
        return cfg.tpu_kernel_spec(shape)
    tuning = sys.modules.get("repro.tuning")
    if tuning is None:
        import os

        # Same truthiness rule as tuning.env_truthy: "0"/"false"/"" disable.
        if os.environ.get("REPRO_AUTOTUNE", "").strip().lower() not in (
            "", "0", "false", "no", "off"
        ):
            import repro.tuning as tuning
    if tuning is not None and tuning.is_enabled():
        return tuning.tuned_spec(shape, dtype, backend=backend)
    return cfg.tpu_kernel_spec(shape)


def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    spec: Optional[TpuGemmSpec] = None,
    config: Optional[OpenGeMMConfig] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """C = A @ B through the OpenGeMM kernel generator.

    a: (M, K), b: (K, N).  int8 inputs accumulate to int32, floats to f32.
    """
    backend = _resolve(backend)
    if backend == "xla":
        return ref.gemm_ref(a, b)
    M, K = a.shape
    _, N = b.shape
    cfg = config or CASE_STUDY
    spec = spec or _dispatch_spec(cfg, GemmShape(M, K, N), a.dtype, backend)
    ap, bp = _pad2(a, spec.tm, spec.tk), _pad2(b, spec.tk, spec.tn)
    interpret = backend == "interpret"
    kernel_name = "pipelined" if backend == "pipelined" else "pallas"
    out = make_kernel(kernel_name, spec, interpret=interpret)(ap, bp)
    return out[:M, :N]


def gemm_int8_dequant(
    a_q: jax.Array,
    b_q: jax.Array,
    scale_a: jax.Array,
    scale_b: jax.Array,
    *,
    spec: Optional[TpuGemmSpec] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """(A_q @ B_q) * sa * sb -> float32, fused in the kernel epilogue."""
    backend = _resolve(backend)
    if backend == "xla":
        return ref.gemm_dequant_ref(a_q, b_q, scale_a, scale_b)
    M, K = a_q.shape
    _, N = b_q.shape
    # Tuned separately from the plain int8 GeMM: the fused scale epilogue
    # changes the write-back cost, so "dequant" is its own tuning key.
    spec = spec or _dispatch_spec(CASE_STUDY, GemmShape(M, K, N), a_q.dtype, "dequant")
    ap, bp = _pad2(a_q, spec.tm, spec.tk), _pad2(b_q, spec.tk, spec.tn)
    sa = _pad2(scale_a, spec.tm, 1)
    sb = _pad2(scale_b, 1, spec.tn)
    k = make_kernel("dequant", spec, interpret=(backend == "interpret"))
    return k(ap, bp, sa, sb)[:M, :N]


def quantize(x: jax.Array, axis: int = -1) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-channel int8 quantization (jnp; kernels/quant.py for TPU)."""
    return ref.quantize_ref(x, axis=axis)


def gemm_w8a8(
    x: jax.Array,
    w_q: jax.Array,
    w_scale: jax.Array,
    *,
    act_scale: Optional[jax.Array] = None,
    spec: Optional[TpuGemmSpec] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """The int8-resident-weight GeMM: float x (M, K), int8 w_q (K, N) with
    f32 per-column scales -> f32 (M, N).

    Activations quantize per-row on the fly (dynamic), or with the static
    per-tensor `act_scale` when given (calibrated mode).  On TPU this is the
    fused "w8a8" registry kernel (row quant in VMEM + dequant epilogue); the
    xla path composes the jnp oracles.
    """
    backend = _resolve(backend)
    M, K = x.shape
    N = w_q.shape[-1]
    w_scale = w_scale.reshape(1, -1)
    if act_scale is not None:
        s = jnp.asarray(act_scale, jnp.float32).reshape(())
        xq = jnp.clip(
            jnp.round(x.astype(jnp.float32) / s), -127, 127
        ).astype(jnp.int8)
        sx = jnp.broadcast_to(s, (M, 1))
        if backend == "xla":
            return ref.gemm_dequant_ref(xq, w_q, sx, w_scale)
        return gemm_int8_dequant(xq, w_q, sx, w_scale, spec=spec, backend=backend)
    if backend == "xla":
        xq, sx = ref.quantize_ref(x, axis=-1)
        return ref.gemm_dequant_ref(xq, w_q, sx, w_scale)
    # dtype as the string "int8": the tuner cache key stringifies its dtype
    # argument, and warmup pre-tunes under "int8" (autotune_for_serving) —
    # passing the jnp.int8 class would silently miss every warmed entry.
    spec = spec or _dispatch_spec(
        CASE_STUDY, GemmShape(M, K, N), "int8", "w8a8")
    xp = _pad2(x, spec.tm, spec.tk)
    wp = _pad2(w_q, spec.tk, spec.tn)
    sp = _pad2(w_scale, 1, spec.tn)
    k = make_kernel("w8a8", spec, interpret=(backend == "interpret"))
    return k(xp, wp, sp)[:M, :N]


def _quant_mode():
    """The precision-mode module, if anyone imported it (sys.modules peek:
    a plain float `linear` call never pays for the quant package — the same
    inertness rule as the tuner hook in `_dispatch_spec`)."""
    return sys.modules.get("repro.quant.modes")


def linear(
    x: jax.Array,
    w,
    *,
    quant: Optional[str] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """y = x @ w for arbitrary-rank x (..., K) and w (K, N).

    `w` is a float matrix or an int8-resident `quant.params.QuantTensor`
    (pre-quantized weights + per-column scales: the serving deployment path —
    no per-call weight quantization).

    quant="int8" runs the OpenGeMM int8 deployment path on a float weight:
    activations row-quantized on the fly, weights column-quantized per call,
    and the kernel dequantizes on write-back.  quant=None defers to the
    active precision mode (repro.quant.modes — trace-time dispatch);
    quant="none" opts out of the mode and forces float (for numerically
    sensitive projections, e.g. the SSM dt/gate paths).
    """
    qmod = _quant_mode()
    if qmod is not None and qmod.capturing():
        qmod.capture(x, w)  # calibration tap (eager runs only; see calibrate)
    qp = sys.modules.get("repro.quant.params")
    if qp is not None and isinstance(w, qp.QuantTensor):
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        act = w.act_scale if (qmod is not None and qmod.is_calibrated()) else None
        out = gemm_w8a8(x2, w.q, w.scale, act_scale=act, backend=backend)
        return out.astype(x.dtype).reshape(*lead, w.q.shape[-1])
    if quant is None and qmod is not None:
        quant = qmod.default_quant()
    lead = x.shape[:-1]
    K = x.shape[-1]
    resolved = _resolve(backend)
    if quant in (None, "none") and resolved == "xla":
        # Keep the leading dims intact: flattening (B, S, d) -> (B*S, d)
        # merges differently-sharded axes and forces GSPMD to materialize
        # the full tensor (measured 16x redundant projection compute on the
        # 256-chip mesh — see EXPERIMENTS.md §Perf iteration 3).
        # Output directly in the model dtype (the MXU accumulates in f32
        # internally regardless); avoids materializing an f32 copy of every
        # projection output.
        return jnp.einsum(
            "...k,kn->...n", x, w.astype(x.dtype),
            preferred_element_type=x.dtype,
        )
    x2 = x.reshape(-1, K)
    if quant == "int8":
        xq, sx = quantize(x2, axis=-1)
        wq, sw = quantize(w, axis=0)
        out = gemm_int8_dequant(xq, wq, sx, sw.reshape(1, -1), backend=backend)
        out = out.astype(x.dtype)
    elif quant in (None, "none"):
        out = gemm(x2, w.astype(x2.dtype), backend=backend).astype(x.dtype)
    else:
        raise ValueError(f"unknown quant mode {quant!r}")
    return out.reshape(*lead, w.shape[-1])
