"""Paged flash-decode (Pallas TPU): decode attention straight off the KV pool.

The serving decode path previously materialized every slot's cache view with
``gather_kv`` (a (B, max_blocks * block_size, H, D) gather — the *full* table
extent, mostly null blocks at short lengths) and ran a whole-cache einsum.
This kernel instead walks the per-slot block tables *inside* the grid — the
paper's programmable strided memory access (Sec 3.3) applied to decode: the
block table is the stride program, and each grid step DMAs exactly one pool
block.  HBM traffic per step drops from the table extent to the lived-in
blocks, and nothing is ever materialized per slot.

Shape story (one grid step = one pool block for one (slot, kv-head, split)):

  q            (B, Sq, Hq, D)     -> packed (B, Hkv, G * Sq, D) rows
  k/v pool     (num_blocks, block_size, Hkv, D), addressed via the
               scalar-prefetched block table: block index
               ``tables[b, split * cols_per_split + j]``
  outputs      per-split partial (acc, m, l) — online-softmax state — reduced
               in a cheap second stage (split-K over the sequence dimension,
               so long contexts parallelize across the grid instead of
               serializing one slot's whole table on one core).

GQA is handled by packing the G query heads of a kv head (times the Sq query
positions — Sq > 1 for speculative verify and chunked prefill) into the row
axis of a single (rows, block_size) score tile, so KV is fetched once per
kv head, never repeated.  Per-slot length masking (``kpos <= index[b] + t``)
and sliding windows are applied in-kernel.

int8 KV residency: when the pool carries per-(block, position, kv-head)
scales (``PagedKVCache.k_scale``/``v_scale``, see serving/kv_cache.py), the
kernel fetches int8 K/V blocks and dequantizes them in registers inside the
inner loop — no dequantized copy of the cache ever exists, so the ~4x
byte saving is real end to end.

Also here:

  * ``ref_paged_decode`` — the bounded pure-JAX fallback: a
    ``lax.while_loop`` over block-table column chunks with an online-softmax
    carry, iterating only to the max active length across slots (not the
    table extent).  This is the default decode path on non-TPU hosts.
  * ``paged_decode_attention`` — the backend dispatcher used by
    models/attention.py, with ``set_decode_backend`` / ``decode_backend``
    mirroring kernels/ops.py's backend switch, and a trace-time
    ``set_decode_spec`` hook the serving engine binds tuned
    ``FlashDecodeSpec`` winners through (repro.tuning.decode).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.serving.kv_cache import NULL_BLOCK

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# design point
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlashDecodeSpec:
    """One decode-kernel design point (the analogue of TpuGemmSpec).

    num_splits     split-K factor over the block-table columns: each split
                   produces partial (acc, m, l) reduced in stage 2.  1 = no
                   split (short contexts); long tables want the sequence
                   walk spread across the grid.
    cols_per_iter  table columns the *fallback* path gathers per
                   ``while_loop`` iteration — its chunk/overshoot trade-off
                   (a bigger chunk amortizes iteration overhead but gathers
                   past the needed length by up to a chunk).
    """

    num_splits: int = 1
    cols_per_iter: int = 8

    def __post_init__(self):
        if self.num_splits < 1:
            raise ValueError(f"num_splits must be >= 1, got {self.num_splits}")
        if self.cols_per_iter < 1:
            raise ValueError(
                f"cols_per_iter must be >= 1, got {self.cols_per_iter}")

    def to_json(self) -> dict:
        return {"kind": "flash_decode", "num_splits": self.num_splits,
                "cols_per_iter": self.cols_per_iter}

    @classmethod
    def from_json(cls, d: dict) -> "FlashDecodeSpec":
        return cls(num_splits=int(d["num_splits"]),
                   cols_per_iter=int(d["cols_per_iter"]))


# ---------------------------------------------------------------------------
# the Pallas kernel
# ---------------------------------------------------------------------------

def _decode_kernel(
    bt_ref, idx_ref,                       # scalar-prefetch: tables, index
    q_ref, k_ref, v_ref, *rest,
    cols_per_split: int, block_size: int, sq: int, scale: float,
    window: Optional[int], seq_cap: int, quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, acc_out, m_out, l_out, acc_ref, m_ref, l_ref = rest
    else:
        acc_out, m_out, l_out, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    s = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (rows, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (block_size, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0, :, 0][:, None]
        v = v * vs_ref[0, :, 0][:, None]
    scores = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32)

    # Row r packs (group g, query offset t) = (r // sq, r % sq); padding rows
    # past G * Sq carry zero queries and are sliced off after the combine.
    col = s * cols_per_split + j
    t = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0) % sq
    qpos = idx_ref[b] + t
    kpos = col * block_size + jax.lax.broadcasted_iota(
        jnp.int32, scores.shape, 1)
    mask = (kpos <= qpos) & (kpos < seq_cap)
    if window is not None:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[...]                                     # (rows, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.exp(scores - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(j == cols_per_split - 1)
    def _flush():
        acc_out[0, 0, 0] = acc_ref[...]
        m_out[0, 0, 0] = m_ref[...][:, 0]
        l_out[0, 0, 0] = l_ref[...][:, 0]


def _combine_splits(acc, m, l):
    """Stage 2 of split-K: merge per-split online-softmax partials.

    acc (B, H, S, rows, D); m, l (B, H, S, rows).  A fully-masked split
    carries (acc=0, m=NEG_INF, l=0): its alpha underflows to zero against any
    live split, and when *every* split is masked the l floor keeps the (all
    padding rows / inactive slot) output finite — garbage, but finite, and
    hidden by the caller exactly like the gather path's null-block rows.
    """
    m_g = jnp.max(m, axis=2)                               # (B, H, rows)
    alpha = jnp.exp(m - m_g[:, :, None])                   # (B, H, S, rows)
    l_g = jnp.sum(l * alpha, axis=2)
    acc_g = jnp.sum(acc * alpha[..., None], axis=2)
    return acc_g / jnp.maximum(l_g, 1e-30)[..., None]      # (B, H, rows, D)


def _pack_q(q, groups: int, Hkv: int):
    """(B, Sq, Hq, D) -> (B, Hkv, rows_padded, D) with rows = G * Sq padded
    to the f32 sublane multiple; row r = g * Sq + t."""
    B, Sq, Hq, D = q.shape
    rows = groups * Sq
    qr = q.reshape(B, Sq, Hkv, groups, D).transpose(0, 2, 3, 1, 4)
    qr = qr.reshape(B, Hkv, rows, D)
    rows_p = -(-rows // 8) * 8
    if rows_p != rows:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, rows_p - rows), (0, 0)))
    return qr, rows, rows_p


def _unpack_out(out, B: int, Sq: int, Hq: int, D: int, groups: int, rows: int):
    """(B, Hkv, rows_padded, D) -> (B, Sq, Hq, D)."""
    Hkv = Hq // groups
    out = out[:, :, :rows].reshape(B, Hkv, groups, Sq, D)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)


def flash_decode_attention(
    q: jax.Array,                  # (B, Sq, Hq, D)
    cache,                         # PagedKVCache (float or int8 + scales)
    block_tables: jax.Array,       # (B, max_blocks) int32 into the pool
    index,                         # scalar or (B,): first query position
    *,
    window: Optional[int] = None,
    spec: Optional[FlashDecodeSpec] = None,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention over the paged pool via the Pallas kernel."""
    spec = spec or FlashDecodeSpec()
    B, Sq, Hq, D = q.shape
    nb, bs, Hkv, _ = cache.k.shape
    groups = Hq // Hkv
    max_blocks = block_tables.shape[1]
    seq_cap = max_blocks * bs

    splits = max(1, min(spec.num_splits, max_blocks))
    cps = -(-max_blocks // splits)
    bt = block_tables.astype(jnp.int32)
    pad_cols = splits * cps - max_blocks
    if pad_cols:
        bt = jnp.pad(bt, ((0, 0), (0, pad_cols)),
                     constant_values=NULL_BLOCK)
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (B,))

    qr, rows, rows_p = _pack_q(q, groups, Hkv)
    k_scale = getattr(cache, "k_scale", None)
    v_scale = getattr(cache, "v_scale", None)
    quantized = k_scale is not None

    def bmap(b, h, s, j, bt, idx):
        return (b, h, 0, 0)

    def kvmap(b, h, s, j, bt, idx, cps=cps):
        return (bt[b, s * cps + j], 0, h, 0)

    def smap(b, h, s, j, bt, idx, cps=cps):
        return (bt[b, s * cps + j], 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, rows_p, D), bmap),
        pl.BlockSpec((1, bs, 1, D), kvmap),
        pl.BlockSpec((1, bs, 1, D), kvmap),
    ]
    operands = [qr, cache.k, cache.v]
    if quantized:
        in_specs += [pl.BlockSpec((1, bs, 1), smap),
                     pl.BlockSpec((1, bs, 1), smap)]
        operands += [k_scale, v_scale]

    def out_map4(b, h, s, j, bt, idx):
        return (b, h, s, 0)

    def out_map5(b, h, s, j, bt, idx):
        return (b, h, s, 0, 0)

    kernel = functools.partial(
        _decode_kernel, cols_per_split=cps, block_size=bs, sq=Sq,
        scale=D ** -0.5, window=window, seq_cap=seq_cap, quantized=quantized,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, splits, cps),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, rows_p, D), out_map5),
            pl.BlockSpec((1, 1, 1, rows_p), out_map4),
            pl.BlockSpec((1, 1, 1, rows_p), out_map4),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows_p, D), jnp.float32),
            pltpu.VMEM((rows_p, 1), jnp.float32),
            pltpu.VMEM((rows_p, 1), jnp.float32),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, splits, rows_p, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, splits, rows_p), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, splits, rows_p), jnp.float32),
        ],
        interpret=interpret,
    )(bt, idx, *operands)
    out = _combine_splits(acc, m, l)
    return _unpack_out(out, B, Sq, Hq, D, groups, rows).astype(q.dtype)


# ---------------------------------------------------------------------------
# bounded pure-JAX fallback (the non-TPU default)
# ---------------------------------------------------------------------------

def ref_paged_decode(
    q: jax.Array,
    cache,
    block_tables: jax.Array,
    index,
    *,
    window: Optional[int] = None,
    cols_per_iter: int = 8,
) -> jax.Array:
    """Online-softmax decode over block-table column chunks, bounded at run
    time to the max active length across slots.

    A ``lax.while_loop`` gathers ``cols_per_iter`` table columns per
    iteration and stops once ``col * block_size`` passes
    ``max(index) + Sq`` — so a batch at length ~100 in a 2048-token table
    touches ~100 tokens of pool, not 2048 (the old ``gather_kv`` extent).
    The iteration count is a *runtime* value: one compiled step serves every
    length, unlike shape-bounded slicing which would recompile per length.
    """
    B, Sq, Hq, D = q.shape
    nb, bs, Hkv, _ = cache.k.shape
    groups = Hq // Hkv
    max_blocks = block_tables.shape[1]
    seq_cap = max_blocks * bs
    C = max(1, min(cols_per_iter, max_blocks))
    n_cols = -(-max_blocks // C) * C
    bt = block_tables.astype(jnp.int32)
    if n_cols != max_blocks:
        bt = jnp.pad(bt, ((0, 0), (0, n_cols - max_blocks)),
                     constant_values=NULL_BLOCK)
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (B,))

    k_scale = getattr(cache, "k_scale", None)
    v_scale = getattr(cache, "v_scale", None)
    k_flat = cache.k.reshape(nb * bs, Hkv, D)
    v_flat = cache.v.reshape(nb * bs, Hkv, D)
    ks_flat = None if k_scale is None else k_scale.reshape(nb * bs, Hkv)
    vs_flat = None if v_scale is None else v_scale.reshape(nb * bs, Hkv)

    qf = (q.astype(jnp.float32) * (D ** -0.5)).reshape(B, Sq, Hkv, groups, D)
    qf = qf.transpose(0, 2, 3, 1, 4)                       # (B, H, G, Sq, D)
    qpos = idx[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]  # (B, Sq)
    # Tokens any slot can attend this step; the loop stops past it.
    bound = jnp.max(idx) + Sq
    span = C * bs

    def cond(carry):
        col = carry[0]
        return (col * bs < bound) & (col < max_blocks)

    def body(carry):
        col, m, l, acc = carry
        blk = jax.lax.dynamic_slice(bt, (0, col), (B, C))  # (B, C)
        flat = (blk[:, :, None] * bs
                + jnp.arange(bs, dtype=jnp.int32)[None, None, :]).reshape(-1)
        k = jnp.take(k_flat, flat, axis=0).reshape(B, span, Hkv, D)
        v = jnp.take(v_flat, flat, axis=0).reshape(B, span, Hkv, D)
        if ks_flat is not None:
            k = k.astype(jnp.float32) * jnp.take(
                ks_flat, flat, axis=0).reshape(B, span, Hkv)[..., None]
            v = v.astype(jnp.float32) * jnp.take(
                vs_flat, flat, axis=0).reshape(B, span, Hkv)[..., None]
        s = jnp.einsum(
            "bhgqd,bkhd->bhgqk", qf, k.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )                                                  # (B, H, G, Sq, span)
        kpos = col * bs + jnp.arange(span, dtype=jnp.int32)
        mask = (kpos[None, None, :] <= qpos[:, :, None]) \
            & (kpos < seq_cap)[None, None, :]
        if window is not None:
            mask &= (qpos[:, :, None] - kpos[None, None, :]) < window
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (col + C, m_new, l_new, acc_new)

    m0 = jnp.full((B, Hkv, groups, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, groups, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, groups, Sq, D), jnp.float32)
    _, m, l, acc = jax.lax.while_loop(
        cond, body, (jnp.int32(0), m0, l0, acc0))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# backend dispatch (mirrors kernels/ops.py's switch)
# ---------------------------------------------------------------------------

_BACKENDS = ("auto", "gather", "blocked", "flash", "interpret")
_DECODE_BACKEND: Optional[str] = None
_DECODE_SPEC: Optional[FlashDecodeSpec] = None


def set_decode_backend(backend: Optional[str]) -> None:
    """Process-wide decode backend: "gather" (legacy full-extent baseline),
    "blocked" (bounded while_loop fallback), "flash" (Pallas kernel),
    "interpret" (Pallas under the interpreter — CPU tests), "auto"/None
    (flash on TPU, blocked elsewhere).  Binds at *trace* time: set it before
    a step is jit-traced (the engine does this in warmup)."""
    global _DECODE_BACKEND
    if backend is not None and backend not in _BACKENDS:
        raise ValueError(
            f"unknown decode backend {backend!r}; known: {_BACKENDS}")
    _DECODE_BACKEND = backend


def get_decode_backend() -> Optional[str]:
    return _DECODE_BACKEND


@contextlib.contextmanager
def decode_backend(backend: Optional[str]):
    """Scoped ``set_decode_backend`` (trace steps under it, like
    quant.modes.precision)."""
    prev = _DECODE_BACKEND
    set_decode_backend(backend)
    try:
        yield
    finally:
        set_decode_backend(prev)


def set_decode_spec(spec: Optional[FlashDecodeSpec]) -> None:
    """Bind a tuned design point for spec-less dispatch (trace-time, like
    the backend); the engine binds its autotuned winner here in warmup."""
    global _DECODE_SPEC
    _DECODE_SPEC = spec


def get_decode_spec() -> Optional[FlashDecodeSpec]:
    return _DECODE_SPEC


def _resolve_backend(backend: Optional[str]) -> str:
    b = backend or _DECODE_BACKEND or "auto"
    if b == "auto":
        from repro.kernels import ops as _ops

        r = _ops._resolve(None)
        if r in ("pallas", "pipelined"):
            return "flash"
        if r == "interpret":
            return "interpret"
        return "blocked"
    return b


def _gather_decode(q, cache, block_tables, index, *, window=None,
                   prefix_len: int = 0):
    """The legacy path: materialize the slot views, dense softmax over the
    full table extent.  Kept as the benchmark baseline and the
    ``prefix_len`` fallback (bidirectional prefixes never page in practice —
    VLM/encdec are excluded from paged serving)."""
    from repro.models.attention import decode_attention
    from repro.serving.kv_cache import gather_kv

    k, v = gather_kv(cache, block_tables)
    return decode_attention(q, k, v, index=index, window=window,
                            prefix_len=prefix_len)


def paged_decode_attention(
    q: jax.Array,
    cache,
    block_tables: jax.Array,
    index,
    *,
    window: Optional[int] = None,
    prefix_len: int = 0,
    backend: Optional[str] = None,
    spec: Optional[FlashDecodeSpec] = None,
) -> jax.Array:
    """Decode attention over a paged KV cache — the dispatch entry the model
    layer calls.  Equivalent to ``gather_kv`` + ``decode_attention`` for
    every backend (tested in tests/test_flash_decode.py); they differ only
    in how much pool they touch."""
    if prefix_len:
        return _gather_decode(q, cache, block_tables, index, window=window,
                              prefix_len=prefix_len)
    b = _resolve_backend(backend)
    spec = spec or _DECODE_SPEC or FlashDecodeSpec()
    if b == "gather":
        return _gather_decode(q, cache, block_tables, index, window=window)
    if b == "blocked":
        return ref_paged_decode(q, cache, block_tables, index, window=window,
                                cols_per_iter=spec.cols_per_iter)
    return flash_decode_attention(q, cache, block_tables, index,
                                  window=window, spec=spec,
                                  interpret=(b == "interpret"))


def make_flash_decode(spec: FlashDecodeSpec, *, interpret: bool = False):
    """Registry factory (kernels/registry.py): specialize the paged decode
    kernel at one ``FlashDecodeSpec`` design point.  Returns
    ``fn(q, cache, block_tables, index, *, window=None)``."""

    def fn(q, cache, block_tables, index, *, window=None):
        return flash_decode_attention(
            q, cache, block_tables, index, window=window, spec=spec,
            interpret=interpret)

    return fn
