"""Kernel registry: named GeMM kernel factories, one per backend variant.

The Chisel generator's elaboration table, in software: every entry maps a
backend name to a factory ``factory(spec, *, interpret=False) -> gemm_fn``
that specializes a Pallas kernel for one `TpuGemmSpec` design point.

`ops.gemm` and `repro.tuning` dispatch through this table instead of
hard-coding imports, so adding a kernel variant (a new dataflow, a fused
epilogue, a future backend) is one `register_kernel` call — the autotuner
and every caller pick it up without modification.

Generated kernels are memoized per (name, spec, interpret): re-tracing the
same specialization on every call would defeat jit caching upstream.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

from repro.kernels.flash_decode import make_flash_decode
from repro.kernels.gemm import make_dequant_gemm, make_gemm
from repro.kernels.gemm_pipelined import make_pipelined_gemm
from repro.kernels.quant import make_w8a8_gemm

KernelFactory = Callable[..., Callable]

_REGISTRY: Dict[str, KernelFactory] = {}


def register_kernel(name: str, factory: KernelFactory, *, overwrite: bool = False) -> None:
    """Add a kernel variant.  `factory(spec, *, interpret=False) -> fn`."""
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"kernel {name!r} already registered")
    _REGISTRY[name] = factory
    _make_cached.cache_clear()


def registered_kernels() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_kernel_factory(name: str) -> KernelFactory:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {registered_kernels()}"
        ) from None


@functools.lru_cache(maxsize=256)
def _make_cached(name: str, spec, interpret: bool) -> Callable:
    return _REGISTRY[name](spec, interpret=interpret)


# `spec` is the design point of the named kernel family: a TpuGemmSpec for
# the GeMM variants, a FlashDecodeSpec for "flash_decode" — any hashable
# frozen dataclass works (the memoization keys on it).
def make_kernel(name: str, spec, *, interpret: bool = False) -> Callable:
    """Instantiate (or fetch the memoized) kernel `name` at design point `spec`."""
    get_kernel_factory(name)  # raise the readable error before caching
    return _make_cached(name, spec, interpret)


# -- built-in variants -------------------------------------------------------

register_kernel("pallas", make_gemm)
register_kernel("pipelined", make_pipelined_gemm)
register_kernel("dequant", make_dequant_gemm)
# The int8 deployment path end to end: float activations row-quantized in
# VMEM, int8 x int8 -> int32 GeMM, fused dequant epilogue (quant.py).
register_kernel("w8a8", make_w8a8_gemm)
# Paged decode attention (flash_decode.py): spec is a FlashDecodeSpec, not a
# TpuGemmSpec — the registry only requires a hashable frozen design point.
register_kernel("flash_decode", make_flash_decode)
