"""Tuning cache: persisted winners, keyed by (shape, dtype, backend).

Two layers, mirroring the accelerator's own configuration hierarchy:

  * an in-memory LRU (the "CSR file": hot configs resolve in O(1) with no
    I/O — this is the path `tuned_gemm` hits on every call after the first);
  * an on-disk JSON registry (the "generator output": survives processes,
    shareable between machines, human-readable for EXPERIMENTS.md dumps).

Writes go through a temp-file rename so a crashed run never corrupts the
registry; concurrent readers always see a complete JSON document.

The default location is ``~/.cache/repro-opengemm/tunecache.json``,
overridable with ``REPRO_TUNE_CACHE`` (useful for committing a tuned
registry next to a deployment, or pointing tests at a tmpdir).
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
from collections import OrderedDict
from typing import Dict, Optional

from repro.core.dataflow import GemmShape
from repro.core.generator import TpuGemmSpec

_ENV_VAR = "REPRO_TUNE_CACHE"


def default_cache_path() -> str:
    return os.environ.get(_ENV_VAR) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-opengemm", "tunecache.json"
    )


def cache_key(shape: GemmShape, dtype, backend: str) -> str:
    name = getattr(dtype, "name", str(dtype))
    return f"{shape.M}x{shape.K}x{shape.N}|{name}|{backend}"


@dataclasses.dataclass(frozen=True)
class CacheEntry:
    """One tuned winner: the spec plus provenance for auditability.

    `spec` is whatever design point the kernel family tunes — a `TpuGemmSpec`
    for the GeMM backends, a `FlashDecodeSpec` for decode attention.  Records
    other than GeMM carry a "kind" discriminator in their JSON form (GeMM
    entries stay bare for backward compatibility with existing registries).
    """

    spec: object
    score: float              # predicted clocks (analytic) or seconds (wallclock)
    source: str               # "analytic" | "wallclock"

    def to_json(self) -> dict:
        if isinstance(self.spec, TpuGemmSpec):
            d = {
                "tm": self.spec.tm, "tk": self.spec.tk, "tn": self.spec.tn,
                "depth": self.spec.depth, "int8": self.spec.int8,
            }
        else:
            d = dict(self.spec.to_json())  # must include its "kind"
        d["score"] = self.score
        d["source"] = self.source
        return d

    @classmethod
    def from_json(cls, d: dict) -> "CacheEntry":
        kind = d.get("kind")
        if kind == "flash_decode":
            # Lazy: keep tuning importable without the kernels package.
            from repro.kernels.flash_decode import FlashDecodeSpec

            spec = FlashDecodeSpec.from_json(d)
        elif kind is None:
            spec = TpuGemmSpec(
                tm=int(d["tm"]), tk=int(d["tk"]), tn=int(d["tn"]),
                depth=int(d.get("depth", 2)), int8=bool(d.get("int8", True)),
            )
        else:
            raise ValueError(f"unknown cache entry kind {kind!r}")
        return cls(
            spec=spec,
            score=float(d["score"]),
            source=str(d.get("source", "analytic")),
        )


class TuneCache:
    """JSON-backed winner registry with an in-memory LRU front.

    `persistent=False` makes the cache memory-only: nothing is read from or
    written to disk (hermetic benchmarks / tests).
    """

    def __init__(
        self,
        path: Optional[str] = None,
        *,
        lru_size: int = 256,
        persistent: bool = True,
    ):
        self.path = path or default_cache_path()
        self.lru_size = lru_size
        self.persistent = persistent
        self._lru: "OrderedDict[str, CacheEntry]" = OrderedDict()
        self._disk: Dict[str, dict] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        if persistent:
            self._load()

    # -- persistence ---------------------------------------------------------

    def _load(self) -> None:
        try:
            with open(self.path) as f:
                data = json.load(f)
            if isinstance(data, dict):
                self._disk = {str(k): v for k, v in data.items()}
        except (OSError, ValueError):
            self._disk = {}

    def save(self) -> None:
        if not self.persistent:
            return
        with self._lock:
            snapshot = dict(self._disk)
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d or ".", suffix=".tunecache")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(snapshot, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- lookup / insert -----------------------------------------------------

    def get(self, key: str) -> Optional[CacheEntry]:
        with self._lock:
            hit = self._lru.get(key)
            if hit is not None:
                self._lru.move_to_end(key)
                self.hits += 1
                return hit
            raw = self._disk.get(key)
            if raw is not None:
                try:
                    entry = CacheEntry.from_json(raw)
                except (KeyError, ValueError, TypeError):
                    self.misses += 1
                    return None
                self._insert_lru(key, entry)
                self.hits += 1
                return entry
            self.misses += 1
            return None

    def put(self, key: str, entry: CacheEntry, *, persist: bool = True) -> None:
        with self._lock:
            self._insert_lru(key, entry)
            self._disk[key] = entry.to_json()
        if persist:
            self.save()

    def _insert_lru(self, key: str, entry: CacheEntry) -> None:
        self._lru[key] = entry
        self._lru.move_to_end(key)
        while len(self._lru) > self.lru_size:
            self._lru.popitem(last=False)

    def __len__(self) -> int:
        return len(self._disk)

    def dump(self) -> Dict[str, dict]:
        """The on-disk registry as a dict (see EXPERIMENTS.md for reading it)."""
        with self._lock:
            return {k: dict(v) for k, v in sorted(self._disk.items())}
