"""Decode-attention tuning: FlashDecodeSpec search, cached like GeMM tiles.

The GeMM autotuner closes the paper's generator loop for matmuls: enumerate
legal design points, rank (analytic model or wall clock), persist the winner.
This module gives the paged flash-decode kernel (kernels/flash_decode.py) the
same treatment for its two knobs:

  num_splits     split-K factor over the block-table columns (the Pallas
                 kernel's sequence-dimension parallelism / combine-overhead
                 trade);
  cols_per_iter  table columns per ``while_loop`` chunk of the bounded
                 pure-JAX fallback (iteration overhead vs gather overshoot).

Winners land in the same ``TuneCache`` registry as GeMM tiles under an
``fd...|flash_decode`` key (see ``decode_cache_key``), so one
REPRO_TUNE_CACHE file carries a deployment's full configuration — GeMM tiles
and decode design points — exactly like the paper's generated CSR image.

The analytic model is deliberately coarse (decode attention is bandwidth-
bound, not MAC-bound): costs are in "block-visit" units with fixed launch /
combine / iteration overheads, enough to rank the knobs deterministically on
any host.  ``mode="wallclock"`` times the real dispatch path instead — the
Pallas kernel on TPU, the bounded fallback elsewhere.
"""

from __future__ import annotations

import time
from typing import List, NamedTuple, Optional

from repro.kernels.flash_decode import FlashDecodeSpec
from repro.tuning.autotuner import Autotuner, TuneResult, get_tuner
from repro.tuning.cache import CacheEntry

# Coarse cost-model constants (dimensionless "block-visit" units).
_SPLIT_OVERHEAD = 1000.0   # per-split launch + partial (acc, m, l) write
_COMBINE_PER_ELEM = 4.0    # stage-2 rescale/accumulate per partial element
_ITER_OVERHEAD = 4000.0    # while_loop iteration dispatch (fallback path)
_MAX_SPLITS = 16
_MAX_CHUNK_TOKENS = 2048   # fallback gather chunk bound (cols * block_size)


class DecodeShape(NamedTuple):
    """The decode-attention problem, as the tuner keys it."""

    slots: int        # decode batch width B
    kv_heads: int
    groups: int       # Hq // Hkv (GQA fan-in)
    head_dim: int
    sq: int           # query positions per step (1 decode, K+1 verify)
    block_size: int   # pool block tokens
    max_blocks: int   # block-table columns per slot


def decode_cache_key(shape: DecodeShape, dtype, mode: str = "analytic") -> str:
    """Registry key — mirrors ``cache.cache_key``'s shape|dtype|backend form
    (plus the wallclock suffix rule of ``Autotuner.tune``)."""
    name = getattr(dtype, "name", str(dtype))
    key = (f"fd{shape.slots}x{shape.kv_heads}h{shape.groups}g"
           f"{shape.head_dim}d{shape.sq}q"
           f"|bs{shape.block_size}x{shape.max_blocks}|{name}|flash_decode")
    if mode != "analytic":
        key += f"|{mode}"
    return key


def _pow2s(cap: int) -> List[int]:
    out, v = [], 1
    while v <= cap:
        out.append(v)
        v *= 2
    return out or [1]


def enumerate_decode_specs(shape: DecodeShape) -> List[FlashDecodeSpec]:
    """Legal (num_splits, cols_per_iter) design points, default included,
    deterministic order (ascending splits, then cols) — same contract as
    ``candidates.enumerate_tiles``."""
    splits = _pow2s(min(_MAX_SPLITS, shape.max_blocks))
    cols_cap = max(1, min(shape.max_blocks,
                          _MAX_CHUNK_TOKENS // max(1, shape.block_size)))
    cols = _pow2s(cols_cap)
    seen, out = set(), []
    default = FlashDecodeSpec()
    for spec in [default] + [
        FlashDecodeSpec(num_splits=s, cols_per_iter=c)
        for s in splits for c in cols
    ]:
        key = (spec.num_splits, spec.cols_per_iter)
        if key in seen:
            continue
        seen.add(key)
        out.append(spec)
    out.sort(key=lambda s: (s.num_splits, s.cols_per_iter))
    return out


def predict_decode_cost(spec: FlashDecodeSpec, shape: DecodeShape) -> float:
    """Rank a candidate: split-path latency + fallback-path cost.

    The two knobs are independent (each term consumes one), so ranking the
    sum tunes both jointly.  Per kv head: every visited pool block costs
    ``block_size * rows * head_dim * 2`` MAC-ish units (QK^T + PV); splits
    shorten the serial column walk at ``_SPLIT_OVERHEAD`` + combine cost
    each; fallback chunks amortize ``_ITER_OVERHEAD`` against an expected
    half-chunk gather overshoot past the live length.
    """
    rows = max(8, shape.groups * shape.sq)
    block_cost = float(shape.block_size * rows * shape.head_dim * 2)
    splits = max(1, min(spec.num_splits, shape.max_blocks))
    serial_cols = -(-shape.max_blocks // splits)
    split_cost = serial_cols * block_cost + splits * (
        _SPLIT_OVERHEAD + _COMBINE_PER_ELEM * rows * shape.head_dim)
    cols = max(1, min(spec.cols_per_iter, shape.max_blocks))
    iters = -(-shape.max_blocks // cols)
    ref_cost = iters * _ITER_OVERHEAD + (cols / 2.0) * block_cost
    return split_cost + ref_cost


def _time_candidate(spec: FlashDecodeSpec, shape: DecodeShape, dtype,
                    iters: int = 3) -> float:
    """Wall-clock one candidate through the real dispatch path (flash on
    TPU, the bounded fallback elsewhere) at the worst-case length."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import flash_decode as fd
    from repro.serving.kv_cache import init_paged_kv

    B, mb, bs = shape.slots, shape.max_blocks, shape.block_size
    nb = B * mb + 1
    cache = init_paged_kv(nb, bs, shape.kv_heads, shape.head_dim, dtype)
    bt = (jnp.arange(B * mb, dtype=jnp.int32) + 1).reshape(B, mb)
    index = jnp.full((B,), mb * bs - shape.sq, jnp.int32)
    q = jnp.ones((B, shape.sq, shape.kv_heads * shape.groups, shape.head_dim),
                 cache.k.dtype)
    backend = "flash" if jax.default_backend() == "tpu" else "blocked"
    fn = jax.jit(lambda q, c, t, i: fd.paged_decode_attention(
        q, c, t, i, backend=backend, spec=spec))
    fn(q, cache, bt, index).block_until_ready()      # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(q, cache, bt, index)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def tune_decode(
    shape: DecodeShape,
    dtype="float32",
    *,
    mode: str = "analytic",
    tuner: Optional[Autotuner] = None,
    force: bool = False,
) -> TuneResult:
    """Best FlashDecodeSpec for `shape`, cached in the shared registry.

    Uses the default tuner's ``TuneCache`` (REPRO_TUNE_CACHE honored), so
    decode winners persist next to GeMM tiles.  ``mode`` follows
    ``Autotuner``: "analytic" ranks by ``predict_decode_cost``; "wallclock"
    times each candidate's real dispatch path and — like the GeMM tuner —
    refuses to resolve a wallclock query from an analytic cache entry.
    """
    if mode not in ("analytic", "wallclock"):
        raise ValueError(f"unknown tuning mode {mode!r}")
    t = tuner or get_tuner()
    key = decode_cache_key(shape, dtype, mode)
    if not force:
        hit = t.cache.get(key)
        if hit is not None and (mode == "analytic" or hit.source == mode):
            return TuneResult(spec=hit.spec, score=hit.score,
                              source=hit.source, from_cache=True)
    cands = enumerate_decode_specs(shape)
    best, best_score, source = None, float("inf"), "analytic"
    if mode == "wallclock":
        for spec in cands:
            try:
                s = _time_candidate(spec, shape, dtype)
            except Exception:
                continue                  # candidate cannot run here
            if s < best_score:
                best, best_score = spec, s
        if best is not None:
            source = "wallclock"
    if best is None:                      # analytic mode, or nothing ran
        for spec in cands:
            s = predict_decode_cost(spec, shape)
            if s < best_score:            # strict <: ties break to the
                best, best_score = spec, s  # smallest knobs (sorted cands)
        source = "analytic"
    t.cache.put(key, CacheEntry(spec=best, score=best_score, source=source),
                persist=t.persist)
    return TuneResult(spec=best, score=best_score, source=source,
                      from_cache=False, candidates=len(cands))


def serving_decode_shape(cfg, *, slots: int, block_size: int,
                         max_blocks: int, sq: int = 1
                         ) -> Optional[DecodeShape]:
    """The decode-attention problem one serving engine dispatches every
    tick, or None for stacks with no attention layers (pure SSM/xLSTM —
    nothing to tune)."""
    kinds = set(cfg.layer_kinds())
    if not kinds & {"attn", "attn_local"}:
        return None
    return DecodeShape(
        slots=slots, kv_heads=cfg.n_kv_heads,
        groups=cfg.n_heads // cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, sq=sq,
        block_size=block_size, max_blocks=max_blocks)


def tune_decode_for_serving(cfg, *, slots: int, block_size: int,
                            max_blocks: int, mode: str = "analytic",
                            dtype: Optional[str] = None,
                            verbose: bool = False
                            ) -> Optional[FlashDecodeSpec]:
    """Engine-warmup entry: tune the hot Sq=1 decode shape and return the
    winner (None when the stack has no attention).  The engine binds it via
    ``flash_decode.set_decode_spec`` before tracing its steps."""
    shape = serving_decode_shape(cfg, slots=slots, block_size=block_size,
                                 max_blocks=max_blocks)
    if shape is None:
        return None
    r = tune_decode(shape, dtype or cfg.dtype, mode=mode)
    if verbose:
        hit = "cache" if r.from_cache else r.source
        print(f"autotune[decode]: splits={r.spec.num_splits} "
              f"cols={r.spec.cols_per_iter} for {cfg.name} "
              f"(bs{shape.block_size}x{shape.max_blocks}, {hit})")
    return r.spec
