"""Tile autotuner: the OpenGeMM generator loop, closed in software.

  candidates  - MXU-legal (TM, TK, TN) design space per (shape, dtype)
  model       - analytic ranking via the core/simulator.py cycle model
  cache       - JSON winner registry with an in-memory LRU front
  autotuner   - search + cache orchestration, `tuned_gemm` entry point
  decode      - FlashDecodeSpec search for paged decode attention (same
                cache registry, `fd...|flash_decode` keys)

Quick use::

    from repro.tuning import tuned_gemm
    c = tuned_gemm(a, b)                      # best known tile, cached

    from repro import tuning
    tuning.enable()                           # spec-less ops.gemm calls
    ...                                       # now dispatch through the tuner

Set ``REPRO_AUTOTUNE=1`` to enable dispatch at import, and
``REPRO_TUNE_CACHE=/path.json`` to relocate the winner registry.
"""

from repro.tuning.autotuner import (
    Autotuner,
    TuneResult,
    disable,
    enable,
    env_truthy,
    get_tuner,
    is_enabled,
    set_tuner,
    tuned_gemm,
    tuned_spec,
)
from repro.tuning.cache import CacheEntry, TuneCache, cache_key, default_cache_path
from repro.tuning.candidates import dtype_bits, enumerate_tiles
from repro.tuning.decode import (
    DecodeShape,
    decode_cache_key,
    enumerate_decode_specs,
    predict_decode_cost,
    serving_decode_shape,
    tune_decode,
    tune_decode_for_serving,
)
from repro.tuning.model import TilePrediction, predict, predict_clocks, proxy_config

__all__ = [
    "DecodeShape",
    "decode_cache_key",
    "enumerate_decode_specs",
    "predict_decode_cost",
    "serving_decode_shape",
    "tune_decode",
    "tune_decode_for_serving",
    "Autotuner",
    "TuneResult",
    "TuneCache",
    "CacheEntry",
    "TilePrediction",
    "cache_key",
    "default_cache_path",
    "dtype_bits",
    "enumerate_tiles",
    "predict",
    "predict_clocks",
    "proxy_config",
    "enable",
    "disable",
    "env_truthy",
    "is_enabled",
    "get_tuner",
    "set_tuner",
    "tuned_gemm",
    "tuned_spec",
]
