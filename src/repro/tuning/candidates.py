"""Tile-shape candidate enumeration: the autotuner's design space.

The Chisel generator elaborates one accelerator per (Mu, Ku, Nu); the TPU
analogue elaborates one Pallas kernel per (TM, TK, TN) `TpuGemmSpec`.  This
module enumerates every spec that is *legal* for a given problem:

  * TN and TK are multiples of the 128 MXU lanes, TM of the 8 sublanes
    (hard constraints from `TpuGemmSpec.__post_init__`);
  * TM additionally respects the dtype sublane packing (8/16/32 for
    f32/bf16/int8) so no candidate wastes sublanes by construction;
  * the double-buffered A/B blocks plus the accumulator tile fit the VMEM
    budget (`TpuGemmSpec.vmem_bytes`);
  * no tile extends past the *padded* problem (a 512-wide TN for N=128 only
    adds padding MACs, so it is pruned, not ranked).

The default `tpu_kernel_spec` design point is always included, so the
autotuner can only ever match or beat the hard-coded mapping.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.dataflow import GemmShape
from repro.core.generator import (
    CASE_STUDY,
    MXU_LANES,
    OpenGeMMConfig,
    TpuGemmSpec,
    VMEM_BUDGET_BYTES,
    sublane_multiple,
)

# Power-of-two sweep bounds; the per-problem aligned extents are added on top.
_TM_SWEEP = (8, 16, 32, 64, 128, 256, 512)
_TKN_SWEEP = (128, 256, 512)
# int8 operands halve the A/B block footprint and pack 32 sublanes, so the
# int8 design space extends one octave further in every dimension (the
# paper's P_A=P_B=8 datapath is exactly this: more tile per SRAM byte).
# The VMEM-budget check below still prunes anything that does not fit.
_TM_SWEEP_INT8 = _TM_SWEEP + (1024,)
_TKN_SWEEP_INT8 = _TKN_SWEEP + (1024,)


def dtype_bits(dtype) -> int:
    """Operand width in bits for a jnp dtype / dtype name."""
    name = getattr(dtype, "name", str(dtype))
    if "int8" in name or "uint8" in name or "fp8" in name:
        return 8
    if "bfloat16" in name or "float16" in name:
        return 16
    return 32


def _align_up(v: int, a: int) -> int:
    return -(-v // a) * a


def enumerate_tiles(
    shape: GemmShape,
    dtype="int8",
    *,
    depth=None,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    config: Optional[OpenGeMMConfig] = None,
    max_candidates: Optional[int] = None,
) -> List[TpuGemmSpec]:
    """All legal (TM, TK, TN) specs for `shape`/`dtype`, default spec included.

    `depth` is the paper's D_stream knob: an int, a sequence of ints to sweep
    pipeline depths as part of the search (the Fig. 5 depth axis — meaningful
    for the "pipelined" ring-buffer kernel), or None for the config's
    D_stream.  Returned in a deterministic order (ascending tile volume, then
    lexical), so analytic ranking over this list is reproducible run to run.
    """
    bits = dtype_bits(dtype)
    int8 = bits == 8
    sub = sublane_multiple(bits)
    cfg = config or CASE_STUDY
    if depth is None:
        depth = cfg.D_stream
    depths = (depth,) if isinstance(depth, int) else tuple(depth)

    # Candidate extents per dim: the power-of-two sweep, clipped to the padded
    # problem, plus the exact aligned extent (captures e.g. TM=200 for M=197).
    tm_cap = _align_up(shape.M, sub)
    tk_cap = _align_up(shape.K, MXU_LANES)
    tn_cap = _align_up(shape.N, MXU_LANES)
    tm_sweep = _TM_SWEEP_INT8 if int8 else _TM_SWEEP
    tkn_sweep = _TKN_SWEEP_INT8 if int8 else _TKN_SWEEP
    cap_ext = 1024 if int8 else 512
    tms = sorted({min(v, tm_cap) for v in tm_sweep if v % sub == 0}
                 | {min(cap_ext, tm_cap)})
    tks = sorted({min(v, tk_cap) for v in tkn_sweep} | {min(cap_ext, tk_cap)})
    tns = sorted({min(v, tn_cap) for v in tkn_sweep} | {min(cap_ext, tn_cap)})

    seen = set()
    out: List[TpuGemmSpec] = []
    # The default design point rides along at its native depth (dtype flag
    # normalized: tpu_kernel_spec always reports CASE_STUDY's int8), so the
    # search can only match or beat the hard-coded mapping.
    default = dataclasses.replace(
        cfg.tpu_kernel_spec(shape, vmem_budget=vmem_budget), int8=int8
    )
    for spec in [default] + [
        TpuGemmSpec(tm=tm, tk=tk, tn=tn, depth=d, int8=int8)
        for tm in tms
        for tk in tks
        for tn in tns
        for d in depths
    ]:
        key = (spec.tm, spec.tk, spec.tn, spec.depth)
        if key in seen or spec.vmem_bytes(bits) > vmem_budget:
            continue
        seen.add(key)
        out.append(spec)

    out.sort(key=lambda s: (s.tm * s.tk * s.tn, s.tm, s.tk, s.tn, s.depth))
    if max_candidates is not None and len(out) > max_candidates:
        # Keep the default in the pruned set: it is the baseline to beat.
        keep = out[:max_candidates]
        if default not in keep:
            keep[-1] = default
        out = keep
    return out
