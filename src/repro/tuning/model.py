"""Analytic tile ranking: the paper's cycle model re-targeted at the TPU.

`core/simulator.py` models a generated OpenGeMM instance in closed form:
configuration + pipeline fill + compute + streamer stalls, per call.  The
Pallas kernel has exactly the same structure — the DMA engine is the operand
streamer, VMEM the scratchpad, the MXU the MAC array, and the grid's K-inner
schedule the output-stationary tile loop — so the same model ranks TPU tile
shapes if we re-express its constants in TPU units:

  * one simulator "cycle" := one MXU pass over a (TM, TK, TN) tile
    (`pass_clocks` real clocks, from the chip's peak MACs/clock);
  * streamer bandwidth := HBM bytes/clock x pass_clocks, folded into the
    config's `R_mem x P_word` port model;
  * the CSR routine := kernel launch/dispatch overhead, in pass units;
  * `D_stream` := the Pallas pipeline depth (2 for grid double-buffering,
    deeper for gemm_pipelined's explicit ring buffer).

Spatial utilization (padding waste) is captured automatically: the simulator
tiles the problem with `ceil`, so an oversized TN pays its padded passes.

This is the autotuner's *fast path*: ranking ~100 candidates is a few
milliseconds of arithmetic and needs no TPU.  Absolute clock counts are
roofline-grade estimates; only the *ordering* is consumed.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.dataflow import GemmShape
from repro.core.generator import OpenGeMMConfig, TpuGemmSpec
from repro.core.simulator import OpenGeMMSimulator
from repro.tuning.candidates import dtype_bits

# TPU hardware constants: shared with launch/mesh.py via core/hw.py.
from repro.core.hw import CLOCK_HZ, HBM_BW, PEAK_FLOPS_BF16  # noqa: E402

LAUNCH_CLOCKS = 5000          # kernel dispatch overhead per pallas_call

_MACS_PER_CLOCK_BF16 = PEAK_FLOPS_BF16 / (2 * CLOCK_HZ)
_HBM_BYTES_PER_CLOCK = HBM_BW / CLOCK_HZ


def macs_per_clock(bits: int) -> float:
    """Peak MACs/clock by operand width: int8 runs 2x bf16, f32 half."""
    return _MACS_PER_CLOCK_BF16 * {8: 2.0, 16: 1.0, 32: 0.5}[bits]


@dataclasses.dataclass(frozen=True)
class TilePrediction:
    """Model-predicted performance of one (spec, shape, dtype) point."""

    spec: TpuGemmSpec
    clocks: float            # predicted TPU clocks for one GeMM call
    utilization: float       # useful MACs / (clocks * peak MACs/clock)

    @property
    def time_s(self) -> float:
        return self.clocks / CLOCK_HZ

    def gops(self, shape: GemmShape) -> float:
        return 2 * shape.macs / self.time_s / 1e9


def proxy_config(spec: TpuGemmSpec, dtype="int8") -> OpenGeMMConfig:
    """An `OpenGeMMConfig` whose cycle model, run in tile-pass units,
    describes the Pallas kernel generated from `spec`."""
    bits = dtype_bits(dtype)
    pass_clocks = max(1.0, spec.tm * spec.tk * spec.tn / macs_per_clock(bits))
    bw_bits = max(64, int(_HBM_BYTES_PER_CLOCK * pass_clocks) * 8)
    ports = max(1, bw_bits // 64)
    return OpenGeMMConfig(
        Mu=spec.tm, Ku=spec.tk, Nu=spec.tn,
        P_A=bits, P_B=bits, P_C=32,
        D_stream=max(2, spec.depth),
        R_mem=ports, W_mem=ports, P_word=64,
        # CPL / pre-fetch / strided access are all "on" on TPU: dispatch of
        # call i+1 overlaps call i, the grid pipeline prefetches, and VMEM
        # is conflict-free.
        cfg_preload=True, input_prefetch=True, strided_access=True,
        csr_cycles=max(1, round(LAUNCH_CLOCKS / pass_clocks)),
        launch_cycles=1,
        spm_latency=2,
    )


def predict(
    spec: TpuGemmSpec,
    shape: GemmShape,
    dtype="int8",
    *,
    first_call: bool = True,
    config: Optional[OpenGeMMConfig] = None,
) -> TilePrediction:
    """Predicted clocks/utilization for one `gemm(a, b)` call at `spec`."""
    bits = dtype_bits(dtype)
    cfg = config or proxy_config(spec, dtype)
    pass_clocks = max(1.0, spec.tm * spec.tk * spec.tn / macs_per_clock(bits))
    timing = OpenGeMMSimulator(cfg).simulate_call(shape, first_call=first_call)
    clocks = timing.total_cycles * pass_clocks
    util = shape.macs / (clocks * macs_per_clock(bits))
    return TilePrediction(spec=spec, clocks=clocks, utilization=util)


def predict_clocks(spec: TpuGemmSpec, shape: GemmShape, dtype="int8") -> float:
    return predict(spec, shape, dtype).clocks
