"""The autotuner: search tile space per workload, cache the winner.

Closes the paper's generator loop in software.  Where the Chisel generator
elaborates one accelerator per (Mu, Ku, Nu) and the designer picks the point
by DSE, the `Autotuner` elaborates one Pallas kernel per legal (TM, TK, TN)
and picks the point per *workload*:

  1. `candidates.enumerate_tiles`  — the legal design space for (shape, dtype);
  2. ranking                        — analytic (cycle model of
     `core/simulator.py` in TPU units, no device needed: the default) or
     empirical (wall-clock of the generated kernel on the local device);
  3. `cache.TuneCache`              — winners persist across processes,
     LRU-fronted so steady-state dispatch costs one dict lookup.

`tuned_gemm(a, b)` is the user-facing entry: every caller gets the best
known tile for its problem without hand-picking a spec.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import List, Optional, Sequence, Tuple

from repro.core.dataflow import GemmShape
from repro.core.generator import CASE_STUDY, OpenGeMMConfig, TpuGemmSpec, VMEM_BUDGET_BYTES
from repro.tuning import model as tmodel
from repro.tuning.cache import CacheEntry, TuneCache, cache_key
from repro.tuning.candidates import enumerate_tiles

# Backends that name a real kernel specialization.  "interpret" runs the
# "pallas" kernel under the interpreter, so it shares that tuning key.
# "dequant" and "w8a8" are the int8 deployment epilogues (kernels/registry.py):
# their fused scale write-back costs differently from the plain GeMM, so each
# is its own tuning key.
_KERNEL_BACKEND = {
    "pallas": "pallas", "interpret": "pallas", "pipelined": "pipelined",
    "dequant": "dequant", "w8a8": "w8a8",
}


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one tuning query."""

    spec: TpuGemmSpec
    score: float                 # predicted clocks (analytic) / seconds (wallclock)
    source: str                  # "analytic" | "wallclock" | "default"
    from_cache: bool = False
    candidates: int = 0


class Autotuner:
    """Tile-shape search with a persistent winner cache.

    mode="analytic"   rank by the simulator-derived cycle model (fast, exact
                      ordering of the model; works on any host).
    mode="wallclock"  time each candidate kernel on the local device; falls
                      back to analytic when the backend cannot run here
                      (e.g. a pallas kernel on a CPU-only host).
    """

    def __init__(
        self,
        config: Optional[OpenGeMMConfig] = None,
        cache: Optional[TuneCache] = None,
        *,
        mode: str = "analytic",
        vmem_budget: int = VMEM_BUDGET_BYTES,
        max_candidates: Optional[int] = None,
        persist: bool = True,
        wallclock_iters: int = 3,
    ):
        if mode not in ("analytic", "wallclock"):
            raise ValueError(f"unknown tuning mode {mode!r}")
        self.config = config or CASE_STUDY
        self.cache = cache if cache is not None else TuneCache()
        self.mode = mode
        self.vmem_budget = vmem_budget
        self.max_candidates = max_candidates
        self.persist = persist
        self.wallclock_iters = wallclock_iters

    # -- public API ----------------------------------------------------------

    def tune(
        self,
        shape: GemmShape,
        dtype="int8",
        *,
        backend: str = "pallas",
        depth=None,
        force: bool = False,
    ) -> TuneResult:
        """Best spec for (shape, dtype, backend), cached.

        `depth` follows `candidates.enumerate_tiles`; by default the
        "pipelined" backend sweeps the paper's D_stream axis (2/3/4) since
        its ring buffer really honors the knob.
        """
        kb = _KERNEL_BACKEND.get(backend, backend)
        key = cache_key(shape, dtype, kb)
        # Winners from different ranking modes / budgets are not
        # interchangeable: a wallclock re-run must not resolve to a cached
        # analytic entry (and vice versa).
        if self.mode != "analytic":
            key += f"|{self.mode}"
        if self.vmem_budget != VMEM_BUDGET_BYTES:
            key += f"|vmem{self.vmem_budget}"
        if depth is not None:
            ds = (depth,) if isinstance(depth, int) else tuple(depth)
            key += "|d" + "-".join(map(str, ds))
        if self.max_candidates is not None:
            key += f"|top{self.max_candidates}"
        if not force:
            hit = self.cache.get(key)
            # A wallclock tuner only trusts measured entries: an analytic
            # *fallback* persisted by a host that couldn't measure must not
            # stop a capable host from actually timing kernels.
            if hit is not None and (self.mode == "analytic" or hit.source == self.mode):
                return TuneResult(
                    spec=hit.spec, score=hit.score, source=hit.source,
                    from_cache=True,
                )
        if depth is None and kb == "pipelined":
            depth = (2, 3, 4)
        cands = enumerate_tiles(
            shape, dtype, depth=depth, vmem_budget=self.vmem_budget,
            config=self.config, max_candidates=self.max_candidates,
        )
        if self.mode == "wallclock" and self._can_measure(backend):
            spec, score, source = self._rank_wallclock(cands, shape, dtype, backend)
        else:
            spec, score, source = self._rank_analytic(cands, shape, dtype)
        self.cache.put(key, CacheEntry(spec=spec, score=score, source=source),
                       persist=self.persist)
        return TuneResult(spec=spec, score=score, source=source,
                          from_cache=False, candidates=len(cands))

    def spec_for(self, shape: GemmShape, dtype="int8", *, backend: str = "pallas") -> TpuGemmSpec:
        return self.tune(shape, dtype, backend=backend).spec

    def warmup(
        self, shapes: Sequence[GemmShape], dtype="int8", *, backend: str = "pallas"
    ) -> List[TuneResult]:
        """Pre-tune a workload's shapes (e.g. a model's GeMMs before serving)."""
        return [self.tune(s, dtype, backend=backend) for s in shapes]

    # -- ranking strategies --------------------------------------------------

    def _rank_analytic(
        self, cands: Sequence[TpuGemmSpec], shape: GemmShape, dtype
    ) -> Tuple[TpuGemmSpec, float, str]:
        # `cands` is sorted by tile volume; strict `<` therefore breaks score
        # ties toward the smallest tile (least VMEM pressure), deterministically.
        best, best_clocks = None, float("inf")
        for spec in cands:
            clocks = tmodel.predict_clocks(spec, shape, dtype)
            if clocks < best_clocks:
                best, best_clocks = spec, clocks
        assert best is not None, "no legal tile candidates"
        return best, best_clocks, "analytic"

    def _can_measure(self, backend: str) -> bool:
        if backend == "interpret":
            return True
        import jax

        return jax.default_backend() == "tpu"

    def _rank_wallclock(
        self, cands: Sequence[TpuGemmSpec], shape: GemmShape, dtype, backend: str
    ) -> Tuple[TpuGemmSpec, float, str]:
        from repro.kernels.registry import make_kernel

        interpret = backend == "interpret"
        kb = _KERNEL_BACKEND.get(backend, backend)
        best, best_t = None, float("inf")
        for spec in cands:
            try:
                args = self._bench_args(kb, shape, dtype, spec)
                t = self._time_spec(
                    make_kernel(kb, spec, interpret=interpret), args)
            except Exception:
                continue  # candidate fails to compile/run here: not a winner
            if t < best_t:
                best, best_t = spec, t
        if best is None:  # nothing ran (e.g. driver issue): analytic fallback
            return self._rank_analytic(cands, shape, dtype)
        return best, best_t, "wallclock"

    def _bench_args(self, kb: str, shape: GemmShape, dtype, spec: TpuGemmSpec):
        """Dummy operands for one candidate, pre-padded to its tile grid.

        The epilogue kernels take scale operands on top of A/B: "dequant"
        consumes int8 A/B plus row/column scales, "w8a8" consumes *float*
        activations (it quantizes them in-kernel) plus column scales.
        """
        import jax.numpy as jnp

        name = getattr(dtype, "name", str(dtype))
        pad = lambda v, t: v + (-v) % t
        M, K, N = pad(shape.M, spec.tm), pad(shape.K, spec.tk), pad(shape.N, spec.tn)
        if kb == "w8a8":
            return (
                jnp.zeros((M, K), jnp.float32),
                jnp.zeros((K, N), jnp.int8),
                jnp.ones((1, N), jnp.float32),
            )
        a = jnp.zeros((M, K), name)
        b = jnp.zeros((K, N), name)
        if kb == "dequant":
            return (a.astype(jnp.int8), b.astype(jnp.int8),
                    jnp.ones((M, 1), jnp.float32), jnp.ones((1, N), jnp.float32))
        return (a, b)

    def _time_spec(self, kernel, args) -> float:
        kernel(*args).block_until_ready()  # compile + warm
        t0 = time.perf_counter()
        for _ in range(self.wallclock_iters):
            out = kernel(*args)
        out.block_until_ready()
        return (time.perf_counter() - t0) / self.wallclock_iters


# ---------------------------------------------------------------------------
# Process-wide default tuner + dispatch switch (consumed by kernels/ops.py)
# ---------------------------------------------------------------------------

_DEFAULT_TUNER: Optional[Autotuner] = None


def env_truthy(value: Optional[str]) -> bool:
    """Shared REPRO_AUTOTUNE parse: '0'/'false'/'no'/'' disable."""
    return (value or "").strip().lower() not in ("", "0", "false", "no", "off")


_ENABLED = env_truthy(os.environ.get("REPRO_AUTOTUNE"))


def get_tuner() -> Autotuner:
    global _DEFAULT_TUNER
    if _DEFAULT_TUNER is None:
        _DEFAULT_TUNER = Autotuner()
    return _DEFAULT_TUNER


def set_tuner(tuner: Optional[Autotuner]) -> None:
    global _DEFAULT_TUNER
    _DEFAULT_TUNER = tuner


def enable() -> None:
    """Route every spec-less `ops.gemm` call through the tuner."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def is_enabled() -> bool:
    return _ENABLED


def tuned_spec(shape: GemmShape, dtype="int8", *, backend: str = "pallas") -> TpuGemmSpec:
    """Best known spec for this problem via the default tuner."""
    return get_tuner().spec_for(shape, dtype, backend=backend)


def tuned_gemm(a, b, *, backend: Optional[str] = None, tuner: Optional[Autotuner] = None):
    """C = A @ B with the autotuned tile for (shape, dtype, backend).

    The generator-loop entry point: resolves the best `TpuGemmSpec` from the
    cache (tuning on first sight), then dispatches through `ops.gemm`.
    """
    from repro.kernels import ops

    resolved = ops._resolve(backend)
    if resolved == "xla":
        return ops.gemm(a, b, backend="xla")
    shape = GemmShape(a.shape[0], a.shape[1], b.shape[1])
    t = tuner or get_tuner()
    spec = t.spec_for(shape, a.dtype, backend=resolved)
    return ops.gemm(a, b, spec=spec, backend=resolved)
