"""PaliGemma-3B [vlm]: SigLIP patch prefix (stub) + gemma decoder, MQA.
[arXiv:2407.07726; hf]"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=257216, head_dim=256,
    tie_embeddings=True,
    prefix_len=256,                 # 16x16 SigLIP patches at 224px
    group_size=3,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, prefix_len=4, group_size=1, dtype="float32",
    )
