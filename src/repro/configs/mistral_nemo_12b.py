"""Mistral-Nemo-12B [dense]: GQA kv=8, 128k context.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128,
    rope_theta=1e6,
    group_size=4,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, group_size=1, dtype="float32",
    )
