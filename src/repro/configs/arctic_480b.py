"""Snowflake Arctic-480B [moe]: 128 experts top-2 + dense FFN residual.
[hf:Snowflake/snowflake-arctic-base; hf]"""
import dataclasses
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, head_dim=128,
    rope_theta=1e6,
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True),
    group_size=5,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, group_size=1, dtype="float32",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64, dense_residual=True),
    )
