"""Assigned-architecture registry.

Each module defines `CONFIG` (full production config, exact constants from
the assignment) and `smoke_config()` (reduced same-family config for CPU
tests).  `get(name)` / `list_archs()` are the public API; `--arch <id>` in
the launchers resolves through here.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ArchConfig

_ARCH_MODULES = {
    "whisper-medium": "repro.configs.whisper_medium",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "arctic-480b": "repro.configs.arctic_480b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    # The paper's own transformer benchmark backbones (Table 2):
    "bert-base": "repro.configs.bert_base",
    "vit-b-16": "repro.configs.vit_b_16",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return importlib.import_module(_ARCH_MODULES[name]).smoke_config()


# Shape grid assigned to the LM-family architectures.
SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# long_500k requires sub-quadratic sequence mixing; only the SSM/hybrid archs
# run it (see DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = {"jamba-1.5-large-398b", "xlstm-1.3b"}


def shapes_for(name: str) -> List[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if name in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out
