"""Jamba-1.5-Large-398B [hybrid]: Mamba+attention 1:7 interleave, MoE 16e
top-2, GQA kv=8.  [arXiv:2403.19887; hf]"""
import dataclasses
from repro.models.config import ArchConfig, MambaConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536, head_dim=128,
    attn_every=8,                   # 1 attention layer per 8 (1:7 with Mamba)
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576),
    moe_every=2,                    # MoE on alternate layers (Jamba)
    group_size=8,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, attn_every=4, group_size=4, dtype="float32",
        mamba=MambaConfig(d_state=4, d_conv=4, expand=2),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128), moe_every=2,
    )
