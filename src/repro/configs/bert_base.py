"""BERT-base: the paper's Table-2 transformer benchmark (encoder-only).
Modeled as a non-causal dense LM backbone for framework integration."""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="bert-base", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=30522, head_dim=64,
    mlp_variant="gelu", norm="ln",
    group_size=2,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, group_size=1, dtype="float32",
    )
