"""Whisper-medium [audio]: 24+24 layer encoder-decoder, d_model=1024, 16
heads (kv=16, i.e. MHA), GeLU MLP, LayerNorm; conv frontend is a STUB
(input_specs feeds precomputed 1500-frame embeddings).
[arXiv:2212.04356; unverified]"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, head_dim=64,
    mlp_variant="gelu", norm="ln",
    encoder_layers=24, encoder_seq=1500,
    group_size=4,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, encoder_layers=2, encoder_seq=16,
        group_size=1, dtype="float32",
    )
