"""xLSTM-1.3B [ssm]: mLSTM blocks with sLSTM every 8th (7:1), d_ff=0 (the
blocks carry their own projections).  [arXiv:2405.04517; unverified]"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_every=8,
    group_size=8,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        vocab=256, slstm_every=2, group_size=2, dtype="float32",
    )
