"""Gemma3-1B [dense]: GQA kv=1 (MQA), 5:1 local:global sliding window,
tied embeddings, 262k vocab.  [hf:google/gemma-3-1b-pt; unverified]"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab=262144, head_dim=256,
    local_window=512, local_ratio=5, rope_theta=1e6,
    post_block_norm=True, tie_embeddings=True,
    # 26 layers scanned as 2 groups of 13; the 5:1 local:global cadence is
    # approximated per group (globals at in-group positions 6 and 12 -> 4
    # global layers per 26, matching the 5:1 ratio; the exact phase shifts
    # by one at the group boundary).
    group_size=13,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, local_window=8, group_size=6, dtype="float32",
    )
