"""DBRX-132B [moe]: 16 experts top-4 fine-grained, GQA kv=8.
[hf:databricks/dbrx-base; unverified]"""
import dataclasses
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, head_dim=128,
    rope_theta=5e5,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
    group_size=4,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, group_size=1, dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
    )
