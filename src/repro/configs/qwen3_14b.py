"""Qwen3-14B [dense]: qk_norm, GQA kv=8.  [hf:Qwen/Qwen3-8B family; hf]"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    group_size=4,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, group_size=1, dtype="float32",
    )
