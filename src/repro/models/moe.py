"""Mixture-of-Experts: top-k routing with capacity-bounded scatter dispatch.

Dispatch strategy (compile-friendly at 128 experts x 1M tokens):
  * router logits -> top_k -> softmax over the selected experts,
  * position-in-expert via a cumulative sum over the one-hot assignment,
  * tokens scattered into a (E, capacity, d) buffer (drops beyond capacity),
  * expert FFNs run as one batched einsum over the expert dimension (sharded
    expert-parallel on the "model" mesh axis),
  * results gathered back and combined with routing weights.

Arctic's dense-residual variant runs a small dense FFN in parallel and sums.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel.logical import shard


def init_moe(key, cfg):
    mc = cfg.moe
    d, E, ffe = cfg.d_model, mc.num_experts, mc.d_ff_expert
    ks = jax.random.split(key, 5)
    dt = cfg.jax_dtype
    scale = d ** -0.5
    p = {
        "router": layers._init_dense(ks[0], d, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, ffe)) * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, ffe)) * scale).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, ffe, d)) * ffe ** -0.5).astype(dt),
    }
    if mc.dense_residual:
        p["dense"] = layers.init_mlp(ks[4], d, cfg.d_ff, "swiglu", dt)
    return p


def _capacity(tokens: int, cfg) -> int:
    mc = cfg.moe
    c = int(tokens * mc.top_k / mc.num_experts * mc.capacity_factor)
    return max(8, -(-c // 8) * 8)


def moe_block(x: jax.Array, p, cfg, *, quant: Optional[str] = None) -> jax.Array:
    B, S, d = x.shape
    mc = cfg.moe
    E, k = mc.num_experts, mc.top_k
    T = B * S
    C = _capacity(T, cfg)

    x2 = x.reshape(T, d)
    logits = layers.dense(x2.astype(jnp.float32), p["router"])       # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(logits, k)                 # (T, k)
    weights = jax.nn.softmax(gate_vals, axis=-1)                     # (T, k)

    # Flatten (token, slot) pairs; earlier tokens win capacity slots.
    flat_e = expert_idx.reshape(T * k)                               # (T*k,)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)                  # (T*k, E)
    pos = jnp.cumsum(oh, axis=0) - oh                                # pre-count
    pos_in_e = jnp.sum(pos * oh, axis=-1)                            # (T*k,)
    keep = pos_in_e < C
    # Dropped pairs go to a sacrificial slot C (buffer has C+1 rows).
    slot = jnp.where(keep, pos_in_e, C)

    buf = jnp.zeros((E, C + 1, d), x.dtype)
    token_ids = jnp.repeat(jnp.arange(T), k)
    buf = buf.at[flat_e, slot].add(x2[token_ids])
    buf = shard(buf, "expert", None, None)[:, :C]                    # (E, C, d)

    # Expert FFNs (SwiGLU), batched over E.
    bf = buf.astype(jnp.float32)
    gate = jnp.einsum("ecd,edf->ecf", bf, p["w_gate"].astype(jnp.float32))
    up = jnp.einsum("ecd,edf->ecf", bf, p["w_up"].astype(jnp.float32))
    h = jax.nn.silu(gate) * up
    h = shard(h.astype(x.dtype), "expert", None, "mlp")
    out_buf = jnp.einsum("ecf,efd->ecd", h.astype(jnp.float32),
                         p["w_down"].astype(jnp.float32))            # (E, C, d)
    out_buf = shard(out_buf.astype(x.dtype), "expert", None, None)

    # Gather back and combine with routing weights (dropped -> zero).
    out_pairs = out_buf[flat_e, jnp.minimum(slot, C - 1)]            # (T*k, d)
    out_pairs = jnp.where(keep[:, None], out_pairs, 0)
    w_pairs = weights.reshape(T * k, 1).astype(out_pairs.dtype)
    y = jnp.zeros((T, d), out_pairs.dtype).at[token_ids].add(out_pairs * w_pairs)
    y = y.reshape(B, S, d).astype(x.dtype)

    if mc.dense_residual:
        y = y + layers.mlp(x, p["dense"], "swiglu", quant=quant)
    return shard(y, "batch", "seq", "embed")


def aux_load_balance_loss(logits: jax.Array, expert_idx: jax.Array, E: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (exposed for training)."""
    probs = jax.nn.softmax(logits, axis=-1)                          # (T, E)
    frac_tokens = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac_tokens * frac_probs)
