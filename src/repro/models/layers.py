"""Elementary layers: norms, embeddings, RoPE, MLPs.

Functional style: `init_*` returns a params pytree, `apply` functions are
pure.  Every dense projection routes through repro.kernels.ops.linear, so the
OpenGeMM kernel (and its int8 deployment mode) underlies the whole zoo.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.parallel.logical import shard


def _init_dense(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
          *, quant: Optional[str] = None) -> jax.Array:
    y = ops.linear(x, w, quant=quant)
    if b is not None:
        y = y + b
    return y


# -- norms -------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(x: jax.Array, p, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


# -- embeddings ---------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    x = jnp.take(table, tokens, axis=0)
    return shard(x, "batch", "seq", "embed")


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Logits = x @ table^T (tied) — table is (vocab, d)."""
    logits = ops.linear(x, table.T.astype(x.dtype))
    return shard(logits, "batch", "seq", "vocab")


# -- rotary position embedding -------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, H, D), positions: (B, S) or (S,)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- feed-forward ---------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, variant: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if variant == "swiglu":
        return {
            "w_gate": _init_dense(k1, d, d_ff, dtype),
            "w_up": _init_dense(k2, d, d_ff, dtype),
            "w_down": _init_dense(k3, d_ff, d, dtype),
        }
    if variant == "gelu":
        return {
            "w_up": _init_dense(k1, d, d_ff, dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "w_down": _init_dense(k2, d_ff, d, dtype),
            "b_down": jnp.zeros((d,), dtype),
        }
    raise ValueError(variant)


def mlp(x: jax.Array, p, variant: str, *, quant: Optional[str] = None) -> jax.Array:
    if variant == "swiglu":
        gate = dense(x, p["w_gate"], quant=quant)
        up = dense(x, p["w_up"], quant=quant)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
        h = shard(h, "batch", "seq", "mlp")
        return dense(h, p["w_down"], quant=quant)
    h = dense(x, p["w_up"], p["b_up"], quant=quant)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", "seq", "mlp")
    return dense(h, p["w_down"], p["b_down"], quant=quant)
