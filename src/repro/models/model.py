"""Model assembly: decoder LMs, hybrid/SSM LMs, encoder-decoder (whisper),
and prefix-LM VLM (paligemma), with scan-over-groups execution, KV/SSM decode
caches and the training loss.

Public API:
  init_model(key, cfg)                         -> params
  forward(params, cfg, batch)                  -> logits        (train/prefill)
  loss_fn(params, cfg, batch)                  -> scalar loss
  init_decode_state(params, cfg, batch, seq)   -> DecodeState
  decode_step(params, cfg, state, tokens)      -> (logits, DecodeState)

`batch` dict keys: "tokens" (B, S) int32 always; "frames" (B, S_enc, d) for
encdec (audio frontend stub); "patches" (B, P, d_vision) for vlm.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import blocks, layers
from repro.models.config import ArchConfig
from repro.parallel.logical import shard

VISION_DIM = 1152  # SigLIP-so400m width (paligemma stub frontend)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_model(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    dt = cfg.jax_dtype
    params: Dict[str, Any] = {
        "embed": layers.init_embedding(ks[0], cfg.vocab, cfg.d_model, dt),
        "final_norm": blocks._init_norm(cfg),
    }
    gkeys = jax.random.split(ks[1], cfg.n_groups)
    cross = cfg.family == "encdec"
    params["blocks"] = jax.vmap(
        lambda k: blocks.init_group(k, cfg, cross_attention=cross)
    )(gkeys)
    if not cfg.tie_embeddings:
        params["head"] = layers._init_dense(ks[2], cfg.d_model, cfg.vocab, dt)
    if cfg.family == "encdec":
        ekeys = jax.random.split(ks[3], cfg.encoder_layers)
        enc_cfg = cfg  # same width; encoder blocks are non-causal, no cross
        params["encoder_blocks"] = jax.vmap(
            lambda k: blocks.init_block(k, enc_cfg, "attn")
        )(ekeys)
        params["encoder_norm"] = blocks._init_norm(cfg)
    if cfg.family == "vlm":
        params["projector"] = layers._init_dense(ks[4], VISION_DIM, cfg.d_model, dt)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _run_groups(x, gparams, cfg, *, positions, causal=True, prefix_len=0,
                encoder_out=None):
    def body(h, gp):
        h, _ = blocks.apply_group(
            h, gp, cfg, positions=positions, causal=causal,
            prefix_len=prefix_len, encoder_out=encoder_out,
        )
        return h, None

    if cfg.remat:
        # Activation checkpointing at group granularity: backward recomputes
        # inside a group, activation memory stays O(n_groups * group I/O).
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, gparams)
    return x


def _run_encoder(frames, params, cfg):
    """Whisper encoder over stubbed conv-frontend frame embeddings."""
    x = frames.astype(cfg.jax_dtype)
    positions = jnp.arange(x.shape[1])

    def body(h, bp):
        h, _ = blocks.apply_block(h, bp, cfg, "attn", positions=positions, causal=False)
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder_blocks"])
    return blocks._norm(x, params["encoder_norm"], cfg)


def forward(
    params, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
    last_only: bool = False,
) -> jax.Array:
    """Logits for the whole sequence, or only the final position when
    `last_only` (serving prefill: the (B, S, vocab) tensor at 32k x 262k
    vocab is ~TBs and is never needed — only the next-token logits are)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = layers.embed(tokens, params["embed"])
    if cfg.tie_embeddings:
        # Gemma-style embedding scaling balances tied input/output tables.
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    prefix_len = 0
    encoder_out = None
    positions = jnp.arange(S)

    if cfg.family == "vlm":
        prefix = layers.dense(batch["patches"].astype(cfg.jax_dtype), params["projector"])
        x = jnp.concatenate([prefix, x], axis=1)
        prefix_len = prefix.shape[1]
        positions = jnp.arange(x.shape[1])
    elif cfg.family == "encdec":
        encoder_out = _run_encoder(batch["frames"], params, cfg)

    x = shard(x, "batch", "seq", "embed")
    x = _run_groups(
        x, params["blocks"], cfg, positions=positions,
        prefix_len=prefix_len, encoder_out=encoder_out,
    )
    x = blocks._norm(x, params["final_norm"], cfg)
    if cfg.family == "vlm":
        x = x[:, prefix_len:]
    if last_only:
        x = x[:, -1:]
    logits = _unembed(x, params, cfg)
    return logits


def _unembed(x, params, cfg):
    # "head_q" is the int8-resident copy of the tied embedding table that
    # quant.quantize_params adds for serving: without it, a tied-head model
    # in w8a8 mode would re-quantize the (vocab x d) table every decode step.
    if "head_q" in params:
        logits = layers.dense(x, params["head_q"])
        return shard(logits, "batch", "seq", "vocab")
    if cfg.tie_embeddings:
        logits = layers.unembed(x, params["embed"])
    else:
        logits = layers.dense(x, params["head"])
        logits = shard(logits, "batch", "seq", "vocab")
    return logits


def trunk(params, cfg: ArchConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    """Final hidden states (B, S, d) before the unembedding."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = layers.embed(tokens, params["embed"])
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    prefix_len = 0
    encoder_out = None
    positions = jnp.arange(S)
    if cfg.family == "vlm":
        prefix = layers.dense(batch["patches"].astype(cfg.jax_dtype), params["projector"])
        x = jnp.concatenate([prefix, x], axis=1)
        prefix_len = prefix.shape[1]
        positions = jnp.arange(x.shape[1])
    elif cfg.family == "encdec":
        encoder_out = _run_encoder(batch["frames"], params, cfg)
    x = shard(x, "batch", "seq", "embed")
    x = _run_groups(
        x, params["blocks"], cfg, positions=positions,
        prefix_len=prefix_len, encoder_out=encoder_out,
    )
    x = blocks._norm(x, params["final_norm"], cfg)
    if cfg.family == "vlm":
        x = x[:, prefix_len:]
    return x


def loss_fn(
    params, cfg: ArchConfig, batch: Dict[str, jax.Array], *, chunk: int = 512,
) -> jax.Array:
    """Next-token cross-entropy, computed over sequence chunks.

    The (B, S, vocab) f32 logits of a 262k-vocab model at 4k tokens are
    ~4.3 GB per sequence; chunking the unembedding + softmax (with remat on
    the chunk body) keeps loss memory O(B * chunk * vocab) regardless of S.
    """
    x = trunk(params, cfg, batch)                       # (B, S, d)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    B, S, _ = x.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    def chunk_loss(_, xs):
        xc, lc, mc = xs                                 # (B, chunk, .) each
        logits = _unembed(xc, params, cfg).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0]
        return None, (jnp.sum(ll * mc), jnp.sum(mc))

    resh = lambda t: jnp.moveaxis(
        t.reshape(t.shape[0], n_chunks, chunk, *t.shape[2:]), 1, 0)
    _, (lls, ms) = jax.lax.scan(
        jax.checkpoint(chunk_loss), None, (resh(x), resh(labels), resh(mask))
    )
    return -jnp.sum(lls) / jnp.maximum(jnp.sum(ms), 1.0)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    caches: Any                   # per-group tuple-of-kind states (stacked)
    cross_caches: Any             # encdec only
    index: jax.Array              # current position (scalar int32)


def init_decode_state(
    params, cfg: ArchConfig, batch: int, max_seq: int,
    encoder_out: Optional[jax.Array] = None,
) -> DecodeState:
    kinds = cfg.layer_kinds()

    def make_group(_):
        return tuple(
            blocks.init_cache_for_kind(cfg, kind, batch, max_seq) for kind in kinds
        )

    caches = jax.vmap(make_group)(jnp.arange(cfg.n_groups))
    cross = None
    if cfg.family == "encdec":
        assert encoder_out is not None

        def make_cross(gp):
            out = []
            for i in range(cfg.group_size):
                p = gp[f"sub{i}"]["cross"]
                hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
                k = layers.dense(encoder_out, p["wk"]).reshape(
                    batch, -1, hkv, hd)
                v = layers.dense(encoder_out, p["wv"]).reshape(
                    batch, -1, hkv, hd)
                out.append(attn_lib.KVCache(k, v))
            return tuple(out)

        cross = jax.vmap(lambda g: make_cross(g))(params["blocks"])
    return DecodeState(caches=caches, cross_caches=cross, index=jnp.zeros((), jnp.int32))


def decode_step(
    params, cfg: ArchConfig, state: DecodeState, tokens: jax.Array,
) -> Tuple[jax.Array, DecodeState]:
    """One token for every sequence: tokens (B, 1) -> logits (B, 1, vocab)."""
    B = tokens.shape[0]
    x = _embed_tokens(params, cfg, tokens)
    positions = state.index[None] + jnp.zeros((B, 1), jnp.int32)

    if state.cross_caches is None:
        x, new_caches = _trunk_step(
            params, cfg, x, positions, state.caches, state.index, None)
    else:

        def body(h, xs):
            gp, gcache, gcross = xs
            h, new_caches = blocks.apply_group(
                h, gp, cfg, positions=positions, causal=True,
                caches=gcache, cache_index=state.index, cross_caches=gcross,
            )
            return h, new_caches

        x, new_caches = jax.lax.scan(
            body, x, (params["blocks"], state.caches, state.cross_caches)
        )

    x = blocks._norm(x, params["final_norm"], cfg)
    logits = _unembed(x, params, cfg)
    new_state = DecodeState(
        caches=new_caches, cross_caches=state.cross_caches, index=state.index + 1
    )
    return logits, new_state


# ---------------------------------------------------------------------------
# paged serving: per-slot lengths, block-table KV addressing, chunked prefill
# ---------------------------------------------------------------------------

class PagedDecodeState(NamedTuple):
    """Serving decode state: shared KV block pools + per-slot request state.

    Unlike `DecodeState`'s single scalar position, every slot tracks its own
    length, so slots can be refilled mid-flight (continuous batching) without
    re-initializing anyone else's state.
    """

    caches: Any                   # per-group tuple-of-kind states (stacked);
                                  # attention kinds hold PagedKVCache pools
    block_tables: jax.Array       # (slots, max_blocks) int32 into the pool
    lengths: jax.Array            # (slots,) int32 tokens held per slot


def init_paged_decode_state(
    cfg: ArchConfig, slots: int, *, num_blocks: int, block_size: int,
    max_blocks_per_slot: int, kv_precision: str = "float",
) -> PagedDecodeState:
    if cfg.family in ("encdec", "vlm"):
        raise NotImplementedError(
            f"paged serving not wired for family {cfg.family!r}")
    kinds = cfg.layer_kinds()

    def make_group(_):
        return tuple(
            blocks.init_paged_cache_for_kind(
                cfg, kind, slots, num_blocks, block_size,
                kv_precision=kv_precision)
            for kind in kinds
        )

    caches = jax.vmap(make_group)(jnp.arange(cfg.n_groups))
    return PagedDecodeState(
        caches=caches,
        block_tables=jnp.zeros((slots, max_blocks_per_slot), jnp.int32),
        lengths=jnp.zeros((slots,), jnp.int32),
    )


def _trunk_step(params, cfg, x, positions, caches, cache_index, block_tables,
                collect_states=False):
    """Scan the block groups in decode mode; returns (hidden, new_caches)."""

    def body(h, xs):
        gp, gcache = xs
        h, new_caches = blocks.apply_group(
            h, gp, cfg, positions=positions, causal=True,
            caches=gcache, cache_index=cache_index, block_tables=block_tables,
            collect_states=collect_states,
        )
        return h, new_caches

    return jax.lax.scan(body, x, (params["blocks"], caches))


def _embed_tokens(params, cfg, tokens):
    x = layers.embed(tokens, params["embed"])
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return shard(x, "batch", "seq", "embed")


def paged_decode_step(
    params, cfg: ArchConfig, state: PagedDecodeState, tokens: jax.Array,
    active: Optional[jax.Array] = None,
) -> Tuple[jax.Array, PagedDecodeState]:
    """One token for every *active* slot at its own position: tokens (B, 1)
    -> logits (B, 1, vocab).

    `active` (B,) bool masks slots that are idle or mid-prefill while this
    decode batch runs: their lengths and recurrent states are held (the
    whole batch computes, but inactive updates are discarded), so
    interleaved prefill chunks resume exactly where they left off.  Inactive
    KV writes land at/above the slot's true length — positions the mask
    hides until a real token overwrites them — or in the null block."""
    x = _embed_tokens(params, cfg, tokens)
    positions = state.lengths[:, None]
    x, new_caches = _trunk_step(
        params, cfg, x, positions, state.caches, state.lengths,
        state.block_tables,
    )
    if active is not None:
        new_caches = _select_slots(active, new_caches, state.caches)
        new_lengths = state.lengths + active.astype(jnp.int32)
    else:
        new_lengths = state.lengths + 1
    x = blocks._norm(x, params["final_norm"], cfg)
    logits = _unembed(x, params, cfg)
    return logits, PagedDecodeState(
        caches=new_caches, block_tables=state.block_tables,
        lengths=new_lengths,
    )


def _select_slots(active, new_caches, old_caches):
    """Keep updates only for active slots.  Paged KV pools pass through —
    an inactive slot's write sits at/above its length, invisible until a
    real write replaces it — while per-slot recurrent states revert."""
    from repro.serving.kv_cache import PagedKVCache

    out = []
    for n, o in zip(new_caches, old_caches):
        if isinstance(n, PagedKVCache):
            out.append(n)
            continue

        def sel(a, b):
            mask = active.reshape((1, -1) + (1,) * (a.ndim - 2))
            return jnp.where(mask, a, b)

        out.append(jax.tree_util.tree_map(sel, n, o))
    return tuple(out)


def paged_verify_step(
    params, cfg: ArchConfig, state: PagedDecodeState, tokens: jax.Array,
    active: jax.Array, limits: jax.Array, eos: jax.Array,
) -> Tuple[jax.Array, jax.Array, PagedDecodeState]:
    """Score S drafted positions per slot in ONE paged forward pass and
    greedily accept the longest matching prefix — speculative decoding's
    batched verification.

    Where ``paged_decode_step`` issues an M=slots GEMV per token, this step
    runs every hot matmul at M = slots * S — the software analogue of the
    paper's output buffering / input pre-fetching: K sequential ticks of
    starved GEMV become one well-fed GEMM (see README §Speculative).

    Inputs per slot row:
      tokens (B, S) int32 — [last committed token, d_1 .. d_{S-1}]: the not-
        yet-consumed tail token followed by the drafter's S-1 guesses.  Rows
        with fewer real drafts pad arbitrarily and bound acceptance via
        ``limits``.
      active (B,) bool   — slots decoding this tick (others fully held).
      limits (B,) int32  — max tokens this slot may emit this tick (>= 1 for
        active slots; caps acceptance at request max_new and draft length).
      eos    (B,) int32  — per-slot EOS id, -1 for none; emission stops at
        the first EOS so host and device lengths never diverge.

    Returns (greedy (B, S) int32, n_new (B,) int32, new_state):
      greedy[i, :n_new[i]] are slot i's committed tokens this tick —
      identical to what n_new[i] successive ``paged_decode_step`` calls
      would emit under greedy decoding (token-identity is tested per
      family).  KV for all S positions is written through the block tables;
      positions at/after the new length hold rejected-draft garbage that the
      causal length mask hides until a later write replaces it (exactly the
      inactive-slot convention of ``paged_decode_step``).  Recurrent (SSM /
      xLSTM) layers cannot be masked after the fact, so their per-position
      states are collected during the pass and the state at the accepted
      position is selected — checkpoint-and-restore at token granularity,
      not KV rewind.
    """
    B, S = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    positions = state.lengths[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    x, per_pos = _trunk_step(
        params, cfg, x, positions, state.caches, state.lengths,
        state.block_tables, collect_states=True,
    )
    x = blocks._norm(x, params["final_norm"], cfg)
    logits = _unembed(x, params, cfg)                       # (B, S, vocab)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, S)

    # Greedy acceptance: drafted token i is kept iff it equals the model's
    # argmax at the previous position (given all earlier drafts, which the
    # causal mask already conditioned on); the run stops at the first miss.
    match = (tokens[:, 1:] == greedy[:, :-1]).astype(jnp.int32)   # (B, S-1)
    acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)             # drafts kept
    acc = jnp.minimum(acc, jnp.maximum(limits, 1) - 1)
    # One bonus token always falls out of the last accepted position; clamp
    # emission at the first EOS so the host never records past it.
    emit = jnp.arange(S, dtype=jnp.int32)[None, :] <= acc[:, None]
    eos_hit = (greedy == eos[:, None]) & emit
    first_eos = jnp.argmax(eos_hit, axis=1).astype(jnp.int32)
    n_new = jnp.where(jnp.any(eos_hit, axis=1), first_eos + 1, acc + 1)
    n_new = jnp.where(active, n_new, 0).astype(jnp.int32)

    sel = jnp.maximum(n_new - 1, 0)       # state after the n_new-th token
    caches = _commit_verified(active, sel, per_pos, state.caches)
    return greedy, n_new, PagedDecodeState(
        caches=caches, block_tables=state.block_tables,
        lengths=state.lengths + n_new,
    )


def _commit_verified(active, idx, per_pos_caches, old_caches):
    """Select each slot's recurrent state at its accepted position (leaves
    (G, B, S, ...) -> (G, B, ...)); inactive slots revert to their old
    state.  Paged KV pools pass through — rejected-position writes sit
    beyond the committed length, invisible until overwritten."""
    from repro.serving.kv_cache import PagedKVCache

    out = []
    for n, o in zip(per_pos_caches, old_caches):
        if isinstance(n, PagedKVCache):
            out.append(n)
            continue

        def commit(a, b):
            i = idx.reshape((1, -1, 1) + (1,) * (a.ndim - 3))
            picked = jnp.take_along_axis(a, i, axis=2)[:, :, 0]
            mask = active.reshape((1, -1) + (1,) * (picked.ndim - 2))
            return jnp.where(mask, picked, b)

        out.append(jax.tree_util.tree_map(commit, n, o))
    return tuple(out)


# ---------------------------------------------------------------------------
# Sampling: temperature / top-k / top-p with per-request on-device PRNG keys
# ---------------------------------------------------------------------------


def _adjusted_logits(logits, temperature, top_k, top_p):
    """Apply temperature / top-k / top-p to logits (..., V); the knob arrays
    broadcast over logits.shape[:-1].  Returns unnormalized log-probs with
    truncated entries at -inf — feed straight into ``jax.random.categorical``
    (softmax of the result is the sampling distribution p-tilde).

    Rows with ``temperature <= 0`` are *greedy*: they collapse to a one-hot
    0/-inf row at ``argmax(logits)``, so a categorical draw over them emits
    exactly the token the greedy decode paths would (argmax over float32 is
    exact for every pool dtype — bf16 upcasts losslessly)."""
    V = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    temperature = jnp.asarray(temperature, jnp.float32)
    top_k = jnp.asarray(top_k, jnp.int32)
    top_p = jnp.asarray(top_p, jnp.float32)
    greedy = temperature <= 0.0
    scaled = logits / jnp.where(greedy, 1.0, temperature)[..., None]
    desc = jnp.sort(scaled, axis=-1)[..., ::-1]
    # top-k: keep entries >= the kth-largest (k=0 disables). Ties at the
    # threshold all survive — harmless broadening, never exclusion.
    k = jnp.where(top_k > 0, jnp.minimum(top_k, V), V)
    kth = jnp.take_along_axis(desc, (k - 1)[..., None], axis=-1)
    keep = scaled >= kth
    # top-p (nucleus): keep the smallest prefix of the sorted distribution
    # whose mass reaches top_p.  Exclusive cumsum: a token stays while the
    # mass *before* it is < top_p, so the boundary token is always included
    # and top_p=1.0 keeps everything.
    probs = jax.nn.softmax(desc, axis=-1)
    before = jnp.cumsum(probs, axis=-1) - probs
    in_nucleus = before < top_p[..., None]
    cutoff = jnp.min(jnp.where(in_nucleus, desc, jnp.inf), axis=-1,
                     keepdims=True)
    keep = keep & (scaled >= cutoff)
    adj = jnp.where(keep, scaled, -jnp.inf)
    onehot = (jnp.arange(V, dtype=jnp.int32)[None, :].reshape(
        (1,) * (logits.ndim - 1) + (V,))
        == jnp.argmax(logits, axis=-1, keepdims=True))
    return jnp.where(greedy[..., None], jnp.where(onehot, 0.0, -jnp.inf), adj)


def _fold_keys(seeds, idx):
    """Per-element PRNG keys: fold the 0-based generated-token index into
    PRNGKey(seed).  The stream is a pure function of (seed, index) — never
    of batch composition, tick boundaries, or chunking — so a seeded
    request replays bitwise-identically whatever else the engine is
    serving.  seeds/idx share a shape; returns that shape + key tail."""
    shape = idx.shape
    flat = jax.vmap(
        lambda s, i: jax.random.fold_in(jax.random.PRNGKey(s), i)
    )(jnp.asarray(seeds, jnp.int32).reshape(-1),
      jnp.asarray(idx, jnp.int32).reshape(-1))
    return flat.reshape(shape + flat.shape[1:])


def sample_tokens(logits, seeds, gen_idx, temperature, top_k, top_p):
    """Draw one token per row from adjusted logits (..., V) using the
    per-(seed, gen_idx) key stream; greedy rows return argmax exactly."""
    adj = _adjusted_logits(logits, temperature, top_k, top_p)
    keys = _fold_keys(seeds, gen_idx)
    toks = jax.vmap(jax.random.categorical)(
        keys.reshape((-1,) + keys.shape[len(gen_idx.shape):]),
        adj.reshape(-1, adj.shape[-1]))
    return toks.reshape(adj.shape[:-1]).astype(jnp.int32)


def paged_decode_sample_step(
    params, cfg: ArchConfig, state: PagedDecodeState, tokens: jax.Array,
    active: Optional[jax.Array], temperature: jax.Array, top_k: jax.Array,
    top_p: jax.Array, seeds: jax.Array, gen_idx: jax.Array,
) -> Tuple[jax.Array, PagedDecodeState]:
    """``paged_decode_step`` + on-device sampling: returns (tokens (B,),
    new_state).  The trunk pass is byte-identical to the greedy step; only
    the head differs (sample vs host-side argmax), and greedy rows inside a
    mixed batch still emit argmax (see ``_adjusted_logits``)."""
    logits, new_state = paged_decode_step(params, cfg, state, tokens, active)
    sampled = sample_tokens(logits[:, -1], seeds, gen_idx,
                            temperature, top_k, top_p)
    return sampled, new_state


def paged_verify_sample_step(
    params, cfg: ArchConfig, state: PagedDecodeState, tokens: jax.Array,
    active: jax.Array, limits: jax.Array, eos: jax.Array,
    temperature: jax.Array, top_k: jax.Array, top_p: jax.Array,
    seeds: jax.Array, gen_idx: jax.Array,
) -> Tuple[jax.Array, jax.Array, PagedDecodeState]:
    """Speculative verification under stochastic sampling: the rejection-
    sampling analogue of ``paged_verify_step`` (same inputs + the sampling
    knob arrays; same (out (B, S), n_new (B,), state) contract).

    The drafter is deterministic (a point mass at its guess d_j), so full
    leftover-distribution rejection sampling reduces to: accept d_j with
    probability p-tilde(d_j) — a uniform draw from the position's key —
    and on the first real rejection resample from p-tilde with the rejected
    token masked out (the leftover distribution after removing the point
    mass's accepted share).  The bonus token after a fully-accepted (or
    limit-capped) run samples p-tilde unmasked, exactly like a decode tick.
    Every emitted position is therefore distributed exactly p-tilde —
    speculation changes wall-clock, not the output law.  Greedy rows
    (temperature <= 0) degenerate to the argmax accept rule of
    ``paged_verify_step``: p-tilde(d) is 0 or 1, and the masked resample
    can only land on the argmax.

    Position j consumes the uniform at key (seed, gen_idx + j), and the
    resample folds one extra step off that key — a run with the same seeds
    and drafts replays bitwise-identically, though the realized stream
    differs from the non-speculative stream for the same seed (same law,
    different draws).
    """
    B, S = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    positions = state.lengths[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    x, per_pos = _trunk_step(
        params, cfg, x, positions, state.caches, state.lengths,
        state.block_tables, collect_states=True,
    )
    x = blocks._norm(x, params["final_norm"], cfg)
    logits = _unembed(x, params, cfg)                       # (B, S, vocab)
    V = logits.shape[-1]

    bcast = lambda a: jnp.broadcast_to(jnp.asarray(a)[:, None], (B, S))
    adj = _adjusted_logits(logits, bcast(temperature), bcast(top_k),
                           bcast(top_p))
    probs = jax.nn.softmax(adj, axis=-1)                    # p-tilde
    idx = bcast(gen_idx) + jnp.arange(S, dtype=jnp.int32)[None, :]
    keys = _fold_keys(bcast(seeds), idx)                    # (B, S, key)
    u = jax.vmap(jax.random.uniform)(
        keys.reshape((-1,) + keys.shape[2:])).reshape(B, S)

    # Accept drafted token d_j (input tokens[:, j+1], scored at position j)
    # with probability p-tilde(d_j); the kept run is the capped prefix of
    # consecutive accepts, mirroring the greedy cumprod.
    drafts = tokens[:, 1:]                                  # (B, S-1)
    p_draft = jnp.take_along_axis(
        probs[:, :-1], drafts[..., None], axis=-1)[..., 0]
    accept = (u[:, :S - 1] < p_draft).astype(jnp.int32)
    acc_raw = jnp.sum(jnp.cumprod(accept, axis=1), axis=1)
    acc = jnp.minimum(acc_raw, jnp.maximum(limits, 1) - 1)

    # Position acc emits a fresh sample: with the rejected draft masked out
    # when a real rejection stopped the run (leftover distribution), or
    # unmasked when the run ended by draft/limit exhaustion (bonus token).
    rejected = (acc == acc_raw) & (acc < S - 1)
    rows = jnp.arange(B)
    key2 = jax.vmap(lambda kk: jax.random.fold_in(kk, 1))(keys[rows, acc])
    bad = tokens[rows, jnp.minimum(acc + 1, S - 1)]
    masked = jnp.where(
        rejected[:, None] & (jnp.arange(V)[None, :] == bad[:, None]),
        -jnp.inf, adj[rows, acc])
    final = jax.vmap(jax.random.categorical)(key2, masked).astype(jnp.int32)

    draft_shift = jnp.pad(drafts, ((0, 0), (0, 1)))         # (B, S)
    out = jnp.where(jnp.arange(S, dtype=jnp.int32)[None, :] < acc[:, None],
                    draft_shift, final[:, None]).astype(jnp.int32)

    emit = jnp.arange(S, dtype=jnp.int32)[None, :] <= acc[:, None]
    eos_hit = (out == eos[:, None]) & emit
    first_eos = jnp.argmax(eos_hit, axis=1).astype(jnp.int32)
    n_new = jnp.where(jnp.any(eos_hit, axis=1), first_eos + 1, acc + 1)
    n_new = jnp.where(active, n_new, 0).astype(jnp.int32)

    sel = jnp.maximum(n_new - 1, 0)
    caches = _commit_verified(active, sel, per_pos, state.caches)
    return out, n_new, PagedDecodeState(
        caches=caches, block_tables=state.block_tables,
        lengths=state.lengths + n_new,
    )


def _slice_slot_caches(caches, slot, width: int = 1):
    """Per-kind slot slice: SSM states are per-slot (axis 1 under the group
    axis); paged KV pools are shared and pass through whole."""
    from repro.serving.kv_cache import PagedKVCache

    out = []
    for c in caches:
        if isinstance(c, PagedKVCache):
            out.append(c)
        else:
            out.append(jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, width, axis=1), c))
    return tuple(out)


def _merge_slot_caches(full, part, slot):
    """Write a slot-sliced cache update back; pools come back whole."""
    from repro.serving.kv_cache import PagedKVCache

    out = []
    for f, pt in zip(full, part):
        if isinstance(f, PagedKVCache):
            out.append(pt)
        else:
            out.append(jax.tree_util.tree_map(
                lambda a, b: jax.lax.dynamic_update_slice_in_dim(
                    a, b.astype(a.dtype), slot, axis=1), f, pt))
    return tuple(out)


def prefill_chunk(
    params, cfg: ArchConfig, state: PagedDecodeState, tokens: jax.Array,
    slot: jax.Array,
) -> Tuple[jax.Array, PagedDecodeState]:
    """Advance one slot by a chunk of C prompt tokens: tokens (1, C) ->
    (last-position logits (1, 1, vocab), updated state).

    The chunk attends causally over the slot's block-table view (which the
    same step just wrote), and SSM states advance by C tokens via their
    chunked scans — C-fold fewer step dispatches than token-by-token, the
    input-prefetch/output-buffering analogue.  The LM head runs on the last
    position only (the (1, C, vocab) tensor is never needed)."""
    C = tokens.shape[1]
    start = jax.lax.dynamic_slice_in_dim(state.lengths, slot, 1)       # (1,)
    tables = jax.lax.dynamic_slice_in_dim(state.block_tables, slot, 1, axis=0)
    caches = _slice_slot_caches(state.caches, slot)
    x = _embed_tokens(params, cfg, tokens)
    positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    x, part_caches = _trunk_step(
        params, cfg, x, positions, caches, start, tables)
    x = blocks._norm(x[:, -1:], params["final_norm"], cfg)
    logits = _unembed(x, params, cfg)
    new_lengths = jax.lax.dynamic_update_slice(
        state.lengths, start + jnp.int32(C), (slot,))
    return logits, PagedDecodeState(
        caches=_merge_slot_caches(state.caches, part_caches, slot),
        block_tables=state.block_tables,
        lengths=new_lengths,
    )


def reset_slots(
    cfg: ArchConfig, state: PagedDecodeState, mask: jax.Array,
) -> PagedDecodeState:
    """Zero the recurrent state and length of every masked slot for fresh
    requests — slot refill without re-initializing the whole batch, and one
    step per admission wave however many slots it fills.  KV pages need no
    reset: freed blocks are re-written before the length mask exposes them."""
    from repro.serving.kv_cache import PagedKVCache

    kinds = cfg.layer_kinds()
    fresh = []
    for kind, cur in zip(kinds, state.caches):
        if isinstance(cur, PagedKVCache):
            fresh.append(cur)
            continue
        one = blocks.init_cache_for_kind(cfg, kind, 1, 0)   # batch-1 template

        def sel(full, init):
            m = mask.reshape((1, -1) + (1,) * (full.ndim - 2))
            return jnp.where(m, init[None].astype(full.dtype), full)

        fresh.append(jax.tree_util.tree_map(sel, cur, one))
    lengths = jnp.where(mask, 0, state.lengths)
    return PagedDecodeState(
        caches=tuple(fresh), block_tables=state.block_tables, lengths=lengths)


def prefill(
    params, cfg: ArchConfig, batch: Dict[str, jax.Array], max_seq: int,
) -> Tuple[jax.Array, DecodeState]:
    """Run the full prompt, building decode caches (serving prefill path).

    Returns (last-position logits, DecodeState ready for decode_step).
    Implemented as forward + cache construction through decode-shaped
    updates; for simplicity the caches are built by re-projecting K/V per
    group (no attention recompute).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    encoder_out = None
    if cfg.family == "encdec":
        encoder_out = _run_encoder(batch["frames"], params, cfg)
    state = init_decode_state(params, cfg, B, max_seq, encoder_out=encoder_out)
    logits = forward(params, cfg, batch)
    # Populate caches by replaying K/V projections blockwise.
    # (The dry-run lowers decode_step and forward separately; this utility is
    # for the CPU serving example, where S is small.)
    def write_token(state, t):
        logits_t, state = decode_step(params, cfg, state, tokens[:, t][:, None])
        return state, logits_t

    state, _ = jax.lax.scan(write_token, state, jnp.arange(S))
    return logits[:, -1:], state
