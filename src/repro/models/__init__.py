"""models subpackage."""
