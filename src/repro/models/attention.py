"""Attention: GQA/MQA/MHA with qk-norm, sliding windows, cross-attention,
KV-cache decode, and a memory-bounded blockwise (flash-style) prefill path.

The blockwise path scans over KV blocks with an online softmax so 32k-token
prefill never materializes the full (S, S) score matrix — required for the
``prefill_32k`` dry-run shapes to fit per-device HBM.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel.logical import shard

NEG_INF = -2.0e38


class KVCache(NamedTuple):
    """Per-layer-kind decode cache: k/v (B, S_max, H_kv, D), f32 position."""

    k: jax.Array
    v: jax.Array


def init_attention(key, cfg, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = cfg.jax_dtype
    p = {
        "wq": layers._init_dense(ks[0], d, hq * hd, dt),
        "wk": layers._init_dense(ks[1], d, hkv * hd, dt),
        "wv": layers._init_dense(ks[2], d, hkv * hd, dt),
        "wo": layers._init_dense(ks[3], hq * hd, d, dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rmsnorm(hd, dt)
        p["k_norm"] = layers.init_rmsnorm(hd, dt)
    return p


def _project_qkv(x, kv_src, p, cfg, positions, *, rope: bool = True):
    B, S, _ = x.shape
    hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = layers.dense(x, p["wq"], p.get("bq")).reshape(B, S, hq, hd)
    k = layers.dense(kv_src, p["wk"], p.get("bk")).reshape(B, kv_src.shape[1], hkv, hd)
    v = layers.dense(kv_src, p["wv"], p.get("bv")).reshape(B, kv_src.shape[1], hkv, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        kv_pos = positions if kv_src.shape[1] == S else jnp.arange(kv_src.shape[1])
        k = layers.apply_rope(k, kv_pos, cfg.rope_theta)
    # Head-TP plans shard "heads"/"kv_heads" on the model axis; seq-sharded
    # plans map "attn_seq" to it instead (and replicate heads/KV) — the same
    # annotations serve both (see ParallelPlan.attn_seq).
    q = shard(q, "batch", "attn_seq", "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    B, S, H, D = k.shape
    return jnp.repeat(k, groups, axis=2)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,
    window: Optional[int] = None,
    prefix_len: int = 0,
    block_kv: int = 1024,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Rematerialized flash-style attention: the KV-block scan's residuals
    are never saved for backward (jax.checkpoint below) — without this, a
    4k-token training step keeps O(S^2 / block) probability tensors alive
    per layer and blows per-device HBM.

    On TPU the fused Pallas kernel (kernels/flash_attention.py) takes over
    whenever its feature set suffices — it keeps scores/probabilities in
    VMEM, removing the dominant HBM-traffic term of the XLA path (see
    EXPERIMENTS.md §Perf)."""
    from repro.kernels import ops as _ops

    plain_offset = isinstance(q_offset, int) and q_offset == 0
    if (_ops._resolve(None) in ("pallas", "pipelined")
            and plain_offset and not prefix_len and softcap is None):
        from repro.kernels.flash_attention import flash_attention

        f = functools.partial(flash_attention, causal=causal, window=window)
        return jax.checkpoint(lambda a, b, c: f(a, b, c))(q, k, v)

    f = functools.partial(
        _blockwise_attention,
        causal=causal, window=window, prefix_len=prefix_len,
        block_kv=block_kv, softcap=softcap,
    )
    return jax.checkpoint(f)(q, k, v, q_offset)


def _blockwise_attention(
    q, k, v, q_offset, *, causal, window, prefix_len, block_kv, softcap,
) -> jax.Array:
    """Online-softmax attention scanning KV blocks.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D).  Masks supported:
      causal (with q_offset for caches), sliding window, bidirectional
      prefix (prefix-LM for the VLM arch).
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    groups = Hq // Hkv
    scale = D ** -0.5

    block_kv = min(block_kv, Skv)
    n_blocks = -(-Skv // block_kv)
    pad = n_blocks * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # (B, Hkv, G, Sq, D) — GQA groups kept explicit so KV is never repeated.
    # q/k/p stay in the model dtype (bf16 on TPU) as in fused flash kernels;
    # only the softmax statistics and the output accumulator are f32.
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(B, Sq, Hkv, groups, D)
    qf = qf.transpose(0, 2, 3, 1, 4)
    # KV stay in model dtype at (n_blocks, B, block, Hkv, D); each block is
    # upcast inside the scan body, so peak memory is one block, not the cache.
    kb_all = jnp.moveaxis(k.reshape(B, n_blocks, block_kv, Hkv, D), 1, 0)
    vb_all = jnp.moveaxis(v.reshape(B, n_blocks, block_kv, Hkv, D), 1, 0)

    q_pos = jnp.arange(Sq) + q_offset  # (Sq,)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, b_idx = blk
        s = jnp.einsum(
            "bhgqd,bkhd->bhgqk", qf, kb.astype(qf.dtype),
            preferred_element_type=jnp.float32,
        )  # (B, Hkv, G, Sq, block) f32 scores
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = b_idx * block_kv + jnp.arange(block_kv)  # (block,)
        mask = jnp.ones((Sq, block_kv), bool)
        if causal:
            cm = q_pos[:, None] >= kpos[None, :]
            if prefix_len:
                cm = cm | (kpos[None, :] < prefix_len)
            mask &= cm
        if window is not None:
            mask &= (q_pos[:, None] - kpos[None, :]) < window
        mask &= (kpos < Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        # p in model dtype for the PV matmul (flash-kernel convention).
        pv = jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, groups, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, groups, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, groups, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kb_all, vb_all, jnp.arange(n_blocks))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    index: jax.Array,
    window: Optional[int] = None,
    prefix_len: int = 0,
) -> jax.Array:
    """Query-over-whole-cache attention, no KV-block scan.

    `index` is the position of the first query token — a scalar (all slots
    at the same position, classic lock-step decode) or a (B,) vector (paged
    serving: every slot at its own length).  Sq may be > 1 (chunked prefill:
    query t sits at position index + t and attends causally up to itself).

    With the cache sequence-sharded on the model axis, the score einsum and
    the weighted sum stay fully local per shard; only the softmax statistics
    (B, H) reduce across shards.  The scan-based path would dynamic-slice
    the sharded cache and all-gather every block (measured 86 GB/device/token
    on dbrx decode_32k — EXPERIMENTS.md §Perf).
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    groups = Hq // Hkv
    qf = (q * jnp.asarray(D ** -0.5, q.dtype)).reshape(B, Sq, Hkv, groups, D)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qf, k, preferred_element_type=jnp.float32
    )  # (B, Hkv, G, Sq, Skv)
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (B,))
    qpos = idx[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]  # (B, Sq)
    kpos = jnp.arange(Skv)
    mask = kpos[None, None, :] <= qpos[..., None]  # (B, Sq, Skv) — past only
    if window is not None:
        mask &= (qpos[..., None] - kpos[None, None, :]) < window
    if prefix_len:
        mask |= (kpos < prefix_len)[None, None, :]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum(
        "bhgqk,bkhd->bqhgd", p_attn, v, preferred_element_type=jnp.float32
    )
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def attention(
    x: jax.Array,
    p,
    cfg,
    *,
    positions: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    kv_src: Optional[jax.Array] = None,
    cache: Optional[KVCache] = None,
    cache_index: Optional[jax.Array] = None,
    block_tables: Optional[jax.Array] = None,
):
    """Full attention sublayer.  Returns (out, new_cache).

    Prefill / training: cache is None -> blockwise attention over x itself
    (or kv_src for cross-attention).  Dense decode: cache holds
    (B, S_max, Hkv, D); x is (B, 1, d) and cache_index the scalar write
    position.  Paged decode/prefill: cache is a PagedKVCache pool,
    block_tables (B, max_blocks) addresses it, and cache_index is the (B,)
    per-slot first-token position (x may carry S > 1 chunk tokens).
    """
    cross = kv_src is not None
    src = kv_src if cross else x
    q, k, v = _project_qkv(x, src, p, cfg, positions, rope=not cross)

    if cache is not None and not cross:
        from repro.serving import kv_cache as paged

        if isinstance(cache, paged.PagedKVCache):
            # Paged decode: scatter this step's k/v through the block table,
            # then attend over the pool directly (block-table walk) — the
            # gather/blocked/flash backend choice lives in
            # kernels/flash_decode.py and binds at trace time.
            from repro.kernels import flash_decode as _fd

            assert block_tables is not None, "paged cache needs block_tables"
            new_cache = paged.write_kv(cache, block_tables, k, v, cache_index)
            out = _fd.paged_decode_attention(
                q, new_cache, block_tables, cache_index,
                window=window, prefix_len=prefix_len,
            )
        else:
            # Dense decode: append this step's k/v then attend over the cache.
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), cache_index, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), cache_index, axis=1)
            new_cache = KVCache(k_cache, v_cache)
            out = decode_attention(
                q, k_cache, v_cache, index=cache_index,
                window=window, prefix_len=prefix_len,
            )
    else:
        new_cache = None
        if cross and cache is not None:
            # Cross-attention decode reuses the precomputed encoder cache.
            k, v = cache.k, cache.v
            new_cache = cache
        out = blockwise_attention(
            q, k, v, causal=causal and not cross, window=window,
            prefix_len=prefix_len, softcap=cfg.logit_softcap,
        )

    B, Sq = x.shape[:2]
    out = shard(out, "batch", "attn_seq", "heads", None)
    out = out.reshape(B, Sq, cfg.n_heads * cfg.resolved_head_dim)
    out = layers.dense(out, p["wo"])
    return shard(out, "batch", "seq", "embed"), new_cache
