"""Architecture configuration schema for the model zoo.

One `ArchConfig` describes any of the 10 assigned architectures (plus the
paper's own ViT/BERT encoders).  The flags are the union of the features the
zoo needs: GQA, qk-norm, QKV bias, sliding-window patterns, MoE (incl. dense
residual), Mamba/attention hybrids, xLSTM blocks, encoder-decoder and
prefix-LM (VLM) wiring.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # Snowflake Arctic: dense FFN residual in parallel with the MoE FFN.
    dense_residual: bool = False


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or -(-d_model // 16)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads

    # attention flavor
    qk_norm: bool = False                   # qwen3
    qkv_bias: bool = False                  # qwen2.5
    rope_theta: float = 10_000.0
    local_window: Optional[int] = None      # sliding-window size
    local_ratio: int = 0                    # gemma3: N local layers per global
    logit_softcap: Optional[float] = None

    # ffn flavor
    mlp_variant: str = "swiglu"             # swiglu | gelu (whisper/encoders)

    # mixture of experts; MoE replaces the dense FFN on every `moe_every`-th
    # layer (Jamba: 2 -> alternate layers; DBRX/Arctic: 1 -> all layers).
    moe: Optional[MoEConfig] = None
    moe_every: int = 1

    # hybrid (jamba): one attention layer per `attn_every` layers, rest Mamba
    attn_every: int = 0
    mamba: Optional[MambaConfig] = None

    # ssm (xlstm): mLSTM blocks with one sLSTM per `slstm_every`
    slstm_every: int = 0

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0                    # frontend-stub sequence length

    # vlm prefix (paligemma)
    prefix_len: int = 0                     # image-patch prefix (stub embeds)

    # norms
    norm: str = "rms"                       # rms | ln (whisper/encoders)
    norm_eps: float = 1e-6
    post_block_norm: bool = False           # gemma-style post norms
    tie_embeddings: bool = False

    # numerics
    dtype: str = "bfloat16"
    remat: bool = True                      # activation checkpointing per group

    # layer grouping for scan-over-layers (compile-time compression)
    group_size: int = 1

    def __post_init__(self):
        if self.n_heads % max(1, self.n_kv_heads):
            raise ValueError("n_heads must be divisible by n_kv_heads")
        if self.family == "hybrid" and not (self.attn_every and self.mamba):
            raise ValueError("hybrid needs attn_every and mamba config")
        if self.local_ratio and not self.local_window:
            raise ValueError("local_ratio needs local_window")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jax_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def n_groups(self) -> int:
        if self.n_layers % self.group_size:
            raise ValueError(
                f"{self.name}: n_layers {self.n_layers} not divisible by "
                f"group_size {self.group_size}"
            )
        return self.n_layers // self.group_size

    def layer_kinds(self) -> Tuple[str, ...]:
        """Sub-layer kinds inside one scanned group, in execution order.

        'attn' | 'attn_local' | 'mamba' | 'mlstm' | 'slstm' — each is
        followed by its FFN (if d_ff > 0).
        """
        kinds = []
        for i in range(self.group_size):
            if self.family in ("ssm",):
                # xLSTM: one sLSTM per slstm_every, rest mLSTM.
                if self.slstm_every and (i + 1) % self.slstm_every == 0:
                    kinds.append("slstm")
                else:
                    kinds.append("mlstm")
            elif self.family == "hybrid":
                # Jamba: attention once per attn_every, rest Mamba.
                kinds.append("attn" if (i + 1) % self.attn_every == 0 else "mamba")
            elif self.local_ratio:
                # Gemma3: local_ratio local layers then one global.
                kinds.append(
                    "attn" if (i + 1) % (self.local_ratio + 1) == 0 else "attn_local"
                )
            else:
                kinds.append("attn")
        return tuple(kinds)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        d, v = self.d_model, self.vocab
        hd = self.resolved_head_dim
        n = v * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        ffn = 3 * d * self.d_ff if self.mlp_variant == "swiglu" else 2 * d * self.d_ff
        moe = 0
        if self.moe:
            moe = (
                d * self.moe.num_experts
                + self.moe.num_experts * 3 * d * self.moe.d_ff_expert
            )
            if self.moe.dense_residual:
                moe += ffn

        def ffn_params(layer_idx: int) -> int:
            if self.moe and (layer_idx + 1) % self.moe_every == 0:
                return moe
            return ffn if self.d_ff else 0

        mixer = {}
        mixer["attn"] = mixer["attn_local"] = attn
        if self.mamba:
            di = self.mamba.expand * d
            dtr = self.mamba.resolved_dt_rank(d)
            mixer["mamba"] = (
                d * 2 * di + self.mamba.d_conv * di
                + di * (dtr + 2 * self.mamba.d_state) + dtr * di
                + di * self.mamba.d_state + di + di * d
            )
        if self.family == "ssm":
            # xLSTM blocks: in/out projections + gates, no separate FFN.
            di = 2 * d
            mixer["mlstm"] = d * 2 * di + 4 * di * hd + di * d + 3 * di
            mixer["slstm"] = 4 * d * d + int(8 / 3 * d * d) * 2
        kinds = self.layer_kinds()
        per_group = sum(
            mixer[k] + (ffn_params(i) if k not in ("mlstm", "slstm") else 0)
            for i, k in enumerate(kinds)
        )
        n += self.n_groups * per_group
        if self.encoder_layers:
            n += self.encoder_layers * (attn + ffn + attn)  # enc + cross-attn
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top_k experts)."""
        if not self.moe:
            return self.param_count()
        total = self.param_count()
        expert_p = self.moe.num_experts * 3 * self.d_model * self.moe.d_ff_expert
        active_p = self.moe.top_k * 3 * self.d_model * self.moe.d_ff_expert
        n_moe_layers = self.n_layers // self.moe_every
        return total - n_moe_layers * (expert_p - active_p)
