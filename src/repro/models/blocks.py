"""Transformer/hybrid blocks: one mixer (attention | mamba | mLSTM | sLSTM)
plus its FFN/MoE, with pre- (and optionally post-) norms.

Blocks are grouped into `cfg.group_size`-layer groups whose parameters are
stacked along a leading axis and executed under `jax.lax.scan` (model.py) —
compile time stays O(group) instead of O(layers), which is what makes the
35-72 layer production configs lowerable in minutes on the CPU dry-run.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers, moe as moe_lib, ssm


def _init_norm(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "ln":
        return layers.init_layernorm(d, cfg.jax_dtype)
    return layers.init_rmsnorm(d, cfg.jax_dtype)


def _norm(x, p, cfg):
    if cfg.norm == "ln":
        return layers.layer_norm(x, p, cfg.norm_eps)
    return layers.rms_norm(x, p, cfg.norm_eps)


def _layer_uses_moe(cfg, layer_idx: int) -> bool:
    return cfg.moe is not None and (layer_idx + 1) % cfg.moe_every == 0


def init_block(key, cfg, kind: str, *, layer_idx: int = 0,
               cross_attention: bool = False):
    ks = jax.random.split(key, 6)
    p: dict = {"norm1": _init_norm(cfg)}
    if kind in ("attn", "attn_local"):
        p["mixer"] = attn_lib.init_attention(ks[0], cfg)
    elif kind == "mamba":
        p["mixer"] = ssm.init_mamba(ks[0], cfg)
    elif kind == "mlstm":
        p["mixer"] = ssm.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["mixer"] = ssm.init_slstm(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cross_attention:
        p["norm_cross"] = _init_norm(cfg)
        p["cross"] = attn_lib.init_attention(ks[1], cfg, cross=True)
    # xLSTM blocks carry their own FFN (d_ff == 0); others get MLP or MoE.
    if kind in ("attn", "attn_local", "mamba") and (cfg.d_ff or cfg.moe):
        p["norm2"] = _init_norm(cfg)
        if _layer_uses_moe(cfg, layer_idx):
            p["ffn"] = moe_lib.init_moe(ks[2], cfg)
        else:
            p["ffn"] = layers.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_variant, cfg.jax_dtype)
    if cfg.post_block_norm:
        p["post_norm1"] = _init_norm(cfg)
        if "ffn" in p:
            p["post_norm2"] = _init_norm(cfg)
    return p


def apply_block(
    x: jax.Array,
    p,
    cfg,
    kind: str,
    *,
    positions: jax.Array,
    causal: bool = True,
    prefix_len: int = 0,
    cache: Optional[Any] = None,
    cache_index: Optional[jax.Array] = None,
    encoder_out: Optional[jax.Array] = None,
    cross_cache: Optional[attn_lib.KVCache] = None,
    block_tables: Optional[jax.Array] = None,
    collect_states: bool = False,
) -> Tuple[jax.Array, Any]:
    """Returns (x, new_mixer_cache).  cache is the mixer state (KV / SSM).

    ``collect_states`` asks recurrent mixers for per-position states (an
    extra (S,) axis on every state leaf) instead of the final state —
    speculative verification selects the state at the accepted position.
    Attention kinds ignore it (the paged KV pool is positional already).
    """
    h = _norm(x, p["norm1"], cfg)
    if kind in ("attn", "attn_local"):
        window = cfg.local_window if kind == "attn_local" else None
        h, new_cache = attn_lib.attention(
            h, p["mixer"], cfg, positions=positions, causal=causal,
            window=window, prefix_len=prefix_len, cache=cache,
            cache_index=cache_index, block_tables=block_tables,
        )
    elif kind == "mamba":
        h, new_cache = ssm.mamba_block(h, p["mixer"], cfg, state=cache,
                                       collect_states=collect_states)
    elif kind == "mlstm":
        h, new_cache = ssm.mlstm_block(h, p["mixer"], cfg, state=cache,
                                       collect_states=collect_states)
    elif kind == "slstm":
        h, new_cache = ssm.slstm_block(h, p["mixer"], cfg, state=cache,
                                       collect_states=collect_states)
    else:
        raise ValueError(kind)
    if cfg.post_block_norm:
        h = _norm(h, p["post_norm1"], cfg)
    x = x + h

    if "cross" in p:
        h = _norm(x, p["norm_cross"], cfg)
        h, _ = attn_lib.attention(
            h, p["cross"], cfg, positions=positions, causal=False,
            kv_src=encoder_out if cross_cache is None else h,  # decode: cache
            cache=cross_cache, cache_index=None,
        )
        x = x + h

    if "ffn" in p:
        h = _norm(x, p["norm2"], cfg)
        if "router" in p["ffn"]:
            h = moe_lib.moe_block(h, p["ffn"], cfg)
        else:
            h = layers.mlp(h, p["ffn"], cfg.mlp_variant)
        if cfg.post_block_norm:
            h = _norm(h, p["post_norm2"], cfg)
        x = x + h
    return x, new_cache


def init_group(key, cfg, *, cross_attention: bool = False):
    """Parameters for one scanned group: dict sub0..sub{G-1}."""
    kinds = cfg.layer_kinds()
    ks = jax.random.split(key, len(kinds))
    return {
        f"sub{i}": init_block(
            ks[i], cfg, kind, layer_idx=i, cross_attention=cross_attention
        )
        for i, kind in enumerate(kinds)
    }


def apply_group(
    x, gp, cfg, *, positions, causal=True, prefix_len=0,
    caches=None, cache_index=None, encoder_out=None, cross_caches=None,
    block_tables=None, collect_states=False,
):
    """Apply one group of cfg.group_size blocks; returns (x, new_caches)."""
    kinds = cfg.layer_kinds()
    new_caches = []
    for i, kind in enumerate(kinds):
        x, nc = apply_block(
            x, gp[f"sub{i}"], cfg, kind,
            positions=positions, causal=causal, prefix_len=prefix_len,
            cache=None if caches is None else caches[i],
            cache_index=cache_index,
            encoder_out=encoder_out,
            cross_cache=None if cross_caches is None else cross_caches[i],
            block_tables=block_tables,
            collect_states=collect_states,
        )
        new_caches.append(nc)
    return x, tuple(new_caches)


def init_cache_for_kind(cfg, kind: str, batch: int, max_seq: int):
    """Decode-state template for one block of the given kind."""
    if kind in ("attn", "attn_local"):
        hd = cfg.resolved_head_dim
        shape = (batch, max_seq, cfg.n_kv_heads, hd)
        return attn_lib.KVCache(
            k=jnp.zeros(shape, cfg.jax_dtype), v=jnp.zeros(shape, cfg.jax_dtype)
        )
    if kind == "mamba":
        return ssm.init_mamba_state(cfg, batch)
    if kind == "mlstm":
        return ssm.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return ssm.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def init_paged_cache_for_kind(
    cfg, kind: str, batch: int, num_blocks: int, block_size: int,
    kv_precision: str = "float",
):
    """Paged-serving decode state: attention kinds get a shared block pool
    (no per-slot KV allocation — the point of paging); SSM kinds keep their
    O(1) per-slot state.  `kv_precision="int8"` makes the pool int8-resident
    with per-(block, position, head) scales (see serving/kv_cache.py)."""
    from repro.serving import kv_cache as paged

    if kind in ("attn", "attn_local"):
        return paged.init_paged_kv(
            num_blocks, block_size, cfg.n_kv_heads, cfg.resolved_head_dim,
            cfg.jax_dtype, kv_precision=kv_precision,
        )
    return init_cache_for_kind(cfg, kind, batch, 0)
