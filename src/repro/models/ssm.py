"""State-space / recurrent blocks: Mamba (Jamba's 7/8 layers) and xLSTM
(mLSTM matrix-memory + sLSTM scalar-memory blocks).

Training/prefill uses a chunked scan: a `lax.scan` over sequence chunks with
an associative scan inside each chunk, so activation memory is
O(B * chunk * d_inner * d_state) instead of O(B * S * ...).  Decode is a
single O(1) state update — this is why the ``long_500k`` shape runs for the
SSM/hybrid architectures and is skipped for full attention.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel.logical import shard


# ---------------------------------------------------------------------------
# Mamba (selective SSM, v1 parameterization)
# ---------------------------------------------------------------------------

class MambaState(NamedTuple):
    h: jax.Array          # (B, d_inner, d_state) SSM state
    conv: jax.Array       # (B, d_conv - 1, d_inner) causal-conv tail


def init_mamba(key, cfg):
    d = cfg.d_model
    mc = cfg.mamba
    di = mc.expand * d
    dtr = mc.resolved_dt_rank(d)
    ks = jax.random.split(key, 6)
    dt = cfg.jax_dtype
    return {
        "w_in": layers._init_dense(ks[0], d, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (mc.d_conv, di)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "w_x": layers._init_dense(ks[2], di, dtr + 2 * mc.d_state, dt),
        "w_dt": layers._init_dense(ks[3], dtr, di, dt),
        "b_dt": jnp.zeros((di,), jnp.float32),
        # S4D-real init: A_log = log(1..d_state), broadcast over channels.
        "A_log": jnp.log(jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": layers._init_dense(ks[4], di, d, dt),
    }


def _mamba_inner(x_in, p, cfg):
    """Shared projections: returns (dA, dBx, C, x_conv) per token."""
    mc = cfg.mamba
    dtr = mc.resolved_dt_rank(cfg.d_model)
    # quant="none": the dt/B/C projections feed exp() in the selective-scan
    # discretization — int8 noise there compounds through the recurrence, so
    # they opt out of the w8a8 precision mode (quant/modes.py).
    xdb = layers.dense(x_in, p["w_x"], quant="none").astype(jnp.float32)
    dt, B_ssm, C_ssm = jnp.split(xdb, [dtr, dtr + mc.d_state], axis=-1)
    dt = jax.nn.softplus(
        layers.dense(dt.astype(x_in.dtype), p["w_dt"], quant="none").astype(jnp.float32)
        + p["b_dt"]
    )  # (..., di)
    A = -jnp.exp(p["A_log"])  # (di, ds)
    dA = jnp.exp(dt[..., None] * A)                     # (..., di, ds)
    dBx = dt[..., None] * B_ssm[..., None, :] * x_in.astype(jnp.float32)[..., None]
    return dA, dBx, C_ssm


def mamba_block(
    x: jax.Array,
    p,
    cfg,
    *,
    state: Optional[MambaState] = None,
    chunk: int = 16,
    collect_states: bool = False,
) -> Tuple[jax.Array, Optional[MambaState]]:
    """x: (B, S, d) -> (B, S, d).

    state is None -> training/prefill-from-scratch (no state returned).
    state given, S == 1 -> decode: one O(1) update.
    state given, S > 1  -> chunked prefill: advance the carried state by S
    tokens with the chunked selective scan (conv context and h both resume
    from the state), returning the updated state.

    ``collect_states`` (requires a carried state) returns a MambaState with
    an extra position axis — h/conv *after every token* (B, S, ...) — so
    speculative verification can restore the state at any accepted position.
    """
    B, S, d = x.shape
    mc = cfg.mamba
    di = mc.expand * d
    xz = layers.dense(x, p["w_in"])
    x_in, z = jnp.split(xz, 2, axis=-1)         # (B, S, di) each
    x_in = shard(x_in, "batch", "seq", "mlp")

    if state is not None and S == 1 and not collect_states:
        # --- decode: O(1) update --------------------------------------------
        conv_ctx = jnp.concatenate([state.conv, x_in.astype(state.conv.dtype)], axis=1)
        w = p["conv_w"].astype(jnp.float32)     # (dc, di)
        xc = jnp.einsum("btd,td->bd", conv_ctx.astype(jnp.float32), w) + p["conv_b"].astype(jnp.float32)
        xc = jax.nn.silu(xc)[:, None, :].astype(x.dtype)            # (B, 1, di)
        dA, dBx, C_ssm = _mamba_inner(xc, p, cfg)
        h = state.h * dA[:, 0] + dBx[:, 0]                           # (B, di, ds)
        y = jnp.einsum("bds,bs->bd", h, C_ssm[:, 0])[:, None, :]
        y = y + p["D"] * xc.astype(jnp.float32)
        new_state = MambaState(h=h, conv=conv_ctx[:, 1:])
        out = layers.dense(
            (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype), p["w_out"]
        )
        return shard(out, "batch", "seq", "embed"), new_state

    # --- training / prefill: chunked selective scan --------------------------
    # The causal-conv context and the SSM state h resume from `state` when
    # given (chunked prefill), and start at zero otherwise.
    dc = mc.d_conv
    tail = (state.conv if state is not None
            else jnp.zeros((B, dc - 1, di), x_in.dtype))
    xp = jnp.concatenate([tail.astype(x_in.dtype), x_in], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    xc = sum(
        xp[:, i : i + S].astype(jnp.float32) * w[i] for i in range(dc)
    ) + p["conv_b"].astype(jnp.float32)
    xc = jax.nn.silu(xc).astype(x.dtype)        # (B, S, di)

    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    def chunk_body(h, xc_c):
        # Discretization (dt/B/C projections, exp) fused INTO the chunk body:
        # the (B, chunk, di, d_state) tensors exist one chunk at a time
        # instead of O(S) — at 32k tokens x d_inner 16k the full-sequence
        # version is ~34 TB/device (EXPERIMENTS.md §Perf, jamba iteration 1).
        dA_c, dBx_c, C_c = _mamba_inner(xc_c, p, cfg)

        def combine(a, b):
            return (a[0] * b[0], b[0] * a[1] + b[1])

        # Prefix products/sums within the chunk (inclusive).
        pA, pBx = jax.lax.associative_scan(combine, (dA_c, dBx_c), axis=1)
        h_c = pA * h[:, None] + pBx             # (B, chunk, di, ds)
        y_c = jnp.einsum("bcds,bcs->bcd", h_c, C_c)
        if collect_states:
            return h_c[:, -1], (y_c, h_c)
        return h_c[:, -1], y_c

    resh = lambda t: jnp.moveaxis(t.reshape(B, n_chunks, chunk, *t.shape[2:]), 1, 0)
    h0 = (state.h if state is not None
          else jnp.zeros((B, di, mc.d_state), jnp.float32))
    # checkpoint: backward recomputes one chunk at a time; only the per-chunk
    # carry states (B, di, ds) are saved across the sequence.
    h_final, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, resh(xc))
    per_pos = None
    if collect_states:
        assert state is not None, "collect_states needs a carried state"
        ys, h_all = ys                          # h_all: (n_chunks, B, chunk, di, ds)
        h_pos = jnp.moveaxis(h_all, 0, 1).reshape(B, S, di, mc.d_state)
        # Conv tail after token j is the last (d_conv - 1) inputs up to j —
        # a slice of xp, which already prepends the carried tail.
        conv_pos = jnp.stack([xp[:, j + 1: j + dc] for j in range(S)], axis=1)
        per_pos = MambaState(h=h_pos, conv=conv_pos.astype(tail.dtype))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    y = y + p["D"] * xc.astype(jnp.float32)
    out = layers.dense((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype), p["w_out"])
    new_state = (MambaState(h=h_final, conv=xp[:, S:].astype(tail.dtype))
                 if state is not None else None)
    return shard(out, "batch", "seq", "embed"), (per_pos if collect_states else new_state)


def init_mamba_state(cfg, batch: int) -> MambaState:
    mc = cfg.mamba
    di = mc.expand * cfg.d_model
    return MambaState(
        h=jnp.zeros((batch, di, mc.d_state), jnp.float32),
        conv=jnp.zeros((batch, mc.d_conv - 1, di), cfg.jax_dtype),
    )


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory) blocks
# ---------------------------------------------------------------------------

def _chunked_scan(step_fn, init_state, seq_tensors, S: int, chunk: int = 64,
                  collect_states: bool = False):
    """Two-level recurrent scan: outer over chunks (carries saved), inner
    over tokens inside a jax.checkpoint'd chunk body.

    Backward memory is O(S/chunk * |state|) saved carries plus one chunk of
    recomputed residuals — without this, AD of a 4k-step scan over the
    mLSTM's (B, H, hd, hd) matrix memory saves ~17 GB/layer.

    seq_tensors: pytree of (B, S, ...) arrays; returns (final_state, ys)
    with ys stacked back to (B, S, ...).

    ``collect_states`` makes ys ``(ys, states)`` where ``states`` carries the
    recurrent state *after every token* (leaves (B, S, ...)).  Speculative
    verification needs this: on a partial draft acceptance the engine restores
    the state at the accepted position — checkpoint-and-restore of the
    recurrence, at token granularity (models/model.py::paged_verify_step).
    """
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    def to_chunks(t):  # (B, S, ...) -> (n_chunks, chunk, B, ...)
        B = t.shape[0]
        t = jnp.moveaxis(t, 1, 0).reshape(n_chunks, chunk, B, *t.shape[2:])
        return t

    xs = jax.tree_util.tree_map(to_chunks, seq_tensors)

    if collect_states:
        base_step = step_fn

        def step_fn(s, t):
            ns, y = base_step(s, t)
            return ns, (y, ns)

    def chunk_body(state, chunk_xs):
        state, ys = jax.lax.scan(step_fn, state, chunk_xs)
        return state, ys

    final, ys = jax.lax.scan(jax.checkpoint(chunk_body), init_state, xs)

    def merge(t):  # (n_chunks, chunk, B, ...) -> (B, S, ...)
        t = t.reshape(n_chunks * chunk, *t.shape[2:])
        return jnp.moveaxis(t, 0, 1)

    return final, jax.tree_util.tree_map(merge, ys)

class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, hd, hd) matrix memory
    n: jax.Array   # (B, H, hd) normalizer
    m: jax.Array   # (B, H) log-space stabilizer


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, H, hd)
    n: jax.Array   # (B, H, hd)
    h: jax.Array   # (B, H, hd)
    m: jax.Array   # (B, H)


def init_mlstm(key, cfg):
    d = cfg.d_model
    di = 2 * d                       # up-projection factor 2 (xLSTM block)
    H = cfg.n_heads
    ks = jax.random.split(key, 7)
    dt = cfg.jax_dtype
    return {
        "w_up": layers._init_dense(ks[0], d, 2 * di, dt),
        "w_q": layers._init_dense(ks[1], di, di, dt),
        "w_k": layers._init_dense(ks[2], di, di, dt),
        "w_v": layers._init_dense(ks[3], di, di, dt),
        "w_i": layers._init_dense(ks[4], di, H, dt),
        "w_f": layers._init_dense(ks[5], di, H, dt),
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),   # forget-gate bias init
        "w_down": layers._init_dense(ks[6], di, d, dt),
    }


def mlstm_block(x, p, cfg, *, state: Optional[MLSTMState] = None,
                collect_states: bool = False):
    """mLSTM block: up-proj, matrix-memory recurrence, gated down-proj.

    ``collect_states`` (requires a carried state) returns an MLSTMState with
    an extra position axis (leaves (B, S, ...)): the state after every token,
    for speculative-verification restore at the accepted position."""
    B, S, d = x.shape
    di = 2 * d
    H = cfg.n_heads
    hd = di // H
    up = layers.dense(x, p["w_up"])
    xm, z = jnp.split(up, 2, axis=-1)            # (B, S, di)
    xm = shard(xm, "batch", "seq", "mlp")

    def heads(w):
        return layers.dense(xm, w).reshape(B, S, H, hd).astype(jnp.float32)

    q, k, v = heads(p["w_q"]), heads(p["w_k"]) * hd ** -0.5, heads(p["w_v"])
    # quant="none": gate pre-activations feed log-space exponentials in the
    # recurrence — they stay float under the w8a8 precision mode.
    i_pre = (layers.dense(xm, p["w_i"], quant="none").astype(jnp.float32) + p["b_i"])
    f_pre = (layers.dense(xm, p["w_f"], quant="none").astype(jnp.float32) + p["b_f"])

    if state is None:
        st = MLSTMState(
            C=jnp.zeros((B, H, hd, hd), jnp.float32),
            n=jnp.zeros((B, H, hd), jnp.float32),
            m=jnp.full((B, H), -1e30, jnp.float32),
        )
    else:
        st = state

    def step(s: MLSTMState, t):
        qt, kt, vt, it, ft = t                   # (B,H,hd) x3, (B,H) x2
        log_f = -jax.nn.softplus(-ft)            # log sigmoid(f)
        m_new = jnp.maximum(log_f + s.m, it)
        f_sc = jnp.exp(log_f + s.m - m_new)[..., None]
        i_sc = jnp.exp(it - m_new)[..., None]
        C = f_sc[..., None] * s.C + (i_sc * vt)[..., None] * kt[..., None, :]
        n = f_sc * s.n + i_sc * kt
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt))[..., None], 1.0
        )
        h = jnp.einsum("bhij,bhj->bhi", C, qt) / denom
        return MLSTMState(C, n, m_new), h

    if state is None and S > 1:
        # Chunkwise-parallel form: per-token (hd x hd) matrix-memory updates
        # become (chunk x chunk) flash-like block matmuls — the xLSTM kernel
        # formulation.  Equivalent to the sequential scan (tests), ~50x less
        # HBM traffic at hd=512 (EXPERIMENTS.md §Perf, xlstm).
        hs, _ = _mlstm_chunkwise(q, k, v, i_pre, f_pre, st)
        h = hs.reshape(B, S, di).astype(x.dtype)
        new_state = None
    else:
        assert state is not None or not collect_states, \
            "collect_states needs a carried state"
        new_state, hs = _chunked_scan(step, st, (q, k, v, i_pre, f_pre), S,
                                      collect_states=collect_states)
        if collect_states:
            hs, new_state = hs
        h = hs.reshape(B, S, di).astype(x.dtype)
    out = layers.dense(h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["w_down"])
    return shard(out, "batch", "seq", "embed"), (new_state if state is not None else None)


def _mlstm_chunkwise(q, k, v, i_pre, f_pre, st: MLSTMState, chunk: int = 64):
    """Chunkwise-parallel stabilized mLSTM.

    Within a chunk (log-space gates): F_t = cumsum(log f), a_s = i_s - F_s,
    M_t = max(m_prev, cummax a_s), decay D[t,s] = exp(a_s - M_t) for s<=t.
      h_t = [exp(m_prev - M_t) (C_prev q_t) + sum_s D[t,s](q_t k_s) v_s]
            / max(|exp(m_prev - M_t)(n_prev q_t) + sum_s D[t,s](q_t k_s)|, 1)
    State closes each chunk with the same quantities at t = chunk.
    q/k/v: (B, S, H, hd) f32; i_pre/f_pre: (B, S, H).
    """
    B, S, H, hd = q.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    def to_c(t):  # (B,S,...) -> (n_chunks, B, chunk, ...)
        return jnp.moveaxis(
            t.reshape(B, n_chunks, chunk, *t.shape[2:]), 1, 0)

    def chunk_body(state, xs):
        qc, kc, vc, ic, fc = xs            # (B, chunk, H, ...) per chunk
        log_f = -jax.nn.softplus(-fc)      # (B, chunk, H)
        F = jnp.cumsum(log_f, axis=1)      # inclusive
        a = ic - F                         # (B, chunk, H)
        M = jnp.maximum(
            state.m[:, None], jax.lax.cummax(a, axis=1))  # (B, chunk, H)
        # intra-chunk: D[t,s] = exp(F_t - F_s + i_s - m_t) = exp(a_s - M_t)
        D = jnp.exp(a[:, None, :, :] - M[:, :, None, :])  # (B, t, s, H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(tri[None, :, :, None], D, 0.0)
        qk = jnp.einsum("bthd,bshd->btsh", qc, kc)        # (B, t, s, H)
        w = D * qk
        num_intra = jnp.einsum("btsh,bshd->bthd", w, vc)
        den_intra = jnp.sum(w, axis=2)                    # (B, t, H)
        # inter-chunk: carry C_prev / n_prev with stabilizer m_prev
        scale = jnp.exp(state.m[:, None] - M)             # (B, t, H)
        num_inter = scale[..., None] * jnp.einsum(
            "bhij,bthj->bthi", state.C, qc)
        den_inter = scale * jnp.einsum("bhd,bthd->bth", state.n, qc)
        num = num_intra + num_inter
        den = den_intra + den_inter
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]  # (B, t, H, hd)
        # close the chunk: state at t = chunk
        M_c = M[:, -1]                                    # (B, H)
        w_end = jnp.exp(a - M_c[:, None])                 # (B, s, H)
        C_new = scale[:, -1][..., None, None] * state.C + jnp.einsum(
            "bsh,bshd,bshe->bhde", w_end, vc, kc)
        n_new = scale[:, -1][..., None] * state.n + jnp.einsum(
            "bsh,bshd->bhd", w_end, kc)
        m_new = F[:, -1] + M_c        # m_t = F_t + M_t at t = chunk
        return MLSTMState(C_new, n_new, m_new), h

    final, hs = jax.lax.scan(
        jax.checkpoint(chunk_body), st,
        (to_c(q), to_c(k), to_c(v), to_c(i_pre), to_c(f_pre)),
    )
    # (n_chunks, B, chunk, H, hd) -> (B, S, H*hd)
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd)
    return hs.reshape(B, S, H * hd), final


def init_slstm(key, cfg):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 9)
    dt = cfg.jax_dtype
    p = {f"w_{g}": layers._init_dense(ks[i], d, d, dt) for i, g in enumerate("izfo")}
    p.update({f"r_{g}": (jax.random.normal(ks[4 + i], (H, hd, hd)) * hd ** -0.5).astype(dt)
              for i, g in enumerate("izfo")})
    p["b_f"] = jnp.full((H, hd), 3.0, jnp.float32)
    k_up, k_dn = jax.random.split(ks[8])
    ff = int(8 / 3 * d) // 8 * 8
    p["w_ff_up"] = layers._init_dense(k_up, d, 2 * ff, dt)
    p["w_ff_down"] = layers._init_dense(k_dn, ff, d, dt)
    return p


def slstm_block(x, p, cfg, *, state: Optional[SLSTMState] = None,
                collect_states: bool = False):
    """sLSTM block: scalar-memory LSTM with head-wise recurrence + GLU FFN.

    ``collect_states`` (requires a carried state) returns an SLSTMState with
    an extra position axis (leaves (B, S, ...)): the state after every token,
    for speculative-verification restore at the accepted position."""
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    pre = {
        # quant="none": LSTM gate projections (exponential/gated recurrence
        # inputs) stay float under the w8a8 precision mode.
        g: layers.dense(x, p[f"w_{g}"], quant="none").reshape(B, S, H, hd)
        .astype(jnp.float32)
        for g in "izfo"
    }
    if state is None:
        st = SLSTMState(
            c=jnp.zeros((B, H, hd), jnp.float32),
            n=jnp.zeros((B, H, hd), jnp.float32),
            h=jnp.zeros((B, H, hd), jnp.float32),
            m=jnp.full((B, H), -1e30, jnp.float32),
        )
    else:
        st = state

    rec = {g: p[f"r_{g}"].astype(jnp.float32) for g in "izfo"}

    def step(s: SLSTMState, t):
        def r(g):
            return jnp.einsum("bhj,hij->bhi", s.h, rec[g])

        i_pre = t["i"] + r("i")
        f_pre = t["f"] + r("f") + p["b_f"]
        z_t = jnp.tanh(t["z"] + r("z"))
        o_t = jax.nn.sigmoid(t["o"] + r("o"))
        log_f = -jax.nn.softplus(-f_pre)               # (B, H, hd)
        m_new = jnp.maximum(
            jnp.max(log_f, -1) + s.m, jnp.max(i_pre, -1)
        )                                              # (B, H)
        f_sc = jnp.exp(log_f + (s.m - m_new)[..., None])
        i_sc = jnp.exp(i_pre - m_new[..., None])
        c = f_sc * s.c + i_sc * z_t
        n = f_sc * s.n + i_sc
        h = o_t * c / jnp.maximum(n, 1.0)
        return SLSTMState(c, n, h, m_new), h

    assert state is not None or not collect_states, \
        "collect_states needs a carried state"
    new_state, hs = _chunked_scan(step, st, pre, S,
                                  collect_states=collect_states)
    if collect_states:
        hs, new_state = hs
    h = hs.reshape(B, S, d).astype(x.dtype)
    # GLU feed-forward (proj factor 4/3, xLSTM-style), fused into the block.
    up = layers.dense(h, p["w_ff_up"])
    a, b = jnp.split(up, 2, axis=-1)
    out = layers.dense(jax.nn.gelu(a.astype(jnp.float32)).astype(x.dtype) * b, p["w_ff_down"])
    return shard(out, "batch", "seq", "embed"), (new_state if state is not None else None)


def init_mlstm_state(cfg, batch: int) -> MLSTMState:
    di = 2 * cfg.d_model
    H = cfg.n_heads
    hd = di // H
    return MLSTMState(
        C=jnp.zeros((batch, H, hd, hd), jnp.float32),
        n=jnp.zeros((batch, H, hd), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
    )


def init_slstm_state(cfg, batch: int) -> SLSTMState:
    H = cfg.n_heads
    hd = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return SLSTMState(c=z(), n=z(), h=z(), m=jnp.full((batch, H), -1e30, jnp.float32))
