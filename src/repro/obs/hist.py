"""Streaming log-bucketed histograms for latency/throughput percentiles.

``EngineMetrics`` used to keep every finished request's latency in an
unbounded Python list and sort it per percentile query — fine for a
benchmark, wrong for a serving process that lives for days.  A
``Histogram`` holds a *bounded* sketch instead: geometric buckets at growth
factor g (default 2^(1/32), ~2.2% per bucket), a count per touched bucket,
plus exact count/sum/min/max.  Properties:

  * **O(1) add**, O(buckets) percentile, O(buckets) merge — and the bucket
    count is bounded by the dynamic range (~1500 buckets across 14 decades),
    not by the number of observations.
  * **Nearest-rank compatible.**  ``percentile(q)`` uses the exact rank
    formula of ``serving.engine.percentile`` (k = ceil(q/100 * n) - 1,
    clamped; 0.0 when empty) over the bucket counts, returning the selected
    bucket's geometric midpoint clamped into [min, max].  The result is
    within half a bucket of the exact nearest-rank value: relative error
    <= sqrt(g) - 1 (~1.1% at the default growth) — `rel_error` states the
    bound, tests/test_obs.py verifies it against the list implementation.
  * **Mergeable.**  Bucket counts add; ``cluster/metrics.py`` aggregates
    per-replica histograms instead of concatenating raw request lists, so
    cluster-wide tails cost O(replicas x buckets), not O(total requests).

Values at or below ``min_value`` (including zeros) collapse into one
underflow bucket represented by the tracked minimum — TTFTs and tok/s are
positive, so in practice only an all-zero stream lands there.
"""

from __future__ import annotations

import math
from typing import Dict

DEFAULT_GROWTH = 2.0 ** (1.0 / 32.0)
_UNDERFLOW = -(1 << 30)          # bucket index for values <= min_value


def nearest_rank_index(q: float, n: int) -> int:
    """0-based nearest-rank index for percentile q over n samples:
    k = ceil(q/100 * n) - 1, clamped into [0, n-1]."""
    return min(n - 1, max(0, int(math.ceil(q / 100.0 * n)) - 1))


def percentile(vals, q: float) -> float:
    """Exact nearest-rank percentile over any iterable of numbers; 0.0 when
    empty.

    The one shared definition — ``serving.engine`` re-exports it and
    ``Histogram.percentile`` applies the same rank formula to its bucket
    counts, so list-based and sketch-based tails agree to bucket error."""
    s = sorted(float(v) for v in vals)
    if not s:
        return 0.0
    return s[nearest_rank_index(q, len(s))]


class Histogram:
    __slots__ = ("growth", "min_value", "_log_g", "counts", "count",
                 "total", "min", "max")

    def __init__(self, growth: float = DEFAULT_GROWTH,
                 min_value: float = 1e-9):
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        if min_value <= 0.0:
            raise ValueError(f"min_value must be > 0, got {min_value}")
        self.growth = growth
        self.min_value = min_value
        self._log_g = math.log(growth)
        self.counts: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    @property
    def rel_error(self) -> float:
        """Max relative error of percentile() vs the exact nearest-rank
        value (half a bucket each way from the geometric midpoint)."""
        return math.sqrt(self.growth) - 1.0

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def add(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= self.min_value:
            b = _UNDERFLOW
        else:
            b = int(math.floor(math.log(v / self.min_value) / self._log_g))
        self.counts[b] = self.counts.get(b, 0) + 1

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold `other` into self (in place); returns self.  Histograms must
        share bucketing (growth, min_value) — merged counts are only
        meaningful over one bucket grid."""
        if (other.growth != self.growth
                or other.min_value != self.min_value):
            raise ValueError(
                f"cannot merge histograms with different bucketing: "
                f"(g={self.growth}, min={self.min_value}) vs "
                f"(g={other.growth}, min={other.min_value})")
        for b, c in other.counts.items():
            self.counts[b] = self.counts.get(b, 0) + c
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile (engine.percentile semantics) to within
        half-bucket relative error; 0.0 when empty."""
        if not self.count:
            return 0.0
        k = nearest_rank_index(q, self.count)
        seen = 0
        for b in sorted(self.counts):
            seen += self.counts[b]
            if seen > k:
                if b == _UNDERFLOW:
                    rep = self.min
                else:
                    rep = self.min_value * self.growth ** (b + 0.5)
                return min(self.max, max(self.min, rep))
        raise AssertionError("bucket counts do not cover count")  # unreachable

    def count_above(self, threshold: float) -> int:
        """Observations whose bucket representative exceeds `threshold` —
        the "bad events" numerator for SLO burn rates (obs/slo.py).  Uses
        the same representative as percentile() (geometric midpoint clamped
        into [min, max]), so count_above(percentile(q)) and the rank math
        stay consistent to bucket error."""
        if not self.count:
            return 0
        bad = 0
        for b, c in self.counts.items():
            if b == _UNDERFLOW:
                rep = self.min
            else:
                rep = self.min_value * self.growth ** (b + 0.5)
            if min(self.max, max(self.min, rep)) > threshold:
                bad += c
        return bad

    def to_dict(self) -> dict:
        """JSON-serializable form (launch/serve.py --metrics-json)."""
        return {
            "growth": self.growth,
            "min_value": self.min_value,
            "count": self.count,
            "total": self.total,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "buckets": {str(b): c for b, c in sorted(self.counts.items())},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Histogram":
        h = cls(growth=d["growth"], min_value=d["min_value"])
        h.count = int(d["count"])
        h.total = float(d["total"])
        h.min = math.inf if d["min"] is None else float(d["min"])
        h.max = -math.inf if d["max"] is None else float(d["max"])
        h.counts = {int(b): int(c) for b, c in d["buckets"].items()}
        return h

    def __repr__(self) -> str:
        if not self.count:
            return "Histogram(empty)"
        return (f"Histogram(n={self.count}, mean={self.mean:.4g}, "
                f"p50={self.percentile(50):.4g}, "
                f"p95={self.percentile(95):.4g}, "
                f"min={self.min:.4g}, max={self.max:.4g})")
