"""Chrome-trace / Perfetto export of recorded tracers.

Converts one or more ``Tracer`` ring buffers into the Chrome Trace Event
JSON format (the `traceEvents` array form), loadable in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``:

  * each tracer becomes one (pid, tid) lane, named via "M" metadata events
    — a ReplicaPool export shows one process row per replica;
  * BEGIN/END become nested "B"/"E" duration events (per-tick phases);
  * ASYNC_BEGIN/END become "b"/"e" events with ``cat="request"`` and the
    request id as ``id`` — Perfetto draws each request's
    queued -> prefill -> decode lifecycle as its own async track;
  * COUNTER becomes "C" events — kv_blocks_in_use / queue_depth render as
    stacked counter charts over the timeline;
  * FLOW_START/STEP/END become "s"/"t"/"f" events with ``cat="flow"`` and
    the request's trace id as ``id`` — Perfetto draws connected arrows from
    the router's admit slice through every prefill chunk / decode tick the
    request touched, across pid lanes, to the finishing tick ("f" carries
    ``bp="e"`` so the arrowhead lands on the enclosing slice);
  * INSTANT becomes thread-scoped "i" events (shed decisions, prefix-cache
    hits, CoW evictions) with the payload under ``args``.

Timestamps are microseconds (the format's unit) relative to the earliest
event across all tracers, so multi-replica traces align on one clock
(every tracer samples the same process-wide ``time.perf_counter_ns``).
"""

from __future__ import annotations

import json
from typing import Iterable, List, Optional

from repro.obs.trace import (
    ASYNC_BEGIN,
    ASYNC_END,
    BEGIN,
    COUNTER,
    END,
    FLOW_END,
    FLOW_START,
    FLOW_STEP,
    INSTANT,
    Tracer,
)

_PH = {BEGIN: "B", END: "E", COUNTER: "C", ASYNC_BEGIN: "b", ASYNC_END: "e",
       FLOW_START: "s", FLOW_STEP: "t", FLOW_END: "f", INSTANT: "i"}
_FLOW_KINDS = (FLOW_START, FLOW_STEP, FLOW_END)


def chrome_trace_events(tracers: Iterable[Tracer], *,
                        origin_ns: Optional[int] = None) -> List[dict]:
    """Flatten tracers into a Chrome-trace `traceEvents` list."""
    decoded = [(t, t.events()) for t in tracers if len(t)]
    if not decoded:
        return []
    if origin_ns is None:
        # ring order is chronological, so the first held event is the oldest
        origin_ns = min(evs[0]["ts_ns"] for _, evs in decoded)
    events: List[dict] = []
    for t, evs in decoded:
        pid, tid = t.pid, 0
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": tid, "args": {"name": t.name}})
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": t.name}})
        for ev in evs:
            kind = ev["kind"]
            out = {
                "ph": _PH[kind],
                "name": ev["name"],
                "pid": pid,
                "tid": tid,
                "ts": (ev["ts_ns"] - origin_ns) / 1e3,   # microseconds
            }
            if kind == COUNTER:
                out["args"] = {ev["name"]: ev["value"]}
            elif kind in (ASYNC_BEGIN, ASYNC_END):
                out["cat"] = "request"
                out["id"] = ev["id"]
            elif kind in _FLOW_KINDS:
                out["cat"] = "flow"
                out["id"] = ev["id"]
                if kind == FLOW_END:
                    out["bp"] = "e"
            elif kind == INSTANT:
                out["s"] = "t"
                out["args"] = {"value": ev["value"]}
            events.append(out)
    return events


def trace_document(tracers: Iterable[Tracer], *,
                   metadata: Optional[dict] = None) -> dict:
    """The full JSON-object trace form ({"traceEvents": [...], ...})."""
    doc = {
        "traceEvents": chrome_trace_events(tracers),
        "displayTimeUnit": "ms",
    }
    dropped = sum(t.dropped for t in tracers)
    meta = dict(metadata or {})
    if dropped:
        meta["dropped_events"] = dropped
    if meta:
        doc["metadata"] = meta
    return doc


def write_chrome_trace(path: str, tracers: Iterable[Tracer], *,
                       metadata: Optional[dict] = None) -> dict:
    """Write a Perfetto-loadable trace JSON; returns the written document."""
    doc = trace_document(tracers, metadata=metadata)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc
