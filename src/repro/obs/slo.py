"""Declarative SLO targets with multi-window burn-rate evaluation.

The PR 7 observability layer collects the raw signals — streaming
histograms (TTFT, per-token latency, tok/s), cumulative scheduler counters
(admitted / rejected), and live MFU gauges.  This module turns them into an
answer to the operator's question: *are we inside our service objective,
and how fast are we spending the error budget?*

The evaluation scheme is the SRE multi-window burn rate:

  * Every target defines a **bad-event fraction** per evaluation window —
    for a histogram target, the fraction of observations above the latency
    threshold (``Histogram.count_above``); for a ratio target, a counter
    ratio (shed / offered); for a floor target, how far a gauge sits below
    its floor.
  * **burn = bad fraction / error budget.**  Burn 1.0 means spending the
    budget exactly as fast as allowed; 2.0 means the budget is gone in half
    the period.
  * Two windows, evaluated over *deltas* of the cumulative series the
    monitor keeps per target: a short window (reacts fast, noisy) and a
    long window (slow, stable).  **BREACH requires both** windows at or
    above ``breach_burn`` — the classic guard against paging on a blip —
    while WARN fires on the long window alone at ``warn_burn``.
  * **Hysteresis on the way down**: escalation is immediate, de-escalation
    waits for ``clear_after`` consecutive calmer evaluations, so a target
    oscillating around a threshold doesn't flap ok/warn every tick.

Monitors are snapshot-driven, not wall-clock-driven: ``observe()`` takes a
dict of named histograms/counters/gauges (``engine_snapshot`` builds one
from a live Engine; ``cluster/metrics.py::slo_snapshot`` from merged
cluster metrics — histograms merge losslessly, so cluster-wide burn equals
the burn of the concatenated per-replica streams).  Each observe() is one
evaluation step; windows are counted in observations, which makes the math
deterministic and directly testable (tests/test_slo.py feeds synthetic
series across the thresholds).

On a transition into BREACH, wire the report into
``obs/recorder.py::FlightRecorder.record_breaches`` to capture an incident
bundle with the ring-buffer evidence of what the engine was doing.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.obs.hist import Histogram

OK = "ok"
WARN = "warn"
BREACH = "breach"

_RANK = {OK: 0, WARN: 1, BREACH: 2}

HISTOGRAM = "histogram"
RATIO = "ratio"
FLOOR = "floor"


@dataclasses.dataclass(frozen=True)
class SloTarget:
    """One declarative objective.

    kind="histogram": `source` names a Histogram in the snapshot; a bad
        event is an observation above `threshold` (seconds, tokens/s, ...);
        `budget` is the allowed bad fraction (p95 target => budget 0.05).
    kind="ratio": `source` is "num/den" naming two cumulative counters; the
        windowed ratio num_delta/den_delta burns against `budget` (e.g.
        shed_rate 0.05 => more than 5% shed burns > 1).
    kind="floor": `source` names a gauge that must stay >= `threshold`
        (e.g. decode MFU); burn = threshold / windowed gauge mean.  A gauge
        at or below zero reads as "no signal yet", not a breach.
    """

    name: str
    kind: str
    source: str
    threshold: float
    budget: float = 0.05

    def __post_init__(self):
        if self.kind not in (HISTOGRAM, RATIO, FLOOR):
            raise ValueError(f"unknown SLO kind {self.kind!r}")
        if self.kind != FLOOR and self.budget <= 0.0:
            raise ValueError(f"{self.name}: budget must be > 0")
        if self.kind == RATIO and "/" not in self.source:
            raise ValueError(f"{self.name}: ratio source must be 'num/den'")


@dataclasses.dataclass
class TargetState:
    """Evaluation result for one target at one observe() step."""

    name: str
    state: str
    prev_state: str
    burn_short: float
    burn_long: float
    bad_total: int = 0
    total: int = 0

    @property
    def transitioned(self) -> bool:
        return self.state != self.prev_state


class SloReport:
    """The result of one SloMonitor.observe() call."""

    def __init__(self, targets: List[TargetState]):
        self.targets = targets

    @property
    def state(self) -> str:
        """Worst per-target state (ok < warn < breach)."""
        if not self.targets:
            return OK
        return max(self.targets, key=lambda t: _RANK[t.state]).state

    @property
    def transitions(self) -> List[TargetState]:
        return [t for t in self.targets if t.transitioned]

    @property
    def breaches(self) -> List[TargetState]:
        return [t for t in self.targets
                if t.transitioned and t.state == BREACH]

    def summary(self) -> str:
        parts = [f"slo={self.state}"]
        for t in self.targets:
            mark = "" if not t.transitioned else f"<-{t.prev_state}"
            parts.append(f"{t.name}={t.state}{mark}"
                         f"(burn {t.burn_short:.2f}/{t.burn_long:.2f})")
        return " ".join(parts)

    def as_dict(self) -> dict:
        return {
            "state": self.state,
            "targets": [dataclasses.asdict(t) | {"transitioned":
                                                 t.transitioned}
                        for t in self.targets],
        }


class SloMonitor:
    """Evaluates a set of SloTargets over a stream of metric snapshots.

    Windows are counted in observe() calls: `short_window`/`long_window`
    are how many trailing observations each burn rate is computed over.
    The monitor keeps a cumulative (bad, total) series per target, seeded
    with a virtual (0, 0) so the first observation evaluates over
    everything seen so far.
    """

    def __init__(self, targets: Sequence[SloTarget], *,
                 short_window: int = 1, long_window: int = 4,
                 warn_burn: float = 1.0, breach_burn: float = 2.0,
                 clear_after: int = 2):
        if short_window < 1 or long_window < short_window:
            raise ValueError("need 1 <= short_window <= long_window")
        if clear_after < 1:
            raise ValueError("clear_after must be >= 1")
        self.targets = list(targets)
        self.short_window = short_window
        self.long_window = long_window
        self.warn_burn = warn_burn
        self.breach_burn = breach_burn
        self.clear_after = clear_after
        # cumulative (bad, total) per histogram/ratio target; raw gauge
        # series per floor target — both seeded for window math
        self._series: Dict[str, List[Tuple[float, float]]] = {
            t.name: [(0.0, 0.0)] for t in self.targets}
        self._state: Dict[str, str] = {t.name: OK for t in self.targets}
        self._calm: Dict[str, int] = {t.name: 0 for t in self.targets}

    # -- per-kind cumulative extraction --------------------------------------

    def _cumulative(self, t: SloTarget, snapshot: dict
                    ) -> Tuple[float, float]:
        """(bad_events, total_events) since process start, per target kind.
        Floor targets return (gauge_value, 1.0) — windowed mean, not a
        counter delta."""
        if t.kind == HISTOGRAM:
            h = snapshot.get(t.source)
            if not isinstance(h, Histogram) or not h.count:
                return 0.0, 0.0
            return float(h.count_above(t.threshold)), float(h.count)
        if t.kind == RATIO:
            num_key, den_key = t.source.split("/", 1)
            return (float(snapshot.get(num_key, 0) or 0),
                    float(snapshot.get(den_key, 0) or 0))
        # FLOOR: stash the raw gauge sample
        return float(snapshot.get(t.source, 0.0) or 0.0), 1.0

    def _burn(self, t: SloTarget, window: int) -> float:
        s = self._series[t.name]
        if t.kind == FLOOR:
            # windowed mean of the gauge samples (skip the (0,0) seed)
            samples = [v for v, _ in s[1:]][-window:]
            if not samples:
                return 0.0
            mean = sum(samples) / len(samples)
            if mean <= 0.0:
                return 0.0          # no signal yet — don't alarm on startup
            return t.threshold / mean
        cur_bad, cur_total = s[-1]
        prev_bad, prev_total = s[max(0, len(s) - 1 - window)]
        bad = max(0.0, cur_bad - prev_bad)
        total = max(0.0, cur_total - prev_total)
        if total <= 0.0:
            return 0.0              # idle window spends no budget
        return (bad / total) / t.budget

    # -- evaluation ----------------------------------------------------------

    def observe(self, snapshot: dict) -> SloReport:
        """Fold one metrics snapshot in and re-evaluate every target."""
        states: List[TargetState] = []
        for t in self.targets:
            self._series[t.name].append(self._cumulative(t, snapshot))
            burn_s = self._burn(t, self.short_window)
            burn_l = self._burn(t, self.long_window)
            if burn_s >= self.breach_burn and burn_l >= self.breach_burn:
                level = BREACH
            elif burn_l >= self.warn_burn:
                level = WARN
            else:
                level = OK
            prev = self._state[t.name]
            if _RANK[level] > _RANK[prev]:
                new, self._calm[t.name] = level, 0    # escalate immediately
            elif _RANK[level] < _RANK[prev]:
                self._calm[t.name] += 1               # hysteretic clear
                if self._calm[t.name] >= self.clear_after:
                    new, self._calm[t.name] = level, 0
                else:
                    new = prev
            else:
                new, self._calm[t.name] = prev, 0
            self._state[t.name] = new
            bad, total = self._series[t.name][-1]
            states.append(TargetState(
                name=t.name, state=new, prev_state=prev,
                burn_short=burn_s, burn_long=burn_l,
                bad_total=int(bad) if t.kind != FLOOR else 0,
                total=int(total) if t.kind != FLOOR else 0))
        return SloReport(states)

    @property
    def state(self) -> str:
        if not self.targets:
            return OK
        return max(self._state.values(), key=lambda s: _RANK[s])


# -- snapshot builders / spec parsing ----------------------------------------

def engine_snapshot(engine) -> dict:
    """Metric snapshot for SloMonitor.observe() from a live Engine (duck-
    typed: anything with .metrics and .scheduler quacks the same)."""
    m = engine.metrics
    sched = engine.scheduler
    offered = (sched.rejected + sched.admitted_total + len(sched.queue))
    return {
        "ttft": m.ttft_hist,
        "latency": m.latency_hist,
        "tok_s": m.tok_s_hist,
        "shed": sched.rejected,
        "offered": offered,
        "mfu_decode": m.mfu.mfu("decode") if m.mfu else 0.0,
    }


_P_SUFFIX = "_p"


def parse_slo_spec(spec: str) -> List[SloTarget]:
    """Parse the --slo CLI string into targets.

    Grammar: comma-separated `key=value` pairs —

        ttft_p95=0.25        TTFT p95 <= 0.25s   (histogram over "ttft")
        latency_p99=1.0      per-token p99 <= 1s (histogram over "latency")
        shed_rate=0.05       <= 5% of offered requests shed  (ratio)
        mfu_floor=1e-6       decode MFU stays above the floor

    A pNN suffix sets the error budget to 1 - NN/100.
    """
    targets: List[SloTarget] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"bad SLO clause {part!r} (want key=value)")
        key, _, raw = part.partition("=")
        key = key.strip()
        try:
            value = float(raw)
        except ValueError:
            raise ValueError(f"bad SLO value in {part!r}") from None
        if key == "shed_rate":
            targets.append(SloTarget(name=key, kind=RATIO,
                                     source="shed/offered",
                                     threshold=value, budget=value))
        elif key == "mfu_floor":
            targets.append(SloTarget(name=key, kind=FLOOR,
                                     source="mfu_decode", threshold=value))
        elif _P_SUFFIX in key:
            source, _, pct = key.rpartition(_P_SUFFIX)
            if source not in ("ttft", "latency", "tok_s"):
                raise ValueError(f"unknown SLO histogram {source!r} in "
                                 f"{part!r}")
            try:
                q = float(pct)
            except ValueError:
                raise ValueError(f"bad percentile in {part!r}") from None
            if not 0.0 < q < 100.0:
                raise ValueError(f"percentile out of range in {part!r}")
            # round away float noise (1 - 95/100 = 0.0500...04) so a burn
            # of exactly breach_burn compares clean against the budget
            budget = round(1.0 - q / 100.0, 12)
            targets.append(SloTarget(name=key, kind=HISTOGRAM,
                                     source=source, threshold=value,
                                     budget=budget))
        else:
            raise ValueError(f"unknown SLO key {key!r}")
    if not targets:
        raise ValueError(f"empty SLO spec {spec!r}")
    return targets
