"""Low-overhead span/event tracing: a pre-allocated ring-buffer event log.

The serving engine's per-tick hot path runs in hundreds of microseconds on
the smoke configs; a tracer that allocates, locks, or formats per event
would show up in the very utilization numbers it exists to explain.  The
design rules, in order:

  * **Pre-allocated ring writes.**  One event = three scalar stores into
    pre-allocated numpy arrays (kind, interned-name code, monotonic
    timestamp) plus an index increment — measured ~0.3 µs/event on the CI
    host, against decode ticks of ~0.5-1 ms (benchmarks/obs_bench.py keeps
    the measured overhead on the record; tests/test_obs.py holds the
    events-per-tick x cost product under 2% of a decode tick).
  * **No allocation or locks per event.**  Names are interned to small int
    codes once (engine init / first use); the hot path never touches a
    string or a dict.  The only lock guards interning, never recording.
  * **Single-writer, thread-safe by confinement.**  Each engine owns its
    tracer and each engine is single-thread-confined (cluster/replica.py),
    so a ReplicaPool traces race-free with zero synchronization: one tracer
    per replica thread, merged at export (obs/export.py gives each its own
    pid/tid in the Chrome trace).
  * **Bounded memory.**  The ring keeps the most recent `capacity` events;
    older events are overwritten and counted in `dropped` — a serving
    process can trace forever without growing.

Event kinds map 1:1 onto Chrome-trace phases (obs/export.py):

  BEGIN/END         -> "B"/"E"   nested duration spans on this tracer's tid
                                 (per-tick phases: sched, prefill, decode,
                                 verify, draft, reset)
  COUNTER           -> "C"       sampled gauges (kv_blocks_in_use,
                                 queue_depth, ...)
  ASYNC_BEGIN/END   -> "b"/"e"   id-keyed spans that outlive any one tick
                                 (per-request lifecycle: queued -> prefill
                                 -> decode, id = trace id)
  FLOW_*            -> "s"/"t"/"f"  id-keyed flow arrows that CROSS tracer
                                 lanes (request tracing: the router lane
                                 starts a flow at admission, each replica
                                 lane steps it per prefill chunk / decode
                                 tick, the finishing tick ends it — one
                                 request renders as a connected arrow chain
                                 across pid lanes in Perfetto).  Flow
                                 events bind to the duration slice open at
                                 their timestamp, so emit them inside a
                                 BEGIN/END pair.
  INSTANT           -> "i"       point annotations (shed decisions,
                                 prefix-cache hits, CoW cache evictions)

Timestamps are `time.perf_counter_ns()` — monotonic, comparable across
tracers in one process (export aligns every tracer to a common origin).
"""

from __future__ import annotations

import contextlib
import threading
import time
from typing import Dict, List

import numpy as np

BEGIN = 0
END = 1
COUNTER = 2
ASYNC_BEGIN = 3
ASYNC_END = 4
FLOW_START = 5
FLOW_STEP = 6
FLOW_END = 7
INSTANT = 8

_KIND_NAMES = ("B", "E", "C", "b", "e", "s", "t", "f", "i")


class Tracer:
    """Single-writer ring-buffer event log (see module docstring).

    `intern()` a name once, then record with the returned code:

        tr = Tracer(name="engine")
        DECODE = tr.intern("decode")
        tr.begin(DECODE); ...; tr.end(DECODE)
    """

    __slots__ = ("capacity", "name", "pid", "enabled", "_kind", "_code",
                 "_aid", "_value", "_ts", "_n", "_names", "_codes", "_lock",
                 "_clock")

    def __init__(self, capacity: int = 1 << 15, *, name: str = "engine",
                 pid: int = 0, clock=time.perf_counter_ns):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.name = name
        self.pid = pid
        self.enabled = True
        self._kind = np.zeros(capacity, np.uint8)
        self._code = np.zeros(capacity, np.uint32)
        self._aid = np.zeros(capacity, np.int64)      # async id (request id)
        self._value = np.zeros(capacity, np.float64)  # counter value
        self._ts = np.zeros(capacity, np.int64)       # perf_counter_ns
        self._n = 0                                   # total events recorded
        self._names: List[str] = []
        self._codes: Dict[str, int] = {}
        self._lock = threading.Lock()                 # interning only
        self._clock = clock

    # -- name interning (off the hot path) -----------------------------------

    def intern(self, name: str) -> int:
        """Name -> small int code; idempotent, safe from any thread."""
        code = self._codes.get(name)
        if code is not None:
            return code
        with self._lock:
            code = self._codes.get(name)
            if code is None:
                code = len(self._names)
                self._names.append(name)
                self._codes[name] = code
            return code

    # -- recording (hot path: 3 scalar stores + 1 increment) -----------------

    def begin(self, code: int) -> None:
        i = self._n % self.capacity
        self._kind[i] = BEGIN
        self._code[i] = code
        self._ts[i] = self._clock()
        self._n += 1

    def end(self, code: int) -> None:
        i = self._n % self.capacity
        self._kind[i] = END
        self._code[i] = code
        self._ts[i] = self._clock()
        self._n += 1

    def counter(self, code: int, value: float) -> None:
        i = self._n % self.capacity
        self._kind[i] = COUNTER
        self._code[i] = code
        self._value[i] = value
        self._ts[i] = self._clock()
        self._n += 1

    def async_begin(self, code: int, aid: int) -> None:
        i = self._n % self.capacity
        self._kind[i] = ASYNC_BEGIN
        self._code[i] = code
        self._aid[i] = aid
        self._ts[i] = self._clock()
        self._n += 1

    def async_end(self, code: int, aid: int) -> None:
        i = self._n % self.capacity
        self._kind[i] = ASYNC_END
        self._code[i] = code
        self._aid[i] = aid
        self._ts[i] = self._clock()
        self._n += 1

    def flow_start(self, code: int, fid: int) -> None:
        """Open flow `fid` (request trace id) at the enclosing slice."""
        i = self._n % self.capacity
        self._kind[i] = FLOW_START
        self._code[i] = code
        self._aid[i] = fid
        self._ts[i] = self._clock()
        self._n += 1

    def flow_step(self, code: int, fid: int) -> None:
        i = self._n % self.capacity
        self._kind[i] = FLOW_STEP
        self._code[i] = code
        self._aid[i] = fid
        self._ts[i] = self._clock()
        self._n += 1

    def flow_end(self, code: int, fid: int) -> None:
        i = self._n % self.capacity
        self._kind[i] = FLOW_END
        self._code[i] = code
        self._aid[i] = fid
        self._ts[i] = self._clock()
        self._n += 1

    def instant(self, code: int, value: float = 0.0) -> None:
        """Point annotation (shed / prefix hit / eviction), with a payload."""
        i = self._n % self.capacity
        self._kind[i] = INSTANT
        self._code[i] = code
        self._value[i] = value
        self._ts[i] = self._clock()
        self._n += 1

    @contextlib.contextmanager
    def span(self, name: str):
        """Convenience span by name (interns; for warm paths only)."""
        code = self.intern(name)
        self.begin(code)
        try:
            yield
        finally:
            self.end(code)

    # -- reading -------------------------------------------------------------

    def __len__(self) -> int:
        """Events currently held (<= capacity)."""
        return min(self._n, self.capacity)

    @property
    def recorded(self) -> int:
        """Total events ever recorded (held + dropped)."""
        return self._n

    @property
    def dropped(self) -> int:
        """Events overwritten by ring wraparound."""
        return max(0, self._n - self.capacity)

    def events(self) -> List[dict]:
        """Held events, oldest first, decoded to plain dicts.

        Call from the writer thread or after it has stopped — a concurrent
        read mid-write may see one torn record at the ring head."""
        n = self._n
        if n <= self.capacity:
            order = range(n)
        else:
            head = n % self.capacity
            order = list(range(head, self.capacity)) + list(range(head))
        out = []
        for i in order:
            kind = int(self._kind[i])
            out.append({
                "kind": kind,
                "ph": _KIND_NAMES[kind],
                "name": self._names[int(self._code[i])],
                "id": int(self._aid[i]),
                "value": float(self._value[i]),
                "ts_ns": int(self._ts[i]),
            })
        return out

    def clear(self) -> None:
        self._n = 0


class NullTracer:
    """No-op stand-in with the full Tracer API: tracing-off engines call the
    same code paths, and each call is one cheap no-op method dispatch (a few
    tens of ns against a ~ms tick)."""

    capacity = 0
    name = "null"
    pid = 0
    enabled = False

    def intern(self, name: str) -> int:
        return 0

    def begin(self, code: int) -> None:
        pass

    def end(self, code: int) -> None:
        pass

    def counter(self, code: int, value: float) -> None:
        pass

    def async_begin(self, code: int, aid: int) -> None:
        pass

    def async_end(self, code: int, aid: int) -> None:
        pass

    def flow_start(self, code: int, fid: int) -> None:
        pass

    def flow_step(self, code: int, fid: int) -> None:
        pass

    def flow_end(self, code: int, fid: int) -> None:
        pass

    def instant(self, code: int, value: float = 0.0) -> None:
        pass

    @contextlib.contextmanager
    def span(self, name: str):
        yield

    def __len__(self) -> int:
        return 0

    recorded = 0
    dropped = 0

    def events(self) -> List[dict]:
        return []

    def clear(self) -> None:
        pass


NULL_TRACER = NullTracer()
