"""Live utilization gauges: achieved throughput vs the analytic bound.

The paper's headline number is *measured utilization* — how close the
generated instance runs to its cycle model's prediction (81.89-99.34%
across workloads, Table 2).  This module is that comparison lifted to the
serving stack, computed live per tick instead of after the fact:

  * **utilization** (per phase) = modeled step time / measured step time.
    The modeled time is the analytic bound from the same models the
    autotuner ranks with: the re-targeted cycle model
    (`tuning/model.py::predict` summed over the step's projection GeMMs,
    launch overhead included) vs the roofline terms from `core/hw.py`
    constants (compute at peak FLOP/s, weights streamed once per step at
    HBM bandwidth — the `launch/roofline.py` decomposition), whichever
    binds.  This is the paper's temporal-utilization analogue: 1.0 means
    the step ran exactly as fast as the model says the hardware allows.
  * **mfu** (per phase) = useful model FLOPs / (measured time x peak
    FLOP/s), with useful FLOPs = 2 x active params x committed tokens
    (`launch/roofline.py::model_flops`' inference formula) — the
    cross-paper-comparable Model FLOPs Utilization figure.

Phases are accounted separately (prefill / decode / verify) because their
bounds differ by orders of magnitude: a decode step is weight-bandwidth
bound at M=slots rows, a prefill chunk amortizes the same weight traffic
over C token rows, and a speculative verify step runs M=slots x (K+1).

The gauges are a few float adds per tick (the bound is memoized per
(phase, rows)) — cheap enough to stay on by default; `EngineMetrics` and
`ClusterMetrics` surface them in `summary()`.

NOTE on absolute values: the hardware constants describe the target
TPU-class chip.  On the CPU CI host the measured step is far slower than
the TPU-modeled bound, so utilization reads in the fractions-of-a-percent
— the *trend* (per phase, across configs, across PRs) is the signal there;
the absolute figure becomes paper-comparable on real accelerator hardware.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional

from repro.core.hw import HBM_BW, PEAK_FLOPS_BF16

PHASES = ("prefill", "decode", "verify")

_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "int8": 1}


@dataclasses.dataclass
class PhaseStat:
    """Accumulated measurements for one serving phase."""

    time_s: float = 0.0      # measured wall time in this phase's steps
    flops: float = 0.0       # useful model FLOPs (committed tokens)
    tokens: int = 0          # committed tokens
    rows: int = 0            # executed GeMM rows (padding slots included)
    steps: int = 0
    bound_s: float = 0.0     # accumulated analytic lower-bound time

    def merge(self, other: "PhaseStat") -> None:
        self.time_s += other.time_s
        self.flops += other.flops
        self.tokens += other.tokens
        self.rows += other.rows
        self.steps += other.steps
        self.bound_s += other.bound_s


class MfuMeter:
    """Per-phase utilization/MFU accounting for one model config."""

    def __init__(self, cfg, *, peak_flops: float = PEAK_FLOPS_BF16,
                 hbm_bw: float = HBM_BW):
        self.arch = cfg.name
        self.dtype = cfg.dtype
        self.peak_flops = float(peak_flops)
        self.hbm_bw = float(hbm_bw)
        active = cfg.active_param_count()
        self.flops_per_token = 2.0 * active
        self.param_bytes = active * _DTYPE_BYTES.get(cfg.dtype, 2)
        self.phases: Dict[str, PhaseStat] = {p: PhaseStat() for p in PHASES}
        self._cfg = cfg
        self._bound_cache: Dict[int, float] = {}

    # -- recording -----------------------------------------------------------

    def note(self, phase: str, *, tokens: int, rows: int, time_s: float
             ) -> None:
        """Account one step: `tokens` committed, `rows` GeMM rows executed
        (padding slots included), `time_s` measured wall time."""
        st = self.phases[phase]
        st.time_s += time_s
        st.tokens += tokens
        st.rows += rows
        st.steps += 1
        st.flops += tokens * self.flops_per_token
        st.bound_s += self.step_bound_s(rows)

    def step_bound_s(self, rows: int) -> float:
        """Analytic lower-bound time for one step executing `rows` token
        rows: max of the roofline terms (compute at peak, weights streamed
        once at HBM bandwidth) and the cycle model's predicted time for the
        step's dense-projection GeMMs.  Memoized — the engine only ever
        executes a handful of distinct row counts (slots, chunk buckets,
        verify widths)."""
        cached = self._bound_cache.get(rows)
        if cached is not None:
            return cached
        compute_s = rows * self.flops_per_token / self.peak_flops
        memory_s = self.param_bytes / self.hbm_bw
        bound = max(compute_s, memory_s, self._gemm_step_s(rows))
        self._bound_cache[rows] = bound
        return bound

    def _gemm_step_s(self, rows: int) -> float:
        """Cycle-model time (tuning/model.py) for the step's per-layer
        projection GeMMs at M=rows — launch overhead and tile padding
        included, the same model the autotuner ranks tiles with.  Covers
        only the spec-dispatched dense projections (MoE experts and SSM
        scans do not route through ops.gemm — see
        engine.serving_gemm_shapes); the roofline terms in step_bound_s
        cover the rest, and the bound takes the max."""
        try:
            from repro.core.dataflow import GemmShape
            from repro.core.generator import TpuGemmSpec
            from repro.tuning import model as tmodel

            cfg = self._cfg
            d, ff, vocab = cfg.d_model, cfg.d_ff, cfg.vocab
            hd = cfg.resolved_head_dim
            hq, hkv = cfg.n_heads, cfg.n_kv_heads
            shapes = []
            for kind in cfg.layer_kinds():
                if kind in ("attn", "attn_local"):
                    shapes += [
                        GemmShape(rows, d, hq * hd),   # q
                        GemmShape(rows, d, hkv * hd),  # k
                        GemmShape(rows, d, hkv * hd),  # v
                        GemmShape(rows, hq * hd, d),   # o
                    ]
                if cfg.moe is None:
                    shapes += [GemmShape(rows, d, ff), GemmShape(rows, ff, d)]
            spec = TpuGemmSpec(tm=8, tk=128, tn=128)
            per_group = sum(
                tmodel.predict(spec, s, self.dtype).time_s for s in shapes)
            head = tmodel.predict(
                spec, GemmShape(rows, d, vocab), self.dtype).time_s
            return cfg.n_groups * per_group + head
        except Exception:
            # The cycle-model term is an enrichment of the bound, not a
            # correctness dependency — an exotic config falls back to the
            # roofline terms alone.
            return 0.0

    # -- reporting -----------------------------------------------------------

    def utilization(self, phase: str) -> float:
        """Modeled time / measured time for this phase (the paper's
        temporal-utilization analogue; 0.0 before any step ran)."""
        st = self.phases[phase]
        return st.bound_s / st.time_s if st.time_s > 0 else 0.0

    def mfu(self, phase: str) -> float:
        """Useful model FLOPs / (measured time x peak FLOP/s)."""
        st = self.phases[phase]
        return (st.flops / (st.time_s * self.peak_flops)
                if st.time_s > 0 else 0.0)

    def active_phases(self) -> Iterable[str]:
        return [p for p in PHASES if self.phases[p].steps]

    def merge(self, other: "MfuMeter") -> "MfuMeter":
        """Fold another meter's phase stats into self (cluster aggregation
        over same-config replicas); returns self."""
        for p in PHASES:
            self.phases[p].merge(other.phases[p])
        return self

    @classmethod
    def merged(cls, meters: Iterable["MfuMeter"]) -> Optional["MfuMeter"]:
        meters = [m for m in meters if m is not None]
        if not meters:
            return None
        out = cls(meters[0]._cfg, peak_flops=meters[0].peak_flops,
                  hbm_bw=meters[0].hbm_bw)
        for m in meters:
            out.merge(m)
        return out

    def summary(self) -> str:
        """Compact per-phase fragment for EngineMetrics.summary():
        ``util[decode]=0.12% mfu[decode]=0.03% ...`` (active phases only).
        """
        parts = []
        for p in self.active_phases():
            parts.append(f"util[{p}]={self.utilization(p):.2%} "
                         f"mfu[{p}]={self.mfu(p):.2%}")
        return " ".join(parts)

    def as_dict(self) -> dict:
        return {
            "arch": self.arch,
            "dtype": self.dtype,
            "peak_flops": self.peak_flops,
            "hbm_bw": self.hbm_bw,
            "phases": {
                p: {
                    "time_s": st.time_s,
                    "flops": st.flops,
                    "tokens": st.tokens,
                    "rows": st.rows,
                    "steps": st.steps,
                    "bound_s": st.bound_s,
                    "utilization": self.utilization(p),
                    "mfu": self.mfu(p),
                }
                for p, st in self.phases.items() if st.steps
            },
        }
