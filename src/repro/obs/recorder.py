"""Anomaly flight recorder: snapshot ring-buffer evidence on trigger.

When an SLO burns or the engine sheds, the aggregate gauges tell you *that*
something went wrong; the flight recorder captures *what the system was
doing at that moment*.  A trigger snapshots, into one self-contained JSON
incident bundle:

  * the newest events from every registered tracer ring (the last
    ``max_events`` per tracer — the tick phases, request lifecycle spans,
    flow steps, and shed/eviction instants leading up to the trigger);
  * every registered metric source (engine metrics, allocator counters,
    scheduler queue state, drafter acceptance) evaluated at trigger time;
  * the trigger record itself: reason, wall/monotonic timestamps, sequence
    number, and any caller-supplied context (e.g. the SLO report that
    transitioned into breach).

Design rules:

  * **Never write into a foreign tracer.**  Tracers are single-writer
    rings owned by their engine thread (obs/trace.py); the recorder may
    fire from the router thread or a monitoring loop while replicas are
    mid-tick.  Reading can at worst see one torn record at the ring head
    (annotated in the bundle as ``live_read``); writing would corrupt the
    ring.  The trigger annotation therefore lives in the bundle JSON, not
    in the trace.
  * **Rate-limited per reason.**  A pressure trigger evaluated per tick
    must not write a thousand bundles; ``min_interval_s`` drops repeat
    triggers for the same reason inside the window (counted in
    ``suppressed``).
  * **Sources never take the recorder down.**  A metric source that raises
    is captured as its error string — an incident bundle with one missing
    section beats no bundle during an incident.

Wiring: ``attach_engine`` registers an Engine's tracer + standard sources;
``record_breaches`` consumes an ``obs/slo.py::SloReport``;
``check_engine`` evaluates built-in pressure triggers (allocator
exhaustion, speculative-acceptance collapse).  launch/serve.py exposes the
lot as ``--incident-dir`` on both the single-engine and cluster paths.
"""

from __future__ import annotations

import json
import os
import re
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.obs.slo import BREACH, SloReport

_REASON_RE = re.compile(r"[^a-zA-Z0-9_.-]+")


class FlightRecorder:
    """Collects tracers + metric sources; dumps incident bundles on
    trigger().  Thread-safe: triggers may arrive concurrently from the
    router, a monitor loop, and test code."""

    def __init__(self, incident_dir: str, *, tracers=(),
                 max_events: int = 512, min_interval_s: float = 0.0,
                 metadata: Optional[dict] = None):
        self.incident_dir = incident_dir
        self.max_events = int(max_events)
        self.min_interval_s = float(min_interval_s)
        self.metadata = dict(metadata or {})
        self._tracers: List = []
        self._sources: Dict[str, Callable[[], dict]] = {}
        self._lock = threading.Lock()
        self._seq = 0
        self._last_trigger: Dict[str, float] = {}   # reason -> monotonic s
        self.suppressed = 0
        self.incidents: List[str] = []
        for t in tracers:
            self.add_tracer(t)

    # -- registration --------------------------------------------------------

    def add_tracer(self, tracer) -> None:
        if tracer is not None and getattr(tracer, "enabled", False):
            self._tracers.append(tracer)

    def add_source(self, name: str, fn: Callable[[], dict]) -> None:
        """Register a zero-arg callable returning a JSON-able dict,
        evaluated at trigger time (not registration time)."""
        self._sources[name] = fn

    def attach_engine(self, engine, name: str = "engine") -> None:
        """Register an Engine's tracer and its standard evidence sources."""
        self.add_tracer(engine.tracer)
        m, sched, alloc = engine.metrics, engine.scheduler, engine.alloc
        self.add_source(f"{name}.metrics", m.as_dict)
        self.add_source(f"{name}.allocator", alloc.stats)
        self.add_source(f"{name}.scheduler", lambda: {
            "queue_depth": len(sched.queue),
            "rejected": sched.rejected,
            "admitted_total": sched.admitted_total,
            "preemptions": sched.preemptions,
            "active": sum(1 for s in sched.slots if s is not None),
        })
        if engine.drafter is not None:
            d = engine.drafter
            self.add_source(f"{name}.drafter", lambda: {
                "draft_calls": d.draft_calls,
                "draft_hits": d.draft_hits,
                "drafted_tokens": d.drafted_tokens,
                "hit_rate": d.hit_rate,
            })

    # -- triggering ----------------------------------------------------------

    def trigger(self, reason: str, extra: Optional[dict] = None
                ) -> Optional[str]:
        """Capture an incident bundle; returns its path, or None when the
        per-reason rate limit suppressed it."""
        now = time.monotonic()
        with self._lock:
            last = self._last_trigger.get(reason)
            if (last is not None and self.min_interval_s > 0.0
                    and now - last < self.min_interval_s):
                self.suppressed += 1
                return None
            self._last_trigger[reason] = now
            self._seq += 1
            seq = self._seq
            bundle = self._capture(reason, seq, extra)
            path = self._write(reason, seq, bundle)
            self.incidents.append(path)
            return path

    def _capture(self, reason: str, seq: int, extra: Optional[dict]) -> dict:
        bundle = {
            "trigger": {
                "reason": reason,
                "seq": seq,
                "ts_unix": time.time(),
                "ts_ns": time.perf_counter_ns(),
                **({"context": extra} if extra else {}),
            },
            "metadata": self.metadata,
            "tracers": [],
            "sources": {},
        }
        for t in self._tracers:
            evs = t.events()[-self.max_events:]
            bundle["tracers"].append({
                "name": t.name,
                "pid": t.pid,
                "events": evs,
                "recorded": t.recorded,
                "dropped": t.dropped,
                "live_read": True,   # rings may be mid-write; see docstring
            })
        for name, fn in self._sources.items():
            try:
                bundle["sources"][name] = fn()
            except Exception as e:  # evidence > purity during an incident
                bundle["sources"][name] = {"error": repr(e)}
        return bundle

    def _write(self, reason: str, seq: int, bundle: dict) -> str:
        os.makedirs(self.incident_dir, exist_ok=True)
        slug = _REASON_RE.sub("-", reason).strip("-") or "incident"
        path = os.path.join(self.incident_dir,
                            f"incident-{seq:03d}-{slug}.json")
        with open(path, "w") as f:
            json.dump(bundle, f, default=str)
        return path

    # -- built-in trigger policies -------------------------------------------

    def record_breaches(self, report: SloReport) -> List[str]:
        """One bundle per target transitioning into BREACH this report."""
        paths = []
        for t in report.breaches:
            p = self.trigger(f"slo-breach-{t.name}", extra={
                "target": t.name,
                "burn_short": t.burn_short,
                "burn_long": t.burn_long,
                "prev_state": t.prev_state,
                "report": report.as_dict(),
            })
            if p:
                paths.append(p)
        return paths

    def check_engine(self, engine, *, free_frac: float = 0.05,
                     min_accept: float = 0.2, min_drafted: int = 64,
                     max_preempt_frac: float = 0.5) -> List[str]:
        """Evaluate built-in pressure triggers against a live engine:
        allocator nearly exhausted (free fraction below `free_frac`, the
        CoW-eviction death spiral precursor), speculative acceptance
        collapse (acceptance below `min_accept` once at least `min_drafted`
        tokens have been drafted — an ngram drafter gone pathological costs
        a full verify step per miss), and preemption pressure (KV swap-outs
        exceeding `max_preempt_frac` of admitted requests — the scheduler
        is thrashing batch work in and out instead of making progress;
        admission or pool sizing needs attention)."""
        paths = []
        st = engine.alloc.stats()
        total = st["in_use"] + st["reserved"] + st["free"]
        if total > 0 and st["free"] / total < free_frac:
            p = self.trigger("allocator-pressure", extra=st)
            if p:
                paths.append(p)
        m = engine.metrics
        if (m.spec_draft_tokens >= min_drafted
                and m.acceptance_rate < min_accept):
            p = self.trigger("spec-acceptance-collapse", extra={
                "drafted": m.spec_draft_tokens,
                "accepted": m.spec_accepted_tokens,
                "acceptance_rate": m.acceptance_rate,
            })
            if p:
                paths.append(p)
        # getattr-guarded: check_engine also serves partial engine doubles
        # (tests, external health probes) that predate preemption fields.
        admitted = getattr(getattr(engine, "scheduler", None),
                           "admitted_total", 0)
        preempts = getattr(m, "preemptions", 0)
        if (admitted > 0 and preempts > 0
                and preempts / admitted > max_preempt_frac):
            p = self.trigger("preemption-pressure", extra={
                "preemptions": preempts,
                "admitted_total": admitted,
                "swap_out_blocks": getattr(m, "swap_out_blocks", 0),
                "swap_in_blocks": getattr(m, "swap_in_blocks", 0),
                "swap_time_s": getattr(m, "swap_time_s", 0.0),
            })
            if p:
                paths.append(p)
        return paths

    @staticmethod
    def is_breach(report: SloReport) -> bool:
        return report.state == BREACH
