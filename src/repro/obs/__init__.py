"""repro.obs — live utilization tracing, streaming metrics, and SLOs.

The pieces, one layer (see each module's docstring):

  * trace.py    — pre-allocated ring-buffer span/event log (per-request
                  lifecycle + per-tick phases + cross-lane request flows),
                  single-writer per engine thread, Chrome-trace exportable;
  * hist.py     — log-bucketed streaming histograms with nearest-rank
                  percentiles and merge (bounded replacement for raw
                  request lists in engine/cluster metrics), plus the one
                  shared nearest-rank ``percentile`` helper;
  * mfu.py      — per-phase utilization (measured vs the cycle-model/
                  roofline analytic bound) and MFU gauges, the paper's
                  Table 2 utilization computed live at serving time;
  * export.py   — Perfetto/chrome://tracing JSON export, flow arrows and
                  instants included;
  * slo.py      — declarative SLO targets with multi-window burn-rate
                  evaluation and an ok/warn/breach state machine;
  * recorder.py — anomaly flight recorder: ring-buffer + metric snapshots
                  into JSON incident bundles on breach/pressure triggers.

Threaded through serving/engine.py (``Engine(trace=True)``),
cluster/replica.py (``ReplicaPool(trace=True)``), cluster/router.py
(``Router(tracer=..., recorder=...)``), and launch/serve.py
(``--trace-out`` / ``--metrics-json`` / ``--slo`` / ``--incident-dir``).
"""

from repro.obs.hist import Histogram, nearest_rank_index, percentile
from repro.obs.mfu import MfuMeter, PHASES, PhaseStat
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer
from repro.obs.export import (
    chrome_trace_events,
    trace_document,
    write_chrome_trace,
)
from repro.obs.slo import (
    BREACH,
    OK,
    WARN,
    SloMonitor,
    SloReport,
    SloTarget,
    engine_snapshot,
    parse_slo_spec,
)
from repro.obs.recorder import FlightRecorder

__all__ = [
    "Histogram",
    "nearest_rank_index",
    "percentile",
    "MfuMeter",
    "PHASES",
    "PhaseStat",
    "NULL_TRACER",
    "NullTracer",
    "Tracer",
    "chrome_trace_events",
    "trace_document",
    "write_chrome_trace",
    "OK",
    "WARN",
    "BREACH",
    "SloMonitor",
    "SloReport",
    "SloTarget",
    "engine_snapshot",
    "parse_slo_spec",
    "FlightRecorder",
]
