"""repro.obs — live utilization tracing and streaming metrics.

Three pieces, one layer (see each module's docstring):

  * trace.py  — pre-allocated ring-buffer span/event log (per-request
                lifecycle + per-tick phases), single-writer per engine
                thread, Chrome-trace exportable;
  * hist.py   — log-bucketed streaming histograms with nearest-rank
                percentiles and merge (bounded replacement for raw request
                lists in engine/cluster metrics);
  * mfu.py    — per-phase utilization (measured vs the cycle-model/roofline
                analytic bound) and MFU gauges, the paper's Table 2
                utilization computed live at serving time;
  * export.py — Perfetto/chrome://tracing JSON export.

Threaded through serving/engine.py (``Engine(trace=True)``),
cluster/replica.py (``ReplicaPool(trace=True)``), and launch/serve.py
(``--trace-out`` / ``--metrics-json``).
"""

from repro.obs.hist import Histogram
from repro.obs.mfu import MfuMeter, PHASES, PhaseStat
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer
from repro.obs.export import (
    chrome_trace_events,
    trace_document,
    write_chrome_trace,
)

__all__ = [
    "Histogram",
    "MfuMeter",
    "PHASES",
    "PhaseStat",
    "NULL_TRACER",
    "NullTracer",
    "Tracer",
    "chrome_trace_events",
    "trace_document",
    "write_chrome_trace",
]
