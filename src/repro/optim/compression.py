"""Gradient compression with error feedback (int8, 128-wide block scales).

For cross-pod data parallelism the gradient all-reduce crosses the slow
inter-pod links; compressing to int8 cuts that traffic 2x vs bf16 / 4x vs
f32.  Plain quantization biases training; error feedback (Seide et al.,
1-bit SGD lineage) accumulates the quantization residual locally and adds it
back before the next step's compression, making the scheme unbiased in the
long run.

Usage (composes with any optimizer):

    ef = init_error_feedback(grads)
    (q_grads, ef) = compress_with_feedback(grads, ef)
    # ... all-reduce q_grads (int8 payload + f32 block scales) ...
    grads = decompress(q_grads)
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import BlockQ, _bq_decode, _bq_encode


def init_error_feedback(grads) -> Any:
    return jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compress_with_feedback(grads, ef_state) -> Tuple[Any, Any]:
    """Returns (BlockQ pytree, new error-feedback state)."""

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q = _bq_encode(corrected)
        residual = corrected - _bq_decode(q, g.shape)
        return q, residual

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    qs = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    efs = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    return qs, efs


def decompress(q_grads, template) -> Any:
    is_bq = lambda x: isinstance(x, BlockQ)
    flat_q = jax.tree_util.tree_leaves(q_grads, is_leaf=is_bq)
    flat_t, tree = jax.tree_util.tree_flatten(template)
    out = [
        _bq_decode(q, t.shape).astype(t.dtype) for q, t in zip(flat_q, flat_t)
    ]
    return jax.tree_util.tree_unflatten(tree, out)


def compressed_bytes(q_grads) -> int:
    """Wire size of the compressed payload (int8 + block scales)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
        q_grads, is_leaf=lambda x: isinstance(x, BlockQ)
    ):
        total += leaf.q.size + leaf.scale.size * 4
    return total
