from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    warmup_cosine,
    global_norm,
)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "warmup_cosine",
    "global_norm",
]
