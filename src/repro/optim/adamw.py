"""AdamW with gradient clipping, warmup-cosine schedule, and compressed
optimizer state (bf16 or block-quantized int8 moments).

State compression is the memory-side analogue of gradient compression: the
477B-parameter configs only fit a 256-chip pod's HBM with sub-fp32 moments
(fp32 m+v alone would be 3.8 GB/chip * 4). int8 moments use 128-wide
block scales (8-bit-Adam style).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class BlockQ(NamedTuple):
    """Block-quantized tensor: q int8, scale f32 per 128-wide block."""

    q: jax.Array
    scale: jax.Array


_BLOCK = 128


def _bq_encode(x: jax.Array) -> BlockQ:
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return BlockQ(q=q, scale=scale.astype(jnp.float32))


def _bq_decode(bq: BlockQ, shape, dtype=jnp.float32) -> jax.Array:
    flat = (bq.q.astype(jnp.float32) * bq.scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"       # float32 | bfloat16 | int8


def adamw_init(params, cfg: AdamWConfig):
    if cfg.state_dtype == "int8":
        zeros = lambda p: _bq_encode(jnp.zeros_like(p, jnp.float32))
    else:
        dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
        zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(
    grads,
    state,
    params,
    lr: jax.Array,
    cfg: AdamWConfig,
) -> Tuple[Any, Any]:
    count = state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    else:
        clip = jnp.asarray(1.0)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    int8 = cfg.state_dtype == "int8"
    state_dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "int8": None}[cfg.state_dtype]

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = _bq_decode(m, p.shape) if int8 else m.astype(jnp.float32)
        vf = _bq_decode(v, p.shape) if int8 else v.astype(jnp.float32)
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        update = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * pf
        new_p = (pf - lr * update).astype(p.dtype)
        new_m = _bq_encode(mf) if int8 else mf.astype(state_dt)
        new_v = _bq_encode(vf) if int8 else vf.astype(state_dt)
        return new_p, new_m, new_v

    is_bq = lambda x: isinstance(x, BlockQ)
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"], is_leaf=is_bq)
    flat_v = jax.tree_util.tree_leaves(state["v"], is_leaf=is_bq)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}


def warmup_cosine(
    base_lr: float, warmup: int, total: int, min_frac: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(1, warmup)
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return sched
