"""parallel subpackage."""
