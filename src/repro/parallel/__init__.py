"""Sharding and pipeline parallelism: the stable ``repro.parallel`` API.

Lazy re-exports (mirroring repro.serving's ``__getattr__`` table) so
``from repro.parallel import shard`` is a stable import without eagerly
loading the mesh/pipeline machinery into every model-layer import.
"""

import importlib

_SUBMODULES = ("logical", "pipeline", "sharding")

_LAZY = {
    # logical axis rules (the model layer's shard() calls resolve here)
    "use_rules": ("repro.parallel.logical", "use_rules"),
    "resolve_spec": ("repro.parallel.logical", "resolve_spec"),
    "shard": ("repro.parallel.logical", "shard"),
    "sharding_for": ("repro.parallel.logical", "sharding_for"),
    # plans: params/batch/cache shardings from a mesh + plan
    "ParallelPlan": ("repro.parallel.sharding", "ParallelPlan"),
    "make_plan": ("repro.parallel.sharding", "make_plan"),
    "param_sharding": ("repro.parallel.sharding", "param_sharding"),
    "batch_sharding": ("repro.parallel.sharding", "batch_sharding"),
    "cache_sharding": ("repro.parallel.sharding", "cache_sharding"),
    # pipeline parallelism
    "gpipe": ("repro.parallel.pipeline", "gpipe"),
    "split_stages": ("repro.parallel.pipeline", "split_stages"),
}

__all__ = sorted(set(_SUBMODULES) | set(_LAZY))


def __getattr__(name: str):
    if name in _LAZY:
        module, attr = _LAZY[name]
        return getattr(importlib.import_module(module), attr)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.parallel.{name}")
    raise AttributeError(f"module 'repro.parallel' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
