"""Logical axis sharding: model code annotates, the runtime decides.

Model code calls `shard(x, "batch", "seq", "model_d")` with *logical* axis
names.  Outside a mesh context this is a no-op (CPU smoke tests); inside
`use_rules(mesh, rules)` each logical name maps to zero or more mesh axes and
the annotation becomes `jax.lax.with_sharding_constraint`.

This is the multi-pod analogue of the paper's strided-memory-access layout
optimization: the rule table is the "data layout" that keeps the compiled
collective schedule conflict-free (no resharding between layers).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, MeshAxes]

_state = threading.local()


def _current() -> Optional[Tuple[Mesh, Rules]]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Rules):
    """Activate a (mesh, logical-rule) context for `shard` annotations."""
    prev = _current()
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def resolve_spec(names: Sequence[Optional[str]], rules: Rules) -> P:
    axes = []
    used: set = set()
    for n in names:
        if n is None:
            axes.append(None)
            continue
        a = rules.get(n)
        # A mesh axis may appear only once in a PartitionSpec; later logical
        # dims that map to an already-used axis fall back to replication.
        if a is None:
            axes.append(None)
        elif isinstance(a, tuple):
            fresh = tuple(x for x in a if x not in used)
            used.update(fresh)
            axes.append(fresh if fresh else None)
        else:
            if a in used:
                axes.append(None)
            else:
                used.add(a)
                axes.append(a)
    return P(*axes)


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Annotate `x` with the sharding implied by logical axis `names`."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(names) != x.ndim:
        raise ValueError(f"{len(names)} names for rank-{x.ndim} array")
    spec = resolve_spec(names, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_for(mesh: Mesh, rules: Rules, *names: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(names, rules))
