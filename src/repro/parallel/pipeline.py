"""Pipeline parallelism (GPipe) over the "pod" axis.

The multi-pod mesh's "pod" axis defaults to data parallelism; for models
whose layers exceed single-pod HBM even with FSDP, it can instead carry a
pipeline: layer groups are split into `n_stages` contiguous stages (stage s
owns groups [s*G/S, (s+1)*G/S)), microbatches flow through a GPipe schedule,
and activations hop stages with `jax.lax.ppermute` inside `shard_map`.

The schedule is the classic (n_micro + n_stages - 1)-tick loop: at tick t,
stage s computes microbatch (t - s) when 0 <= t-s < n_micro.  Autodiff
through ppermute gives the reverse-direction backward hops for free, so the
same function trains (jax.grad) — bubble fraction (S-1)/(T+S-1) as usual.

This is intentionally a *composable* transform: `gpipe` takes any
stage function (carry = activations), so it wraps the model zoo's
`apply_group` unchanged.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe(
    stage_fn: Callable,      # (stage_params, x) -> x     (one stage's layers)
    mesh: Mesh,
    axis: str = "pod",
    n_micro: int = 4,
):
    """Build a pipelined apply: (stage_params_stacked, x_micro) -> y_micro.

    stage_params_stacked: pytree with leading dim n_stages (sharded on
    `axis`); x_micro: (n_micro, mb, ...) replicated along `axis`.
    Returns (n_micro, mb, ...) outputs (valid on the last stage, replicated
    back via ppermute ring so every shard holds them).
    """
    n_stages = mesh.shape[axis]

    def pipelined(stage_params, x_micro):
        # Inside shard_map: stage_params has its leading stage dim sliced
        # away (size 1) -> squeeze; x_micro fully replicated.
        stage_params = jax.tree_util.tree_map(lambda p: p[0], stage_params)
        sid = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        mb_shape = x_micro.shape[1:]

        def tick(carry, t):
            act, outputs = carry
            # stage 0 injects microbatch t (if still valid)
            inject = jnp.where(t < n_micro, t, 0)
            act = jnp.where(sid == 0, x_micro[inject], act)
            # every stage computes (garbage outside its active window is
            # masked at collection time)
            y = stage_fn(stage_params, act)
            # last stage collects microbatch (t - (S-1))
            out_idx = t - (n_stages - 1)
            take = jnp.logical_and(sid == n_stages - 1, out_idx >= 0)
            outputs = jax.lax.cond(
                take,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), axis=0),
                lambda o: o,
                outputs,
            )
            # shift activations stage s -> s+1 (ring; stage 0's recv is
            # overwritten by injection next tick)
            act = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return (act, outputs), None

        act0 = jnp.zeros(mb_shape, x_micro.dtype)
        outs0 = jnp.zeros((n_micro, *mb_shape), x_micro.dtype)
        (_, outputs), _ = jax.lax.scan(tick, (act0, outs0), jnp.arange(n_ticks))
        # outputs are zero except on the last stage: a psum replicates them.
        return jax.lax.psum(outputs, axis)

    pspec = P(axis)
    return shard_map(
        pipelined, mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        check_rep=False,
    )


def split_stages(group_params, n_stages: int):
    """Reshape (G, ...) stacked group params into (n_stages, G/S, ...)."""

    def leaf(p):
        G = p.shape[0]
        assert G % n_stages == 0, (G, n_stages)
        return p.reshape(n_stages, G // n_stages, *p.shape[1:])

    return jax.tree_util.tree_map(leaf, group_params)
