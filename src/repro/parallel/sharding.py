"""Sharding rules: DP / FSDP(ZeRO-3) / TP / EP over the production mesh.

Two rule layers:
  * activation rules — logical names used by `repro.parallel.logical.shard`
    annotations inside the models;
  * parameter rules — path-pattern table mapping every parameter in the zoo
    to a PartitionSpec.

Design (see DESIGN.md §7): batch over ("pod","data"); attention heads, MLP
hidden, experts and vocab over "model" (TP/EP); for models above
`fsdp_threshold` parameters the non-model dimension of every weight is
additionally sharded over the data axes (FSDP) so params + optimizer state
fit HBM, with XLA inserting the all-gather-on-use (overlapped by the
scheduler — the paper's input-pre-fetch mechanism at pod scale).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.logical import Rules


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """Resolved parallelism decisions for one (arch, mesh) pair.

    Two modes over the same fixed production mesh:
      * "tp"  — tensor parallel over the "model" axis + DP over pod/data,
        with FSDP over the DP axes for models whose replicated state would
        not fit HBM.  For the 12B-480B archs.
      * "dp"  — the model axis joins the batch axes (pure 256/512-way data
        parallel) and parameters are FSDP-sharded over everything.  For the
        <4B archs whose head/ffn dims cannot feed a 16-way TP axis without
        padding waste (gemma3: 4 heads).
    """

    batch_axes: Tuple[str, ...]          # mesh axes carrying data parallelism
    model_axis: Optional[str] = "model"  # None => dp mode (no TP)
    fsdp: bool = False                   # ZeRO-3 parameter sharding
    fsdp_axes: Tuple[str, ...] = ()      # axes used for FSDP
    # Attention sharding strategy.  Head-TP is only collective-free when the
    # KV heads divide the model axis; otherwise GSPMD re-shards the
    # (B, Hkv, G, S, D) tensors on every KV-block-scan step ("involuntary
    # full rematerialization", ~TBs of all-gather per step).  When heads
    # don't divide, we shard attention over the *sequence* instead: q and
    # the attention output are seq-sharded on the model axis, K/V replicate
    # across it, and the attention projections become model-replicated
    # (still FSDP over the data axes).
    attn_seq: bool = False
    # Serving: parameters are *statically* 2D-sharded instead of FSDP-
    # gathered (there is no optimizer state to shard against, and an
    # all-gather of 132-477B expert weights per decoded token is the
    # baseline's dominant cost).  Expert FFN weights spread (E -> model,
    # d_ff_expert -> data); the contraction over the data-sharded d_ff dim
    # becomes a tiny activation psum instead of a weight gather.
    expert_2d: bool = False

    def activation_rules(self) -> Rules:
        b = self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]
        M = self.model_axis
        return {
            "batch": b,
            # Sequence parallelism (Megatron-SP): in attn_seq mode the
            # residual stream / norms / attention all run seq-sharded on the
            # model axis; XLA inserts the all-gather before the TP FFN
            # matmuls and a reduce-scatter after, so redundant compute on
            # the model axis disappears.
            "seq": M if self.attn_seq else None,
            "embed": None,
            "heads": None if self.attn_seq else M,
            "kv_heads": None if self.attn_seq else M,
            "attn_seq": M if self.attn_seq else None,
            "mlp": M,
            "vocab": M,
            "expert": M,
        }


def make_plan(
    mesh: Mesh,
    param_count: int,
    *,
    n_kv_heads: Optional[int] = None,
    tp_threshold: int = 4_000_000_000,
    fsdp_threshold: int = 8_000_000_000,
    force_fsdp: Optional[bool] = None,
    force_mode: Optional[str] = None,
    force_attn_seq: Optional[bool] = None,
    serving: bool = False,
) -> ParallelPlan:
    axes = mesh.axis_names
    dp_axes = tuple(a for a in axes if a in ("pod", "data"))
    mode = force_mode or ("tp" if param_count > tp_threshold else "dp")
    if "model" not in axes or mesh.shape.get("model", 1) == 1:
        mode = "dp" if force_mode is None else mode
    if serving and mode == "tp":
        # Static weight sharding for inference: no optimizer state to
        # co-shard, so FSDP's per-step gathers are pure overhead.  TP for
        # dense weights; 2D (model x data) for MoE experts.  Prefill keeps
        # the seq-sharded attention rule (long sequences); decode forces it
        # off (S_q = 1, and its tiny tensors reshard for free).
        model_size = mesh.shape["model"]
        if force_attn_seq is not None:
            attn_seq = force_attn_seq
        else:
            attn_seq = bool(n_kv_heads) and (n_kv_heads % model_size != 0)
        return ParallelPlan(
            batch_axes=dp_axes, model_axis="model",
            fsdp=True, fsdp_axes=dp_axes,   # static 2nd axis for big weights
            attn_seq=attn_seq, expert_2d=True,
        )
    if mode == "dp":
        if serving:
            # Small-model serving: static TP over the model axis (a <4B
            # model fits 16-way sharded); FSDP's per-token weight gathers
            # are the dp-mode decode baseline's entire cost.
            return ParallelPlan(
                batch_axes=dp_axes, model_axis="model",
                fsdp=False, fsdp_axes=(), attn_seq=False,
            )
        batch_axes = tuple(a for a in axes)
        return ParallelPlan(
            batch_axes=batch_axes, model_axis=None,
            fsdp=True if force_fsdp is None else force_fsdp,
            fsdp_axes=batch_axes,
        )
    fsdp = param_count > fsdp_threshold if force_fsdp is None else force_fsdp
    model_size = mesh.shape["model"]
    if force_attn_seq is not None:
        attn_seq = force_attn_seq
    else:
        attn_seq = bool(n_kv_heads) and (n_kv_heads % model_size != 0)
    return ParallelPlan(
        batch_axes=dp_axes,
        model_axis="model",
        fsdp=fsdp,
        fsdp_axes=dp_axes if fsdp else (),
        attn_seq=attn_seq,
    )


# --- parameter sharding -------------------------------------------------------

# (path regex, spec builder) — first match wins.  `F` is the FSDP axis group
# (or None), "model" the tensor-parallel axis.
def _param_spec(path: str, ndim: int, plan: ParallelPlan) -> P:
    F = plan.fsdp_axes if plan.fsdp else None
    M = plan.model_axis
    # seq-sharded attention: projections replicate over the model axis
    # (FSDP still shards them over data) — see ParallelPlan.attn_seq.
    AM = None if plan.attn_seq else M
    table = [
        # embeddings / unembedding
        (r"embed$", {2: P(M, F)}),
        (r"head$", {2: P(F, M)}),
        (r"projector$", {2: P(F, M)}),
        # attention projections
        (r"(wq|wk|wv)$", {2: P(F, AM)}),
        (r"(bq|bk|bv)$", {1: P(AM)}),
        (r"wo$", {2: P(AM, F)}),
        # MLP (rank-3 = MoE expert weights; 2D-sharded when serving)
        (r"(w_gate|w_up|w_ff_up)$",
         {2: P(F, M), 3: P(M, None, plan.fsdp_axes) if plan.expert_2d else P(M, F, None)}),
        (r"(w_down|w_ff_down)$",
         {2: P(M, F), 3: P(M, plan.fsdp_axes, None) if plan.expert_2d else P(M, None, F)}),
        (r"(b_up|b_down)$", {1: P(M)}),
        # MoE
        (r"router$", {2: P(F, None)}),
        # Mamba
        (r"w_in$", {2: P(F, M)}),
        (r"conv_[wb]$", {1: P(M), 2: P(None, M)}),
        (r"w_x$", {2: P(M, None)}),
        (r"w_dt$", {2: P(None, M)}),
        (r"b_dt$", {1: P(M)}),
        (r"A_log$", {2: P(M, None)}),
        (r"D$", {1: P(M)}),
        (r"w_out$", {2: P(M, F)}),
        # xLSTM
        (r"w_(q|k|v|i|f)$", {2: P(None, M)}),
        (r"w_[izfo]$", {2: P(F, M)}),
        (r"r_[izfo]$", {3: P(None, None, None)}),
        (r"b_[if]$", {1: P(None), 2: P(None, None)}),
        # norms and everything else: replicated
    ]
    for pat, by_rank in table:
        if re.search(pat, path):
            spec = by_rank.get(ndim)
            if spec is not None:
                return spec
    return P(*([None] * ndim))


def _stacked(spec: P, extra_leading: int) -> P:
    """Prepend `extra_leading` None dims (scan-group / vmap stacking)."""
    return P(*([None] * extra_leading + list(spec)))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def param_sharding(params, mesh: Mesh, plan: ParallelPlan):
    """NamedSharding pytree for a (possibly group-stacked) params pytree.

    Parameters under the top-level "blocks"/"encoder_blocks" keys carry one
    leading stacking dimension (the scan-group axis); the rule table below is
    written against the *unstacked* rank.
    """

    def leaf(path, x):
        p = _path_str(path)
        extra = 1 if p.split("/", 1)[0] in ("blocks", "encoder_blocks") else 0
        base_rank = x.ndim - extra
        if plan.model_axis is None:
            # dp mode: pure FSDP — shard the largest divisible dim (skipping
            # the stacking dim) over all FSDP axes.
            size = 1
            for a in plan.fsdp_axes:
                size *= mesh.shape[a]
            spec_l: list = [None] * x.ndim
            dims = sorted(range(extra, x.ndim), key=lambda d: -x.shape[d])
            for d in dims:
                if x.shape[d] % size == 0 and x.shape[d] >= size:
                    spec_l[d] = plan.fsdp_axes
                    break
            return NamedSharding(mesh, P(*spec_l))
        spec = _param_spec(p, base_rank, plan)
        spec = _stacked(spec, extra)
        # Guard: drop mesh axes that don't divide the dim (GSPMD would pad;
        # we prefer replication for correctness-of-intent on tiny dims).
        fixed = []
        for dim, ax in zip(x.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axs:
                size *= mesh.shape[a]
            fixed.append(ax if dim % size == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(leaf, params)


def _best_batch_axes(bsz: int, axes: Tuple[str, ...], mesh: Mesh) -> Tuple[str, ...]:
    """Largest contiguous subsequence of `axes` whose size divides `bsz`."""
    best: Tuple[str, ...] = ()
    best_size = 1
    n = len(axes)
    for i in range(n):
        for j in range(i + 1, n + 1):
            sub = axes[i:j]
            size = 1
            for a in sub:
                size *= mesh.shape[a]
            if bsz % size == 0 and size > best_size:
                best, best_size = sub, size
    return best


def batch_sharding(batch, mesh: Mesh, plan: ParallelPlan):
    """Shard every batch leaf on its leading (batch) dimension, using the
    largest divisor subset of the DP axes (decode_32k's batch 128 shards
    16-way on "data" under the 256-chip mesh; long_500k's batch 1 replicates)."""

    def leaf(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        axes = _best_batch_axes(x.shape[0], plan.batch_axes, mesh)
        if not axes:
            return NamedSharding(mesh, P(*([None] * x.ndim)))
        spec0 = axes if len(axes) > 1 else axes[0]
        return NamedSharding(mesh, P(spec0, *([None] * (x.ndim - 1))))

    return jax.tree_util.tree_map(leaf, batch)


def cache_sharding(cache_struct, mesh: Mesh, plan: ParallelPlan):
    """Decode-state sharding: batch on data axes, heads/d_inner on model.

    Cache leaves (after group stacking, leading G dim):
      KV:        (G, B, S, H_kv, hd)
      Mamba h:   (G, B, d_inner, d_state);  conv (G, B, dc-1, d_inner)
      mLSTM C:   (G, B, H, hd, hd); n (G, B, H, hd); m (G, B, H)
      sLSTM:     (G, B, H, hd) x3; m (G, B, H)
    """
    M = plan.model_axis

    def leaf(path, x):
        spec: list = [None] * x.ndim
        if x.ndim >= 2:
            axes = _best_batch_axes(x.shape[1], plan.batch_axes, mesh)
            if axes:
                spec[1] = axes if len(axes) > 1 else axes[0]
        # shard the "wide" state dim on model where divisible
        if M is not None:
            for d in range(2, x.ndim):
                if x.shape[d] % mesh.shape[M] == 0 and x.shape[d] >= mesh.shape[M]:
                    spec[d] = M
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, cache_struct)
