"""Fault-tolerant training runtime.

Single-controller design that scales to a multi-pod fleet:

  * every train step is a pure function of (params, opt_state, batch_cursor)
    — the complete job state is (params, opt, step, cursor), checkpointed
    asynchronously every `ckpt_every` steps with atomic commit;
  * the Supervisor runs the step loop under a retry harness: any exception
    (in production: a failed host barrier / ICI timeout after a chip loss)
    triggers restore-from-latest and continue — `simulate_failure_at` lets
    tests inject deterministic failures;
  * straggler mitigation: per-step wall times feed an EWMA watchdog; steps
    slower than `straggler_factor` x the EWMA are counted and surfaced so an
    orchestrator can drain the slow host (on a real fleet this is the signal
    for preemptive re-scheduling); the watchdog is also exposed as a hook;
  * elastic re-mesh: checkpoints store logical (unsharded) arrays, so
    `Supervisor.restore(..., shardings=new)` resumes on a different mesh
    (tests exercise 1-device -> 2x1 mesh restore).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    straggler_factor: float = 3.0
    max_restarts: int = 5
    log_every: int = 10


class Supervisor:
    def __init__(
        self,
        train_step: Callable,            # (params, opt, batch) -> (params, opt, metrics)
        data_at: Callable[[int], Any],   # cursor -> host batch
        loop_cfg: TrainLoopConfig,
        *,
        put_batch: Optional[Callable[[Any], Any]] = None,
        on_straggler: Optional[Callable[[int, float, float], None]] = None,
        simulate_failure_at: Optional[int] = None,
    ):
        self.train_step = train_step
        self.data_at = data_at
        self.cfg = loop_cfg
        self.put_batch = put_batch or (lambda b: b)
        self.on_straggler = on_straggler
        self.simulate_failure_at = simulate_failure_at
        self.ckpt = AsyncCheckpointer(loop_cfg.ckpt_dir, keep_last=loop_cfg.keep_last)
        self.restarts = 0
        self.straggler_steps = 0
        self.metrics_log: list = []

    # -- state (de)hydration ---------------------------------------------------

    def _pack(self, params, opt_state, step: int):
        return {"params": params, "opt": opt_state, "step": np.int64(step)}

    def restore(self, template_params, template_opt, shardings=None):
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return None
        tree = restore_checkpoint(
            self.cfg.ckpt_dir, step,
            self._pack(template_params, template_opt, 0),
            shardings,
        )
        return tree["params"], tree["opt"], int(tree["step"])

    # -- the supervised loop ----------------------------------------------------

    def run(self, params, opt_state, start_step: int = 0) -> Dict[str, Any]:
        step = start_step
        ewma = None
        while step < self.cfg.total_steps:
            try:
                step, params, opt_state, ewma = self._run_span(
                    params, opt_state, step, ewma
                )
            except _SimulatedFailure:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError("restart budget exhausted")
                self.ckpt.wait()
                restored = self.restore(params, opt_state)
                if restored is None:
                    step = start_step
                else:
                    params, opt_state, step = restored
                # do not re-fire the same simulated failure
                self.simulate_failure_at = None
        self.ckpt.wait()
        return {
            "params": params,
            "opt_state": opt_state,
            "step": step,
            "restarts": self.restarts,
            "straggler_steps": self.straggler_steps,
            "metrics": self.metrics_log,
        }

    def _run_span(self, params, opt_state, step, ewma):
        while step < self.cfg.total_steps:
            if self.simulate_failure_at is not None and step == self.simulate_failure_at:
                raise _SimulatedFailure()
            batch = self.put_batch(self.data_at(step))
            t0 = time.perf_counter()
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if ewma is None:
                ewma = dt
            if dt > self.cfg.straggler_factor * ewma and step > start_grace(step):
                self.straggler_steps += 1
                if self.on_straggler:
                    self.on_straggler(step, dt, ewma)
            ewma = 0.9 * ewma + 0.1 * dt
            step += 1
            if step % self.cfg.log_every == 0:
                self.metrics_log.append(
                    {"step": step, "loss": float(metrics["loss"]), "sec": dt}
                )
            if step % self.cfg.ckpt_every == 0 or step == self.cfg.total_steps:
                self.ckpt.save(step, self._pack(params, opt_state, step))
        return step, params, opt_state, ewma


def start_grace(step: int) -> int:
    """First steps include compile time; exempt them from straggler counting."""
    return 2


class _SimulatedFailure(RuntimeError):
    pass
