from repro.runtime.supervisor import Supervisor, TrainLoopConfig

__all__ = ["Supervisor", "TrainLoopConfig"]
