from repro.data.pipeline import SyntheticLMData, Prefetcher

__all__ = ["SyntheticLMData", "Prefetcher"]
