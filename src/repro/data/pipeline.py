"""Data pipeline: deterministic synthetic LM batches + host-side prefetch.

The prefetcher is the paper's input-pre-fetch mechanism at the host scale: a
depth-D buffer filled by a producer thread that stages the next batches onto
device (jax.device_put with the target sharding) while the current step
computes.  The cursor is part of the checkpointed training state, so a
restart resumes mid-epoch deterministically (fault tolerance contract).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


class SyntheticLMData:
    """Deterministic, restartable synthetic token stream.

    Batch `i` is a pure function of (seed, i): restarting from a checkpointed
    cursor reproduces the exact stream a real sharded corpus reader would.
    """

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 extras: Optional[Dict[str, tuple]] = None):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.extras = extras or {}

    def batch_at(self, cursor: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 32) | (cursor & 0xFFFFFFFF))
        # Markov-ish stream: mixture of a random walk and uniform noise so the
        # LM loss is learnable (quickstart shows it decreasing).
        base = rng.integers(0, self.vocab, size=(self.batch, 1))
        steps = rng.integers(-3, 4, size=(self.batch, self.seq + 1))
        walk = (base + np.cumsum(steps, axis=1)) % self.vocab
        noise = rng.integers(0, self.vocab, size=(self.batch, self.seq + 1))
        use_noise = rng.random((self.batch, self.seq + 1)) < 0.1
        toks = np.where(use_noise, noise, walk).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        for name, shape in self.extras.items():
            out[name] = rng.standard_normal((self.batch, *shape)).astype(np.float32)
        return out

    def iterate(self, start: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        cursor = start
        while True:
            yield self.batch_at(cursor)
            cursor += 1


class Prefetcher:
    """Depth-D device prefetch (paper Sec. 3.3, host-scale analogue)."""

    def __init__(self, it: Iterator, depth: int = 3, shardings=None):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._shardings = shardings
        self._stop = threading.Event()

        def produce():
            for item in it:
                if self._stop.is_set():
                    return
                if self._shardings is not None:
                    item = jax.device_put(item, self._shardings)
                self._q.put(item)
            self._q.put(None)

        self._thread = threading.Thread(target=produce, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
