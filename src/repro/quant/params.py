"""Int8-resident model parameters: quantize weights once at load time.

The paper's deployment story quantizes *weights ahead of time* (they are
static) and activations on the fly (they are not).  `quantize_params` walks a
model's params pytree and replaces every eligible projection matrix with a
`QuantTensor` — int8 values plus float32 per-output-column scales — so:

  * weight memory drops ~4x for the quantized matrices (int8 vs f32, the
    per-column scale rows are noise), and
  * the serving hot path never re-quantizes weights: `ops.linear` sees the
    `QuantTensor` and goes straight to the int8 GeMM with the stored scales,
    where the on-the-fly `quant="int8"` path pays a full weight pass per call.

`QuantTensor` is a NamedTuple, hence a pytree node: stacked group weights
(G, K, N) quantize to q (G, K, N) int8 + scale (G, 1, N), and `jax.lax.scan`
over the block groups slices both leaves in lock step — the scanned model
code needs no changes.

Eligibility is by leaf name (`QUANT_KEYS`): the attention q/k/v/o projections,
the MLP matrices, the mamba in/out projections and the LM head.  Embedding
tables stay float (they are gathered, not multiplied), as do norms, biases,
convs and the SSM dt/gate projections (numerically sensitive recurrence
inputs — see models/ssm.py).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

# Leaf names that quantize well and sit on the serving hot path.
QUANT_KEYS = frozenset({
    "wq", "wk", "wv", "wo",                 # attention projections
    "w_gate", "w_up", "w_down",             # MLP (swiglu / gelu) + mLSTM up/down
    "w_in", "w_out",                        # mamba in/out projections
    "w_q", "w_k", "w_v",                    # mLSTM q/k/v projections
    "w_ff_up", "w_ff_down",                 # sLSTM GLU feed-forward
    "head",                                 # untied LM head
    "projector",                            # VLM vision projector
})


class QuantTensor(NamedTuple):
    """An int8-resident weight: q int8 (..., K, N), scale f32 (..., 1, N),
    and optionally a static per-tensor activation scale (..., 1, 1) from
    calibration (consumed only in "w8a8-calibrated" mode)."""

    q: jax.Array
    scale: jax.Array
    act_scale: Optional[jax.Array] = None

    @property
    def shape(self):
        return self.q.shape

    @property
    def nbytes(self) -> int:
        n = self.q.size + 4 * self.scale.size
        if self.act_scale is not None:
            n += 4 * self.act_scale.size
        return n


def quantize_leaf(w: jax.Array, act_scale=None) -> QuantTensor:
    """Per-output-column symmetric int8 quantization of one weight matrix
    (axis=-2 is the contraction axis, matching y = x @ w)."""
    q, s = ref.quantize_ref(jnp.asarray(w, jnp.float32), axis=-2)
    if act_scale is not None:
        act_scale = jnp.asarray(act_scale, jnp.float32)
    return QuantTensor(q=q, scale=s, act_scale=act_scale)


def dequantize_leaf(t: QuantTensor) -> jax.Array:
    return t.q.astype(jnp.float32) * t.scale


def _stacked_act_scale(scales, path: str, groups: int):
    """Assemble the (G, 1, 1) static activation scale for a stacked group
    leaf from the per-group calibration entries "blocks.{g}.{path}".  All
    groups must be present (a partially calibrated leaf falls back to
    dynamic quantization)."""
    vals = []
    for g in range(groups):
        v = scales.get(f"blocks.{g}.{path}")
        if v is None:
            return None
        vals.append(float(v))
    return jnp.asarray(vals, jnp.float32).reshape(groups, 1, 1)


def quantize_params(
    params: Dict[str, Any],
    *,
    cfg=None,
    scales=None,
    keys: frozenset = QUANT_KEYS,
    tied_head: bool = True,
) -> Dict[str, Any]:
    """Return a copy of `params` with every eligible weight int8-resident.

    `scales` is an optional `calibrate.ScaleTable` (or plain dict of
    per-tensor activation scales); matching entries are attached as static
    `act_scale`s for "w8a8-calibrated" mode.

    With `cfg.tie_embeddings` and `tied_head=True`, an int8 copy of the
    transposed embedding table is added under "head_q" so tied-head models
    do not re-quantize the (vocab x d) unembedding every decode step — the
    float table itself stays (it is gathered by the embedding lookup).
    """
    table = getattr(scales, "scales", scales) or {}

    def walk(tree, path, keys=keys):
        if isinstance(tree, dict):
            # MoE expert FFNs reuse the MLP leaf names but run through the
            # stacked-expert einsum (models/moe.py), not ops.linear — a
            # router sibling marks the dict; its weights stay float.
            if "router" in tree:
                keys = frozenset()
            return {k: walk(v, path + (k,), keys) for k, v in tree.items()}
        if isinstance(tree, QuantTensor):  # already quantized: idempotent
            return tree
        name = path[-1] if path else ""
        if (
            name in keys
            and hasattr(tree, "ndim")
            and tree.ndim >= 2
            and path[0] != "embed"
        ):
            if path[0] == "blocks" and tree.ndim >= 3:
                sub = ".".join(path[1:])
                act = _stacked_act_scale(table, sub, tree.shape[0])
            else:
                v = table.get(".".join(path))
                act = None if v is None else jnp.float32(v)
            return quantize_leaf(tree, act_scale=act)
        return tree

    out = walk(params, ())
    if cfg is not None and getattr(cfg, "tie_embeddings", False) and tied_head:
        v = table.get("head")
        act = None if v is None else jnp.float32(v)
        out["head_q"] = quantize_leaf(
            jnp.asarray(params["embed"], jnp.float32).T, act_scale=act)
    return out


def dequantize_params(params: Dict[str, Any]) -> Dict[str, Any]:
    """Float reconstruction of a quantized pytree ("head_q" dropped — the
    float embedding table is still present and authoritative)."""

    def walk(tree):
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items() if k != "head_q"}
        if isinstance(tree, QuantTensor):
            return dequantize_leaf(tree)
        return tree

    return walk(params)


def weight_bytes(params: Dict[str, Any]) -> int:
    """Total parameter bytes, counting QuantTensors at their packed size."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda t: isinstance(t, QuantTensor)
    ):
        if isinstance(leaf, QuantTensor):
            total += leaf.nbytes
        else:
            total += np.dtype(leaf.dtype).itemsize * leaf.size
    return total


def quantized_leaf_count(params: Dict[str, Any]) -> int:
    return sum(
        isinstance(l, QuantTensor)
        for l in jax.tree_util.tree_leaves(
            params, is_leaf=lambda t: isinstance(t, QuantTensor)
        )
    )
