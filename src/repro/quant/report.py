"""Quantization reporting: per-layer weight error + end-to-end quality delta.

Two questions every int8 deployment has to answer before traffic:

  1. *Where* does precision go?  `layer_error_rows` compares each
     int8-resident weight against its float original (relative Frobenius
     error, max abs error, column-scale spread) so outlier layers are
     visible per parameter path.
  2. *How much* does it cost end to end?  `quality_delta` evaluates the same
     held-out batches in float and in a w8a8 mode and reports the NLL delta
     — the number the acceptance gate and EXPERIMENTS.md quote.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import modes
from repro.quant.params import QuantTensor, dequantize_leaf


# ---------------------------------------------------------------------------
# per-layer weight error
# ---------------------------------------------------------------------------

def layer_error_rows(params_float, params_quant) -> List[Dict[str, Any]]:
    """One row per int8-resident weight: path, shape, relative Frobenius
    error and max abs error of dequantize(quantize(w)) vs w, plus the
    per-column scale spread (max/median — a large ratio flags outlier
    columns that would benefit from per-channel activation treatment)."""

    rows: List[Dict[str, Any]] = []

    def walk(f_tree, q_tree, path):
        if isinstance(q_tree, dict):
            for k, qv in q_tree.items():
                walk(f_tree.get(k) if isinstance(f_tree, dict) else None,
                     qv, path + (k,))
            return
        if not isinstance(q_tree, QuantTensor):
            return
        if f_tree is None and path == ("head_q",):
            f_tree = jnp.asarray(params_float["embed"], jnp.float32).T
        if f_tree is None:
            return
        w = np.asarray(f_tree, np.float32)
        deq = np.asarray(dequantize_leaf(q_tree), np.float32)
        scales = np.asarray(q_tree.scale, np.float32)
        denom = float(np.linalg.norm(w)) or 1.0
        rows.append({
            "path": ".".join(path),
            "shape": tuple(q_tree.q.shape),
            "rel_err": float(np.linalg.norm(deq - w)) / denom,
            "max_abs_err": float(np.max(np.abs(deq - w))),
            "scale_spread": float(scales.max() / max(np.median(scales), 1e-12)),
            "calibrated": q_tree.act_scale is not None,
        })

    walk(params_float, params_quant, ())
    rows.sort(key=lambda r: -r["rel_err"])
    return rows


def format_error_table(rows: List[Dict[str, Any]], *, top: int = 0) -> str:
    """Fixed-width table of `layer_error_rows` output (worst layers first)."""
    shown = rows[:top] if top else rows
    width = max([len(r["path"]) for r in shown] + [5])
    lines = [f"{'layer':<{width}}  {'shape':>18}  {'rel_err':>9}  "
             f"{'max_abs':>9}  {'spread':>7}  calib"]
    for r in shown:
        lines.append(
            f"{r['path']:<{width}}  {str(r['shape']):>18}  "
            f"{r['rel_err']:>9.5f}  {r['max_abs_err']:>9.5f}  "
            f"{r['scale_spread']:>7.2f}  {'yes' if r['calibrated'] else 'no'}"
        )
    if top and len(rows) > top:
        lines.append(f"... {len(rows) - top} more layers")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# end-to-end quality delta
# ---------------------------------------------------------------------------

def eval_nll(params, cfg, batches: Iterable, *, mode: str = "float") -> float:
    """Mean next-token NLL over batches, evaluated under a precision mode.

    Traces fresh each call (no jit cache): the precision mode must bind at
    trace time, and sharing compiled steps across modes would silently
    evaluate the wrong precision (see quant/modes.py)."""
    from repro.models import model as M

    losses = []
    with modes.precision(mode):
        for b in batches:
            b = {k: jnp.asarray(v) for k, v in b.items()}
            logits = M.forward(params, cfg, b)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            ll = jnp.take_along_axis(logp, b["labels"][..., None], -1)
            losses.append(float(-jnp.mean(ll)))
    return float(np.mean(losses))


def quality_delta(
    params_float, params_quant, cfg, batches, *, mode: str = "w8a8",
) -> Dict[str, float]:
    """Float-vs-quantized NLL on the same batches: the end-to-end cost of
    the int8 deployment.  `batches`: dicts with "tokens" and "labels"."""
    batches = list(batches)
    f = eval_nll(params_float, cfg, batches, mode="float")
    q = eval_nll(params_quant, cfg, batches, mode=mode)
    return {
        "float_nll": f,
        "quant_nll": q,
        "delta_nll": q - f,
        "rel_delta": (q - f) / max(abs(f), 1e-12),
        "mode": mode,
    }
