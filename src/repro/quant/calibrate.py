"""Activation calibration: observers over calibration batches -> scale table.

Static ("w8a8-calibrated") activation quantization needs one number per
projection: the scale that maps the layer's typical activation range onto
[-127, 127].  This module collects those numbers by running the model over a
few calibration batches with the `quant.modes` activation tap installed:

  * the forward pass is replayed *eagerly, group by group* (a python loop
    over `cfg.n_groups` instead of the model's `lax.scan`), so every
    `ops.linear` call sees concrete arrays and a concrete weight object;
  * each group's sliced weight leaves are registered by python identity
    (`id(w) -> "blocks.{g}.sub{i}....`"), so a captured (activation, weight)
    pair maps to its exact parameter path with no call-order assumptions;
  * per-path `Observer`s reduce the stream of activations to a scale.

Observers (per-tensor and per-channel variants of each):

  absmax           running max of |x| — tightest coverage, outlier-sensitive
  moving_average   EMA of the per-batch absmax (momentum m): smooths
                   batch-to-batch outliers, the classic PTQ default
  percentile       running max of the per-batch |x| percentile (e.g. 99.9):
                   clips the outlier tail for tighter scales

Calls that happen inside traced regions (e.g. the mamba dt projection under
its chunked scan) deliver tracers to the tap and are skipped — those
projections keep dynamic quantization (or stay float; see models/ssm.py).

Determinism: observers are pure numpy over a deterministic capture order, so
the same params + batches always produce bit-identical tables (tested).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import modes

EPS = 1e-8


# ---------------------------------------------------------------------------
# observers
# ---------------------------------------------------------------------------

class Observer:
    """Reduces a stream of |activation| matrices to quantization scales."""

    def observe(self, a: np.ndarray) -> None:  # a = |x| as (rows, K) f32
        raise NotImplementedError

    def end_batch(self) -> None:
        """Batch boundary hook (only the moving-average observer cares)."""

    def stat(self, per_channel: bool = False) -> np.ndarray:
        raise NotImplementedError

    def scale(self, per_channel: bool = False) -> np.ndarray:
        return np.maximum(self.stat(per_channel), EPS) / 127.0


class AbsmaxObserver(Observer):
    def __init__(self):
        self._ch: Optional[np.ndarray] = None

    def observe(self, a: np.ndarray) -> None:
        ch = a.max(axis=0)
        self._ch = ch if self._ch is None else np.maximum(self._ch, ch)

    def stat(self, per_channel: bool = False) -> np.ndarray:
        assert self._ch is not None, "observer saw no data"
        return self._ch if per_channel else self._ch.max()


class MovingAverageObserver(Observer):
    """EMA of the per-batch absmax.  Within a batch the pending statistic is
    a max (commutative — robust to capture-call ordering); the EMA applies
    once per `end_batch`, so the result is deterministic for a given batch
    sequence."""

    def __init__(self, momentum: float = 0.9):
        self.momentum = momentum
        self._ema: Optional[np.ndarray] = None
        self._pending: Optional[np.ndarray] = None

    def observe(self, a: np.ndarray) -> None:
        ch = a.max(axis=0)
        self._pending = ch if self._pending is None else np.maximum(self._pending, ch)

    def end_batch(self) -> None:
        if self._pending is None:
            return
        if self._ema is None:
            self._ema = self._pending
        else:
            m = self.momentum
            self._ema = m * self._ema + (1.0 - m) * self._pending
        self._pending = None

    def stat(self, per_channel: bool = False) -> np.ndarray:
        ema = self._ema if self._ema is not None else self._pending
        assert ema is not None, "observer saw no data"
        return ema if per_channel else ema.max()


class PercentileObserver(Observer):
    """Running max of the per-batch |x| percentile: clips the outlier tail.
    (Max-of-per-batch-percentiles approximates the pooled percentile without
    retaining every activation; exact for the 100th percentile.)"""

    def __init__(self, percentile: float = 99.9):
        self.percentile = percentile
        self._val: Optional[float] = None
        self._ch: Optional[np.ndarray] = None

    def observe(self, a: np.ndarray) -> None:
        v = float(np.percentile(a, self.percentile))
        ch = np.percentile(a, self.percentile, axis=0)
        self._val = v if self._val is None else max(self._val, v)
        self._ch = ch if self._ch is None else np.maximum(self._ch, ch)

    def stat(self, per_channel: bool = False) -> np.ndarray:
        assert self._val is not None, "observer saw no data"
        return self._ch if per_channel else np.float64(self._val)


OBSERVERS = {
    "absmax": AbsmaxObserver,
    "moving_average": MovingAverageObserver,
    "percentile": PercentileObserver,
}


def make_observer(name: str, **kwargs) -> Observer:
    if name not in OBSERVERS:
        raise ValueError(f"unknown observer {name!r}; known: {sorted(OBSERVERS)}")
    return OBSERVERS[name](**kwargs)


# ---------------------------------------------------------------------------
# the scale table
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScaleTable:
    """Per-site activation scales: `scales` (per-tensor, what the int8 GeMM
    consumes) and `channel_scales` (per-channel, for outlier diagnosis in
    quant/report.py).  Keys are dotted param paths, group-indexed for the
    scanned blocks: "blocks.0.sub1.mixer.wq", "head", ..."""

    scales: Dict[str, float]
    channel_scales: Dict[str, np.ndarray]
    observer: str
    batches: int

    def get(self, path: str, default=None):
        return self.scales.get(path, default)

    def __len__(self) -> int:
        return len(self.scales)


# ---------------------------------------------------------------------------
# calibration run
# ---------------------------------------------------------------------------

def _register(idmap: Dict[int, str], prefix: str, tree: Any) -> None:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        name = ".".join(str(getattr(k, "key", k)) for k in path)
        idmap[id(leaf)] = f"{prefix}.{name}" if name else prefix


def _tokens_of(batch) -> jnp.ndarray:
    if isinstance(batch, dict):
        batch = batch["tokens"]
    return jnp.asarray(np.asarray(batch, np.int32))


def calibrate(
    params,
    cfg,
    batches: Iterable,
    *,
    observer: str = "absmax",
    **observer_kwargs,
) -> ScaleTable:
    """Collect per-layer activation scales over `batches` (each a (B, S)
    token array or a dict with a "tokens" key).

    Runs the decoder forward eagerly group-by-group with the activation tap
    installed; supported for the decoder families (dense/moe/hybrid/ssm) —
    the same set the paged serving engine supports.
    """
    from repro.models import blocks, layers  # deferred: keeps import cheap

    if cfg.family in ("encdec", "vlm"):
        raise NotImplementedError(
            f"calibration not wired for family {cfg.family!r}")

    observers: Dict[str, Observer] = {}
    idmap: Dict[int, str] = {}

    def tap(x, w):
        if isinstance(x, jax.core.Tracer) or isinstance(w, jax.core.Tracer):
            return  # inside a traced region (scan/checkpoint body): skip
        path = idmap.get(id(w))
        if path is None:
            return  # unregistered weight (bias-less helper matmuls etc.)
        obs = observers.get(path)
        if obs is None:
            obs = observers[path] = make_observer(observer, **observer_kwargs)
        a = np.abs(np.asarray(x, np.float32)).reshape(-1, x.shape[-1])
        obs.observe(a)

    n_batches = 0
    with modes.precision("float"), modes.activation_capture(tap):
        for batch in batches:
            tokens = _tokens_of(batch)
            B, S = tokens.shape
            x = layers.embed(tokens, params["embed"])
            if cfg.tie_embeddings:
                x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
            positions = jnp.arange(S)
            for g in range(cfg.n_groups):
                gp = jax.tree_util.tree_map(lambda a: a[g], params["blocks"])
                idmap.clear()
                _register(idmap, f"blocks.{g}", gp)
                x, _ = blocks.apply_group(
                    x, gp, cfg, positions=positions, causal=True)
            x = blocks._norm(x, params["final_norm"], cfg)
            # Head site: feed the tap directly — the observer only reads the
            # *input* activations, so running the (B*S, d) x (d, vocab)
            # unembedding just to trigger the linear hook would materialize
            # (and discard) the full logits tensor per calibration batch.
            idmap.clear()
            head = params["embed"] if cfg.tie_embeddings else params["head"]
            idmap[id(head)] = "head"
            tap(x, head)
            n_batches += 1
            for obs in observers.values():
                obs.end_batch()

    return ScaleTable(
        scales={k: float(o.scale()) for k, o in sorted(observers.items())},
        channel_scales={
            k: np.asarray(o.scale(per_channel=True), np.float64)
            for k, o in sorted(observers.items())
        },
        observer=observer,
        batches=n_batches,
    )


def synthetic_batches(
    cfg, *, n: int = 2, batch: int = 2, seq: int = 32, seed: int = 0,
) -> List[np.ndarray]:
    """Deterministic synthetic token batches for calibration smoke paths
    (real deployments pass held-out data)."""
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=(batch, seq)).astype(np.int32)
            for _ in range(n)]
