"""Precision modes: the process-wide execution-precision switch.

The paper's accelerator is an int8 engine (P_A = P_B = 8, P_C = 32); this
module makes that deployment precision a first-class *mode* of the framework
instead of a per-call kwarg or a monkey-patched default:

  "float"             every `ops.linear` runs in the model dtype (default)
  "w8a8"              int8 weights x int8 activations, activations quantized
                      per-row on the fly (dynamic quantization)
  "w8a8-calibrated"   as w8a8, but activations use the static per-tensor
                      scales collected by `quant.calibrate` (attached to the
                      weights by `quant.params.quantize_params`)

`kernels/ops.py::linear` consults the active mode on every call it traces, so
`with precision("w8a8"): ...` flips the whole model — attention projections,
FFNs, the LM head — without touching model code.

IMPORTANT — trace-time semantics: like every python-level switch in jax, the
mode is read when a function is *traced*, not when its compiled executable
runs.  A jitted step compiled under "w8a8" stays w8a8 forever; re-entering
"float" later does not re-trace it.  The serving engine therefore traces its
decode/prefill steps inside the precision context during warmup (one engine,
one precision), and tests that flip modes must not reuse jit caches across
modes.

The activation-capture hook is the calibration tap: `quant.calibrate` installs
a callback that receives every (activation, weight) pair `linear` sees while
running eagerly, which is how observers collect per-layer statistics without
the model threading any state through its forward pass.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional

MODES = ("float", "w8a8", "w8a8-calibrated")

_state = threading.local()


def _get() -> str:
    return getattr(_state, "mode", "float")


def get_mode() -> str:
    """The active precision mode ("float" unless something set one)."""
    return _get()


def set_mode(mode: str) -> str:
    """Set the precision mode; returns the previous one (for restoring)."""
    if mode not in MODES:
        raise ValueError(f"unknown precision mode {mode!r}; known: {MODES}")
    prev = _get()
    _state.mode = mode
    return prev


@contextlib.contextmanager
def precision(mode: str):
    """Run a block under a precision mode, restoring the previous mode on
    exit (exception-safe, re-entrant)."""
    prev = set_mode(mode)
    try:
        yield
    finally:
        _state.mode = prev


def default_quant() -> Optional[str]:
    """The `quant=` default `ops.linear` should assume under the active mode
    (None in float mode; "int8" in the w8a8 modes).  Callers opt *out* of the
    mode by passing an explicit quant="none" (e.g. numerically sensitive
    SSM gate/dt projections)."""
    return "int8" if _get() != "float" else None


def is_calibrated() -> bool:
    """True when static (calibrated) activation scales should be preferred
    over dynamic per-row quantization."""
    return _get() == "w8a8-calibrated"


# ---------------------------------------------------------------------------
# calibration tap
# ---------------------------------------------------------------------------

_capture_fn: Optional[Callable] = None


def capturing() -> bool:
    return _capture_fn is not None


def capture(x, w) -> None:
    """Feed one (activation, weight) pair to the installed observer hook."""
    if _capture_fn is not None:
        _capture_fn(x, w)


@contextlib.contextmanager
def activation_capture(fn: Callable):
    """Install `fn(x, w)` as the linear-call tap for the duration of the
    block.  Not re-entrant by design: nested calibrations would silently
    cross-contaminate observers."""
    global _capture_fn
    if _capture_fn is not None:
        raise RuntimeError("activation capture already active")
    _capture_fn = fn
    try:
        yield
    finally:
        _capture_fn = None
