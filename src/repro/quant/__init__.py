"""repro.quant: end-to-end int8 (w8a8) quantization — the paper's deployment
precision as a first-class execution mode.

  modes      precision-mode switch ("float" / "w8a8" / "w8a8-calibrated")
             consumed by kernels/ops.py::linear at trace time
  params     QuantTensor + quantize_params: int8-resident weights with
             per-column scales, attached once at load
  calibrate  activation observers (absmax / moving-average / percentile)
             over calibration batches -> static activation-scale table
  report     per-layer quantization error + end-to-end quality delta

Typical deployment (the serving engine does exactly this under
``Engine(cfg, precision="w8a8")`` — see serving/engine.py):

    from repro import quant
    table = quant.collect_scales(params, cfg, batches)     # optional
    qparams = quant.quantize_params(params, cfg=cfg, scales=table)
    with quant.precision("w8a8-calibrated"):
        logits = forward(qparams, cfg, batch)              # int8 GeMMs

`modes` and `params` load eagerly (they are what kernels/ops.py probes via
sys.modules); `calibrate`/`report` pull in the model layer and stay lazy.
"""

from repro.quant import modes
from repro.quant.modes import (
    MODES,
    get_mode,
    precision,
    set_mode,
)
from repro.quant.params import (
    QUANT_KEYS,
    QuantTensor,
    dequantize_params,
    quantize_leaf,
    quantize_params,
    quantized_leaf_count,
    weight_bytes,
)

# Eager re-exports plus the lazy table below; pyflakes reads re-exports off
# __all__ (bare pyflakes has no noqa support).
__all__ = [
    "modes",
    "MODES",
    "get_mode",
    "precision",
    "set_mode",
    "QUANT_KEYS",
    "QuantTensor",
    "dequantize_params",
    "quantize_leaf",
    "quantize_params",
    "quantized_leaf_count",
    "weight_bytes",
]

# NB: "calibrate"/"report" resolve to the submodules (import machinery would
# overwrite a same-named function attribute on first import anyway); the
# calibration *function* is exported as `collect_scales`.
_LAZY = {
    "calibrate": ("repro.quant.calibrate", None),
    "collect_scales": ("repro.quant.calibrate", "calibrate"),
    "synthetic_batches": ("repro.quant.calibrate", "synthetic_batches"),
    "ScaleTable": ("repro.quant.calibrate", "ScaleTable"),
    "make_observer": ("repro.quant.calibrate", "make_observer"),
    "layer_error_rows": ("repro.quant.report", "layer_error_rows"),
    "format_error_table": ("repro.quant.report", "format_error_table"),
    "quality_delta": ("repro.quant.report", "quality_delta"),
    "eval_nll": ("repro.quant.report", "eval_nll"),
    "report": ("repro.quant.report", None),
}

__all__ += sorted(_LAZY)


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        module, attr = _LAZY[name]
        mod = importlib.import_module(module)
        return mod if attr is None else getattr(mod, attr)
    raise AttributeError(f"module 'repro.quant' has no attribute {name!r}")
