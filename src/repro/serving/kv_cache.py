"""Paged KV cache: fixed-size blocks + per-request block tables.

The serving analogue of the paper's programmable strided memory access
(SMA): instead of one dense (slots, S_max, H, D) buffer that pins worst-case
memory per slot, K/V live in a shared pool of `num_blocks` blocks of
`block_size` tokens each, and every request addresses its tokens through a
block table — a programmable stride pattern over the pool.  Slot memory is
decoupled from `max_seq`: idle slots hold zero blocks, and a slot refilled
with a new request reuses freed blocks without re-initializing the pool.

Layout (per attention layer):

  k_pool / v_pool : (num_blocks, block_size, H_kv, D)
  block_tables    : (slots, max_blocks_per_slot) int32, entries index blocks

Block id 0 is a reserved *null* block: unallocated table entries point at
it, and writes from idle slots or masked positions land there.  It is never
handed out by the allocator, so garbage in it is never attended (the causal
length mask excludes every position a table does not really cover).

The device side is pure array math (`write_kv` / `gather_kv`), jit-safe and
scanned over layer groups; the host side (`BlockAllocator`, `BlockTables`)
makes allocation decisions between steps, exactly like the paper's RISC-V
core programs the streamer strides between GeMM calls.
"""

from __future__ import annotations

import math
from typing import List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NULL_BLOCK = 0


class PagedKVCache(NamedTuple):
    """Block-pooled decode cache for one attention layer (or a stacked group).

    Mirrors ``attention.KVCache``'s (k, v) fields so the two cache kinds are
    interchangeable pytree leaves; ``isinstance`` distinguishes them where
    the addressing differs.
    """

    k: jax.Array  # (num_blocks, block_size, H_kv, D)
    v: jax.Array  # (num_blocks, block_size, H_kv, D)

    @property
    def num_blocks(self) -> int:
        return self.k.shape[-4]

    @property
    def block_size(self) -> int:
        return self.k.shape[-3]


def init_paged_kv(
    num_blocks: int, block_size: int, n_kv_heads: int, head_dim: int, dtype
) -> PagedKVCache:
    shape = (num_blocks, block_size, n_kv_heads, head_dim)
    return PagedKVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def _flat_positions(block_tables: jax.Array, start, S: int, block_size: int
                    ) -> jax.Array:
    """Pool-flat write/read indices for S tokens starting at `start` per slot.

    block_tables: (B, max_blocks); start: scalar or (B,).  Returns (B, S)
    indices into the (num_blocks * block_size)-flattened pool.  Positions
    beyond a slot's table capacity resolve to the null block — without the
    explicit mask, take_along_axis would clamp to the table's *last* entry
    and silently overwrite a live block.
    """
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 0:
        start = jnp.broadcast_to(start, (block_tables.shape[0],))
    pos = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]   # (B, S)
    pos = jnp.maximum(pos, 0)
    table_cap = block_tables.shape[1] * block_size
    blk = jnp.take_along_axis(
        block_tables, jnp.minimum(pos, table_cap - 1) // block_size, axis=1)
    blk = jnp.where(pos < table_cap, blk, NULL_BLOCK)
    return blk * block_size + pos % block_size


def write_kv(
    cache: PagedKVCache,
    block_tables: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    start,
) -> PagedKVCache:
    """Scatter S new tokens per slot into the pool at positions start..start+S-1.

    k_new/v_new: (B, S, H, D).  Distinct live slots own distinct blocks, so
    real writes never collide; idle-slot writes collapse onto the null block.
    """
    nb, bs, H, D = cache.k.shape
    B, S = k_new.shape[:2]
    flat = _flat_positions(block_tables, start, S, bs).reshape(-1)
    k_pool = cache.k.reshape(nb * bs, H, D).at[flat].set(
        k_new.astype(cache.k.dtype).reshape(-1, H, D), mode="drop")
    v_pool = cache.v.reshape(nb * bs, H, D).at[flat].set(
        v_new.astype(cache.v.dtype).reshape(-1, H, D), mode="drop")
    return PagedKVCache(k=k_pool.reshape(nb, bs, H, D),
                        v=v_pool.reshape(nb, bs, H, D))


def gather_kv(
    cache: PagedKVCache, block_tables: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Per-slot contiguous K/V views (B, max_blocks * block_size, H, D).

    A gather through the block table — the strided-access read pattern.
    Entries past a slot's true length read the null block; callers mask by
    position, so that garbage is never attended.
    """
    nb, bs, H, D = cache.k.shape
    B, max_blocks = block_tables.shape
    flat = (block_tables[:, :, None] * bs
            + jnp.arange(bs, dtype=jnp.int32)[None, None, :]).reshape(B, -1)
    k = jnp.take(cache.k.reshape(nb * bs, H, D), flat, axis=0)
    v = jnp.take(cache.v.reshape(nb * bs, H, D), flat, axis=0)
    return k, v


# ---------------------------------------------------------------------------
# Host side: allocation decisions between steps
# ---------------------------------------------------------------------------


def blocks_for(tokens: int, block_size: int) -> int:
    return -(-tokens // block_size) if tokens > 0 else 0


class BlockAllocator:
    """Free-list allocator over pool blocks 1..num_blocks-1 (0 is the null
    block) with admission-time reservations.

    A request reserves its worst-case block count (ceil((prompt + max_new) /
    block_size)) when admitted, then draws blocks lazily as its length
    crosses block boundaries — so admission control guarantees a request
    never starves mid-decode, while resident usage tracks actual length.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least 2 blocks (block 0 is reserved)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))  # pop() -> 1 first
        self._reserved = 0

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def available(self) -> int:
        """Blocks neither allocated nor promised to an admitted request."""
        return len(self._free) - self._reserved

    @property
    def in_use(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def occupancy(self) -> float:
        return self.in_use / max(1, self.num_blocks - 1)

    def can_reserve(self, n: int) -> bool:
        return n <= self.available

    def reserve(self, n: int) -> bool:
        if not self.can_reserve(n):
            return False
        self._reserved += n
        return True

    def alloc(self, n: int, *, reserved: bool = True) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: want {n}, free {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        if reserved:
            self._reserved = max(0, self._reserved - n)
        return out

    def free(self, ids: List[int], *, unreserve: int = 0) -> None:
        for b in ids:
            if b == NULL_BLOCK:
                raise ValueError("cannot free the null block")
            self._free.append(b)
        self._reserved = max(0, self._reserved - unreserve)


class BlockTables:
    """Host mirror of the device block tables: (slots, max_blocks) int32.

    Tracks per-slot allocated block lists and materializes the device array
    on demand.  The engine pushes `.array()` into the decode state whenever
    a table row changed (admission, growth, release).
    """

    def __init__(self, slots: int, max_blocks: int):
        self.slots = slots
        self.max_blocks = max_blocks
        self.table = np.zeros((slots, max_blocks), np.int32)
        self.blocks: List[List[int]] = [[] for _ in range(slots)]
        self.dirty = True

    def covered_tokens(self, slot: int, block_size: int) -> int:
        return len(self.blocks[slot]) * block_size

    def ensure(self, slot: int, length: int, alloc: BlockAllocator) -> bool:
        """Grow slot's table to cover `length` tokens; returns True if changed."""
        need = blocks_for(length, alloc.block_size) - len(self.blocks[slot])
        if need <= 0:
            return False
        if len(self.blocks[slot]) + need > self.max_blocks:
            raise RuntimeError(
                f"slot {slot}: {length} tokens exceed max_blocks {self.max_blocks}")
        for b in alloc.alloc(need):
            self.table[slot, len(self.blocks[slot])] = b
            self.blocks[slot].append(b)
        self.dirty = True
        return True

    def release(self, slot: int, alloc: BlockAllocator, *, unreserve: int = 0) -> int:
        """Free all of slot's blocks back to the pool; returns count freed."""
        ids = self.blocks[slot]
        n = len(ids)
        alloc.free(ids, unreserve=unreserve)
        self.blocks[slot] = []
        self.table[slot, :] = NULL_BLOCK
        self.dirty = True
        return n

    def array(self) -> jax.Array:
        self.dirty = False
        return jnp.asarray(self.table)


def default_pool_blocks(
    slots: int, max_seq: int, block_size: int, *, headroom: float = 1.0
) -> int:
    """Pool sizing: null block + headroom * worst-case concurrent demand."""
    per_slot = blocks_for(max_seq, block_size)
    return 1 + max(1, math.ceil(headroom * slots * per_slot))
