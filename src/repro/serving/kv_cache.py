"""Paged KV cache: fixed-size blocks + per-request block tables.

The serving analogue of the paper's programmable strided memory access
(SMA): instead of one dense (slots, S_max, H, D) buffer that pins worst-case
memory per slot, K/V live in a shared pool of `num_blocks` blocks of
`block_size` tokens each, and every request addresses its tokens through a
block table — a programmable stride pattern over the pool.  Slot memory is
decoupled from `max_seq`: idle slots hold zero blocks, and a slot refilled
with a new request reuses freed blocks without re-initializing the pool.

Layout (per attention layer):

  k_pool / v_pool : (num_blocks, block_size, H_kv, D)
  block_tables    : (slots, max_blocks_per_slot) int32, entries index blocks

Block id 0 is a reserved *null* block: unallocated table entries point at
it, and writes from idle slots or masked positions land there.  It is never
handed out by the allocator, so garbage in it is never attended (the causal
length mask excludes every position a table does not really cover).

The device side is pure array math (`write_kv` / `gather_kv`), jit-safe and
scanned over layer groups; the host side (`BlockAllocator`, `BlockTables`)
makes allocation decisions between steps, exactly like the paper's RISC-V
core programs the streamer strides between GeMM calls.
"""

from __future__ import annotations

import math
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NULL_BLOCK = 0


class PagedKVCache(NamedTuple):
    """Block-pooled decode cache for one attention layer (or a stacked group).

    Mirrors ``attention.KVCache``'s (k, v) fields so the two cache kinds are
    interchangeable pytree leaves; ``isinstance`` distinguishes them where
    the addressing differs.

    int8 residency (``Engine(kv_precision="int8")``): the pools hold int8
    codes and ``k_scale``/``v_scale`` hold per-(block, position, kv-head)
    f32 dequant scales — per *position* rather than per block because decode
    appends one token at a time, and a shared per-block scale could not
    absorb a new outlier token without requantizing the block's committed
    bytes.  At D=64 the scale overhead is 4/256 of the f32 pool, so the
    pool shrinks ~3.8x (~4x more blocks per byte).  Float pools leave the
    scale fields None — both layouts are valid pytrees of one NamedTuple.
    """

    k: jax.Array  # (num_blocks, block_size, H_kv, D)
    v: jax.Array  # (num_blocks, block_size, H_kv, D)
    k_scale: Optional[jax.Array] = None  # (num_blocks, block_size, H_kv) f32
    v_scale: Optional[jax.Array] = None

    @property
    def num_blocks(self) -> int:
        return self.k.shape[-4]

    @property
    def block_size(self) -> int:
        return self.k.shape[-3]

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


def init_paged_kv(
    num_blocks: int, block_size: int, n_kv_heads: int, head_dim: int, dtype,
    *, kv_precision: str = "float",
) -> PagedKVCache:
    shape = (num_blocks, block_size, n_kv_heads, head_dim)
    if kv_precision == "int8":
        sshape = shape[:-1]
        return PagedKVCache(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.ones(sshape, jnp.float32),
            v_scale=jnp.ones(sshape, jnp.float32))
    if kv_precision != "float":
        raise ValueError(
            f"unknown kv_precision {kv_precision!r}; known: float, int8")
    return PagedKVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def quantize_kv_tokens(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 per (token, kv-head): (B, S, H, D) float ->
    ((B, S, H, D) int8 codes, (B, S, H) f32 scales).  Zero rows quantize to
    zero codes at scale 1 (no special-casing on dequant)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _flat_positions(block_tables: jax.Array, start, S: int, block_size: int
                    ) -> jax.Array:
    """Pool-flat write/read indices for S tokens starting at `start` per slot.

    block_tables: (B, max_blocks); start: scalar or (B,).  Returns (B, S)
    indices into the (num_blocks * block_size)-flattened pool.  Positions
    beyond a slot's table capacity resolve to the null block — without the
    explicit mask, take_along_axis would clamp to the table's *last* entry
    and silently overwrite a live block.
    """
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 0:
        start = jnp.broadcast_to(start, (block_tables.shape[0],))
    pos = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]   # (B, S)
    pos = jnp.maximum(pos, 0)
    table_cap = block_tables.shape[1] * block_size
    blk = jnp.take_along_axis(
        block_tables, jnp.minimum(pos, table_cap - 1) // block_size, axis=1)
    blk = jnp.where(pos < table_cap, blk, NULL_BLOCK)
    return blk * block_size + pos % block_size


def write_kv(
    cache: PagedKVCache,
    block_tables: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    start,
) -> PagedKVCache:
    """Scatter S new tokens per slot into the pool at positions start..start+S-1.

    k_new/v_new: (B, S, H, D).  Distinct live slots own distinct blocks, so
    real writes never collide; idle-slot writes collapse onto the null block.
    """
    nb, bs, H, D = cache.k.shape
    B, S = k_new.shape[:2]
    flat = _flat_positions(block_tables, start, S, bs).reshape(-1)
    k_scale, v_scale = cache.k_scale, cache.v_scale
    if cache.quantized:
        # Quantize on write (per token x kv-head); the pool never sees floats.
        k_new, ks = quantize_kv_tokens(k_new)
        v_new, vs = quantize_kv_tokens(v_new)
        k_scale = k_scale.reshape(nb * bs, H).at[flat].set(
            ks.reshape(-1, H), mode="drop").reshape(nb, bs, H)
        v_scale = v_scale.reshape(nb * bs, H).at[flat].set(
            vs.reshape(-1, H), mode="drop").reshape(nb, bs, H)
    k_pool = cache.k.reshape(nb * bs, H, D).at[flat].set(
        k_new.astype(cache.k.dtype).reshape(-1, H, D), mode="drop")
    v_pool = cache.v.reshape(nb * bs, H, D).at[flat].set(
        v_new.astype(cache.v.dtype).reshape(-1, H, D), mode="drop")
    return PagedKVCache(k=k_pool.reshape(nb, bs, H, D),
                        v=v_pool.reshape(nb, bs, H, D),
                        k_scale=k_scale, v_scale=v_scale)


def copy_blocks(
    cache: PagedKVCache, src: jax.Array, dst: jax.Array
) -> PagedKVCache:
    """Device-side block copy: pool[dst[i]] = pool[src[i]] for K and V.

    The write half of copy-on-write divergence: when a request must mutate a
    block whose refcount is > 1 (see ``BlockTables.make_writable``), the host
    allocates a fresh destination block and this op clones the shared
    contents into it before any write lands.  `src`/`dst` are (n,) int32;
    jit-safe for a fixed n (the engine batches one divergence wave per step).
    """
    k = cache.k.at[dst].set(cache.k[src])
    v = cache.v.at[dst].set(cache.v[src])
    k_scale, v_scale = cache.k_scale, cache.v_scale
    if cache.quantized:
        k_scale = k_scale.at[dst].set(k_scale[src])
        v_scale = v_scale.at[dst].set(v_scale[src])
    return PagedKVCache(k=k, v=v, k_scale=k_scale, v_scale=v_scale)


def gather_kv(
    cache: PagedKVCache, block_tables: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Per-slot contiguous K/V views (B, max_blocks * block_size, H, D).

    A gather through the block table — the strided-access read pattern.
    Entries past a slot's true length read the null block; callers mask by
    position, so that garbage is never attended.  int8 pools are dequantized
    here (f32 out) — this path materializes the view anyway, so there is no
    byte saving to preserve; the flash-decode kernel dequantizes in-register
    instead (kernels/flash_decode.py).
    """
    nb, bs, H, D = cache.k.shape
    B, max_blocks = block_tables.shape
    flat = (block_tables[:, :, None] * bs
            + jnp.arange(bs, dtype=jnp.int32)[None, None, :]).reshape(B, -1)
    k = jnp.take(cache.k.reshape(nb * bs, H, D), flat, axis=0)
    v = jnp.take(cache.v.reshape(nb * bs, H, D), flat, axis=0)
    if cache.quantized:
        ks = jnp.take(cache.k_scale.reshape(nb * bs, H), flat, axis=0)
        vs = jnp.take(cache.v_scale.reshape(nb * bs, H), flat, axis=0)
        k = k.astype(jnp.float32) * ks[..., None]
        v = v.astype(jnp.float32) * vs[..., None]
    return k, v


def pool_bytes(cache: PagedKVCache) -> int:
    """Resident bytes of this pool (codes + scales) — the capacity metric
    ``EngineMetrics.summary()`` reports per engine."""
    total = cache.k.size * cache.k.dtype.itemsize \
        + cache.v.size * cache.v.dtype.itemsize
    if cache.quantized:
        total += cache.k_scale.size * cache.k_scale.dtype.itemsize
        total += cache.v_scale.size * cache.v_scale.dtype.itemsize
    return total


def swap_out_blocks(caches, ids) -> List[dict]:
    """Serialize pool blocks ``ids`` to host memory, one dict of numpy
    arrays per cache kind — the device->host half of KV-swap preemption.

    The engine stacks per-layer pools with a leading group axis, so the
    block axis is located from the right: ``k``/``v`` are
    (..., num_blocks, block_size, H_kv, D) and scales (when int8-resident)
    are (..., num_blocks, block_size, H_kv).  Payload arrays keep the pool
    dtype (int8 codes swap as int8 — half the host traffic), and restoring
    into a *different* set of block ids later is fine: block contents are
    position-independent, only the table rows carry ordering.
    """
    ids = np.asarray(ids, np.int32)
    out: List[dict] = []
    for c in caches:
        if not isinstance(c, PagedKVCache):
            raise TypeError(
                "swap_out_blocks requires paged (attention) cache kinds; "
                "recurrent state is not block-addressable — gate preemption "
                "to attention-only stacks")
        entry = {"k": np.asarray(jnp.take(c.k, ids, axis=c.k.ndim - 4)),
                 "v": np.asarray(jnp.take(c.v, ids, axis=c.v.ndim - 4))}
        if c.quantized:
            sax = c.k_scale.ndim - 3
            entry["k_scale"] = np.asarray(jnp.take(c.k_scale, ids, axis=sax))
            entry["v_scale"] = np.asarray(jnp.take(c.v_scale, ids, axis=sax))
        out.append(entry)
    return out


def swap_in_blocks(caches, ids, saved: List[dict]):
    """Restore a ``swap_out_blocks`` payload into pool blocks ``ids``
    (freshly allocated — not necessarily the ids swapped out) and return
    the new cache tuple.  Runs un-jitted between ticks: scatter dispatch
    cost is the preemption price, measured by benchmarks/sched_bench.py."""
    ids = np.asarray(ids, np.int32)
    out = []
    for c, entry in zip(caches, saved):
        def put(arr, vals, axis):
            idx = (slice(None),) * axis + (ids,)
            return arr.at[idx].set(jnp.asarray(vals))

        k = put(c.k, entry["k"], c.k.ndim - 4)
        v = put(c.v, entry["v"], c.v.ndim - 4)
        ks, vs = c.k_scale, c.v_scale
        if c.quantized:
            sax = c.k_scale.ndim - 3
            ks = put(ks, entry["k_scale"], sax)
            vs = put(vs, entry["v_scale"], sax)
        out.append(PagedKVCache(k=k, v=v, k_scale=ks, v_scale=vs))
    return tuple(out)


# ---------------------------------------------------------------------------
# Host side: allocation decisions between steps
# ---------------------------------------------------------------------------


def blocks_for(tokens: int, block_size: int) -> int:
    return -(-tokens // block_size) if tokens > 0 else 0


class BlockAllocator:
    """Free-list allocator over pool blocks 1..num_blocks-1 (0 is the null
    block) with admission-time reservations and per-block refcounts.

    A request reserves its worst-case block count (ceil((prompt + max_new) /
    block_size)) when admitted, then draws blocks lazily as its length
    crosses block boundaries — so admission control guarantees a request
    never starves mid-decode, while resident usage tracks actual length.

    Refcounts make blocks *shareable*: ``ref()`` (or the ``fork_blocks``
    helper) lets a second owner — another request reusing a prefilled prompt
    prefix, or the prefix cache itself — hold the same physical block, and
    ``free()`` only returns a block to the free list when its last owner
    lets go.  Shared (refcount > 1) blocks are read-only by convention: the
    engine aligns prefix sharing to block boundaries so KV writes only ever
    land in refcount-1 blocks, and ``BlockTables.make_writable`` +
    ``copy_blocks`` provide explicit copy-on-write divergence for any caller
    that must write into a shared region.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least 2 blocks (block 0 is reserved)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))  # pop() -> 1 first
        self._refs: Dict[int, int] = {}
        self._reserved = 0
        # Lifetime traffic counters (repro.obs): cumulative draws/returns and
        # the high-water mark — cheap int adds, always on.
        self.total_allocated = 0
        self.total_freed = 0
        self.peak_in_use = 0

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def reserved(self) -> int:
        """Blocks promised to admitted requests but not yet drawn."""
        return self._reserved

    @property
    def available(self) -> int:
        """Blocks neither allocated nor promised to an admitted request."""
        return len(self._free) - self._reserved

    @property
    def in_use(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def occupancy(self) -> float:
        return self.in_use / max(1, self.num_blocks - 1)

    def can_reserve(self, n: int) -> bool:
        return n <= self.available

    def reserve(self, n: int) -> bool:
        if not self.can_reserve(n):
            return False
        self._reserved += n
        return True

    def alloc(self, n: int, *, reserved: bool = True) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: want {n}, free {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._refs[b] = 1
        if reserved:
            self._reserved = max(0, self._reserved - n)
        self.total_allocated += n
        if self.in_use > self.peak_in_use:
            self.peak_in_use = self.in_use
        return out

    def ref(self, ids: List[int]) -> None:
        """Add one owner to each (already-allocated) block."""
        for b in ids:
            if b not in self._refs:
                raise ValueError(f"block {b} is not allocated; cannot share it")
            self._refs[b] += 1

    def refcount(self, b: int) -> int:
        return self._refs.get(b, 0)

    def free(self, ids: List[int], *, unreserve: int = 0,
             rereserve: bool = False) -> int:
        """Drop one owner per block; a block returns to the free list only
        when its last owner frees it (shared blocks just lose a ref).

        ``rereserve`` puts every block that actually reached the free list
        back under the caller's admission reservation — the KV-rewind case:
        a request returning blocks drawn for rejected speculative positions
        must still be able to redraw them later without re-admission, or the
        allocator's no-mid-decode-starvation guarantee breaks.  Shared
        blocks (refcount > 1) only lose a ref and are NOT re-reserved — the
        free list did not grow, so a reservation against it would be a lie.
        Returns the number of blocks that reached the free list."""
        returned = 0
        for b in ids:
            if b == NULL_BLOCK:
                raise ValueError("cannot free the null block")
            rc = self._refs.get(b, 0)
            if rc <= 0:
                raise ValueError(f"double free of block {b}")
            if rc == 1:
                del self._refs[b]
                self._free.append(b)
                returned += 1
            else:
                self._refs[b] = rc - 1
        self._reserved = max(0, self._reserved - unreserve)
        if rereserve:
            self._reserved += returned
        self.total_freed += returned
        return returned

    def check(self) -> None:
        """Allocator invariant: free list and refcounted blocks partition
        the pool exactly, every refcount is positive, and the null block is
        owned by neither.  Raises AssertionError on any leak/double-free."""
        free = set(self._free)
        live = set(self._refs)
        assert len(free) == len(self._free), "duplicate ids on the free list"
        assert not (free & live), f"blocks both free and live: {free & live}"
        assert NULL_BLOCK not in free and NULL_BLOCK not in live, \
            "the null block escaped into the allocator"
        every = set(range(1, self.num_blocks))
        assert free | live == every, \
            f"leaked blocks: {sorted(every - free - live)}"
        assert all(rc > 0 for rc in self._refs.values()), "non-positive refcount"
        assert 0 <= self._reserved <= len(self._free), \
            f"reservations ({self._reserved}) exceed the free list ({len(self._free)})"

    def stats(self) -> dict:
        """Counter snapshot for metrics export / trace annotation."""
        return {
            "in_use": self.in_use,
            "reserved": self._reserved,
            "free": len(self._free),
            "total_allocated": self.total_allocated,
            "total_freed": self.total_freed,
            "peak_in_use": self.peak_in_use,
        }


def fork_blocks(alloc: BlockAllocator, ids: List[int]) -> List[int]:
    """Copy-on-write fork: share `ids` with a new owner (refcount + 1 each)
    and return the same physical ids.  No KV bytes move — both owners read
    the same pool blocks; a write requires divergence first (see
    ``BlockTables.make_writable`` / ``copy_blocks``).  The engine only forks
    *full* blocks at block-aligned prefix boundaries, so its writes — which
    always start at the first un-shared position — never touch a forked
    block and the copy half of CoW stays off the hot path."""
    alloc.ref(ids)
    return list(ids)


class BlockTables:
    """Host mirror of the device block tables: (slots, max_blocks) int32.

    Tracks per-slot allocated block lists and materializes the device array
    on demand.  The engine pushes `.array()` into the decode state whenever
    a table row changed (admission, growth, release).
    """

    def __init__(self, slots: int, max_blocks: int):
        self.slots = slots
        self.max_blocks = max_blocks
        self.table = np.zeros((slots, max_blocks), np.int32)
        self.blocks: List[List[int]] = [[] for _ in range(slots)]
        self.dirty = True

    def covered_tokens(self, slot: int, block_size: int) -> int:
        return len(self.blocks[slot]) * block_size

    def ensure(self, slot: int, length: int, alloc: BlockAllocator) -> bool:
        """Grow slot's table to cover `length` tokens; returns True if changed."""
        need = blocks_for(length, alloc.block_size) - len(self.blocks[slot])
        if need <= 0:
            return False
        if len(self.blocks[slot]) + need > self.max_blocks:
            raise RuntimeError(
                f"slot {slot}: {length} tokens exceed max_blocks {self.max_blocks}")
        for b in alloc.alloc(need):
            self.table[slot, len(self.blocks[slot])] = b
            self.blocks[slot].append(b)
        self.dirty = True
        return True

    def seed(self, slot: int, ids: List[int]) -> None:
        """Install already-owned blocks (a forked prefix) at the head of an
        *empty* slot row.  The caller has taken its refs (fork_blocks);
        release() later drops them like any other row entry."""
        if self.blocks[slot]:
            raise RuntimeError(
                f"slot {slot} is not empty; seed only a fresh slot")
        if len(ids) > self.max_blocks:
            raise RuntimeError(
                f"seed of {len(ids)} blocks exceeds max_blocks {self.max_blocks}")
        for i, b in enumerate(ids):
            self.table[slot, i] = b
        self.blocks[slot] = list(ids)
        self.dirty = True

    def make_writable(
        self, slot: int, block_idx: int, alloc: BlockAllocator
    ) -> Optional[Tuple[int, int]]:
        """Copy-on-write divergence for one table entry: if the block at
        `block_idx` is shared (refcount > 1), allocate a private replacement,
        swap it into the row, drop this slot's ref on the original, and
        return ``(src, dst)`` for the caller to clone on device via
        ``copy_blocks``.  Returns None when the block is already exclusive.
        """
        b = self.blocks[slot][block_idx]
        if alloc.refcount(b) <= 1:
            return None
        [fresh] = alloc.alloc(1, reserved=False)
        alloc.free([b])                      # drop this slot's share
        self.blocks[slot][block_idx] = fresh
        self.table[slot, block_idx] = fresh
        self.dirty = True
        return b, fresh

    def rewind(
        self, slot: int, length: int, alloc: BlockAllocator, *,
        rereserve: bool = True,
    ) -> Tuple[int, Optional[Tuple[int, int]]]:
        """KV rewind: shrink slot's table to cover exactly `length` tokens,
        returning blocks past the boundary to the pool.

        The rollback half of speculative decoding: blocks drawn to hold
        drafted-token KV are handed back when the draft is (partially)
        rejected, and — with ``rereserve`` (default) — return to the
        request's admission reservation so later growth cannot starve.
        Freed blocks are not zeroed: the causal length mask never exposes a
        position the table does not cover, and every block is fully
        re-written by its next owner before its positions become visible
        (the same invariant slot release relies on).

        Composes with CoW sharing: when the new tail block is *partial*
        (future writes will land inside it) and shared (refcount > 1 — e.g.
        a forked prefix block), it is diverged via ``make_writable`` so the
        rewound slot never mutates bytes another owner is reading —
        copy-then-rewind, never rewind-in-place.  Returns
        ``(blocks_freed, copy_pair)`` where ``copy_pair`` is the (src, dst)
        to clone on device via ``copy_blocks`` (None when no divergence was
        needed).  A block-aligned `length` needs no divergence: the next
        write starts a fresh block.
        """
        keep = blocks_for(length, alloc.block_size)
        ids = self.blocks[slot]
        if keep > len(ids):
            raise ValueError(
                f"slot {slot}: cannot rewind to {length} tokens "
                f"({keep} blocks) — only {len(ids)} blocks held")
        dropped = ids[keep:]
        if dropped:
            alloc.free(dropped, rereserve=rereserve)
            del ids[keep:]
            self.table[slot, keep:] = NULL_BLOCK
            self.dirty = True
        pair = None
        if keep and length % alloc.block_size:
            pair = self.make_writable(slot, keep - 1, alloc)
        return len(dropped), pair

    def release(self, slot: int, alloc: BlockAllocator, *, unreserve: int = 0) -> int:
        """Free all of slot's blocks back to the pool; returns count freed."""
        ids = self.blocks[slot]
        n = len(ids)
        alloc.free(ids, unreserve=unreserve)
        self.blocks[slot] = []
        self.table[slot, :] = NULL_BLOCK
        self.dirty = True
        return n

    def array(self) -> jax.Array:
        self.dirty = False
        return jnp.asarray(self.table)


def default_pool_blocks(
    slots: int, max_seq: int, block_size: int, *, headroom: float = 1.0
) -> int:
    """Pool sizing: null block + headroom * worst-case concurrent demand."""
    per_slot = blocks_for(max_seq, block_size)
    return 1 + max(1, math.ceil(headroom * slots * per_slot))
