"""Serving engine facade: warmup, request lifecycle, metrics.

Maps the paper's three utilization mechanisms onto the request path:

  * `warmup()` — **configuration pre-loading**: the GeMM tile autotuner and
    the XLA compiler both run before traffic.  Every step the server can
    ever execute (the decode step, each power-of-two prefill-chunk bucket,
    the slot reset) is traced and compiled into the jit cache during
    warmup, so no request ever pays a compile.  Pre-loading covers
    *precision* too: ``Engine(cfg, precision="w8a8")`` calibrates (for the
    calibrated mode), quantizes the weights int8-resident, and compiles
    int8 decode/prefill steps — the paper's int8 deployment datapath, set
    up entirely before traffic (repro.quant).
  * chunked prefill interleaved with decode — **input pre-fetching with
    output buffering**: C prompt tokens stream through one step while
    decode batches drain between chunks; prefill work is proportional to
    real tokens (no padding positions, see serving/prefill.py).
  * the paged KV cache — **programmable strided memory access**: block
    tables address a shared pool, so slot memory tracks actual lengths and
    finished slots hand their blocks to the next request.

Typical use (launch/serve.py is a thin CLI over exactly this):

    eng = Engine(cfg, slots=4, max_seq=256, autotune=True)
    eng.warmup()
    for p in prompts:
        eng.submit(RequestSpec(prompt=p, max_new=16))
    results = eng.run()
    print(eng.metrics.summary())

(`submit(p, max_new=16)` still works through the deprecated legacy shim —
serving/request.py owns the one warning path.)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import GemmShape
from repro.models import model as M
from repro.obs import Histogram, MfuMeter, NULL_TRACER, Tracer
from repro.obs import percentile as _obs_percentile
from repro.serving import kv_cache as kvc
from repro.serving.prefill import chunk_buckets
from repro.serving.request import RequestSpec, as_spec, priority_rank
from repro.serving.scheduler import Phase, Request, Scheduler
from repro.serving.speculative import (
    NgramDrafter,
    bucket_for,
    coerce_spec,
    verify_buckets,
)


# ---------------------------------------------------------------------------
# warmup shape extraction (tile autotuning, the CPL analogue's first half)
# ---------------------------------------------------------------------------

def serving_gemm_shapes(cfg, *, slots: int, chunks: Optional[List[int]] = None
                        ) -> List[GemmShape]:
    """The per-step *dense-projection* GeMMs of the serving path: the shapes
    to pre-tune.

    A decode step runs, per attention layer, the separate q/k/v and output
    projections (models/attention.py: wq (d, hq*hd), wk/wv (d, hkv*hd),
    wo (hq*hd, d)) and — for dense-FFN archs — the two FFN matmuls over
    `slots` token rows, plus the vocab head.  Chunked prefill runs the same
    projections over `C` rows per bucket size C (batch 1), so those M-dims
    are warmed too.  MoE expert matmuls (einsum over stacked expert weights)
    and SSM scans do not route through spec-dispatched ops.gemm, so they are
    not warmed here.
    """
    d, ff, vocab = cfg.d_model, cfg.d_ff, cfg.vocab
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    rows = [slots] + list(chunks or [])
    shapes = []
    for m in rows:
        if cfg.family != "ssm":          # archs with attention layers
            shapes += [
                GemmShape(m, d, hq * hd),    # q projection
                GemmShape(m, d, hkv * hd),   # k / v projections
                GemmShape(m, hq * hd, d),    # attention output projection
            ]
        if cfg.moe is None:              # dense FFN (MoE experts run via einsum)
            shapes += [
                GemmShape(m, d, ff),         # FFN up (and swiglu gate)
                GemmShape(m, ff, d),         # FFN down
            ]
        shapes.append(GemmShape(m, d, vocab))  # LM head
    seen, out = set(), []
    for s in shapes:
        if s not in seen:
            seen.add(s)
            out.append(s)
    return out


def autotune_for_serving(cfg, *, slots: int, mode: str = "analytic",
                         chunks: Optional[List[int]] = None,
                         dtype: Optional[str] = None,
                         backend: str = "pallas",
                         verbose: bool = True) -> None:
    """Warm the tuner cache for this model's shapes and enable tuned dispatch.

    `dtype`/`backend` select the candidate space: a w8a8 engine tunes int8
    tiles for the fused "w8a8" kernel — a *separate* search from the float
    tiles (int8 packs 32 sublanes and twice the tile per VMEM byte, so the
    winners differ; see tuning/candidates.py)."""
    from repro import tuning

    tuner = tuning.Autotuner(mode=mode)
    tuning.set_tuner(tuner)
    shapes = serving_gemm_shapes(cfg, slots=slots, chunks=chunks)
    dtype = dtype or cfg.dtype
    if verbose:
        print(f"autotune[{mode}]: {len(shapes)} GeMM shapes for {cfg.name} "
              f"({dtype}/{backend})")
    for r, s in zip(tuner.warmup(shapes, dtype=dtype, backend=backend), shapes):
        if verbose:
            hit = "cache" if r.from_cache else r.source
            print(f"  {s.M}x{s.K}x{s.N}: tile=({r.spec.tm},{r.spec.tk},"
                  f"{r.spec.tn}) [{hit}]")
    tuning.enable()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

# Nearest-rank percentile over a possibly-empty sequence (0.0 when empty).
# The definition lives in repro.obs (obs/hist.py), shared with
# Histogram.percentile's rank math; the module-level alias stays for
# back-compat (cluster/metrics.py and tests imported it from here before
# the helper moved into repro.obs).
percentile = _obs_percentile


@dataclasses.dataclass
class RequestMetrics:
    rid: int
    prompt_len: int
    new_tokens: int
    ttft_s: float                 # submit -> first generated token
    latency_s: float              # submit -> finish
    queue_steps: int              # engine ticks spent waiting for a slot
    cached_tokens: int = 0        # prompt tokens served from a shared prefix
    priority: str = "interactive"  # SLO class (repro.serving.request)
    tenant: str = "default"
    preemptions: int = 0          # times this request was swapped out

    @property
    def decode_tok_s(self) -> float:
        """Per-request decode rate: tokens after the first over the time
        after the first (the first token falls out of the final prefill
        chunk, so it belongs to TTFT, not decode)."""
        span = self.latency_s - self.ttft_s
        return (self.new_tokens - 1) / span if span > 0 else 0.0


@dataclasses.dataclass
class EngineMetrics:
    prefill_chunks: int = 0
    prefill_tokens: int = 0
    decode_steps: int = 0
    decode_tokens: int = 0
    decode_time_s: float = 0.0    # wall clock spent in decode ticks only
    aot_steps: int = 0            # executables compiled during warmup
    cold_compiles: int = 0        # steps that missed the warmup cache
    precision: str = "float"      # execution precision (quant/modes.py)
    weight_bytes: int = 0         # resident param bytes (post-quantization)
    weight_bytes_float: int = 0   # param bytes before quantization
    calib_sites: int = 0          # activation sites calibrated in warmup
    peak_blocks_in_use: int = 0
    occupancy_sum: float = 0.0
    occupancy_samples: int = 0
    elapsed_s: float = 0.0
    prefix_lookups: int = 0       # admissions that consulted the prefix cache
    prefix_hits: int = 0          # admissions seeded from a cached prefix
    prefix_hit_tokens: int = 0    # prompt tokens whose prefill was skipped
    spec_ticks: int = 0           # decode ticks that ran batched verification
    spec_draft_tokens: int = 0    # draft tokens proposed to the verifier
    spec_accepted_tokens: int = 0  # draft tokens verification accepted
    preemptions: int = 0          # decode victims swapped out for a higher class
    swap_out_blocks: int = 0      # KV blocks serialized to host memory
    swap_in_blocks: int = 0       # KV blocks restored on re-admission
    swap_time_s: float = 0.0      # wall clock in swap-out + restore transfers
    sampled_tokens: int = 0       # tokens emitted via the sampling head
    kv_precision: str = "float"   # pool residency (serving/kv_cache.py)
    kv_pool_bytes: int = 0        # resident KV pool bytes across all layers
    kv_pool_blocks: int = 0       # pool blocks (incl. the null block)
    kv_bytes_per_block: int = 0   # pool bytes per block across all layers
    kv_slot_capacity: int = 0     # max-length requests the pool can hold
    prefill_time_s: float = 0.0   # wall clock spent in prefill-chunk steps
    requests: List[RequestMetrics] = dataclasses.field(default_factory=list)
    # Streaming percentile sketches (repro.obs.hist): fed on every finish,
    # bounded regardless of how long the engine lives.  The raw `requests`
    # list stays for exact/offline analysis but may be capped
    # (Engine(request_log=N)); once entries are dropped, the histograms
    # become the percentile source of truth.
    requests_dropped: int = 0
    ttft_hist: Histogram = dataclasses.field(default_factory=Histogram)
    latency_hist: Histogram = dataclasses.field(default_factory=Histogram)
    tok_s_hist: Histogram = dataclasses.field(default_factory=Histogram)
    # Live utilization gauges (repro.obs.mfu), owned/installed by the engine.
    mfu: Optional[MfuMeter] = None

    def note_request(self, rm: RequestMetrics,
                     log_limit: Optional[int] = None) -> None:
        """Record one finished request: feed the streaming histograms and
        append to the raw log, trimming it to `log_limit` entries (oldest
        first) when set."""
        self.ttft_hist.add(rm.ttft_s)
        self.latency_hist.add(rm.latency_s)
        self.tok_s_hist.add(rm.decode_tok_s)
        self.requests.append(rm)
        if log_limit is not None and len(self.requests) > log_limit:
            drop = len(self.requests) - log_limit
            del self.requests[:drop]
            self.requests_dropped += drop

    @property
    def finished_requests(self) -> int:
        """Total requests finished (raw log length + trimmed entries)."""
        return len(self.requests) + self.requests_dropped

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / max(1, self.occupancy_samples)

    @property
    def throughput_tok_s(self) -> float:
        """Decode throughput over decode-tick time only — dividing by the
        total elapsed time would fold prefill ticks into the denominator
        and understate prompt-heavy workloads."""
        return self.decode_tokens / self.decode_time_s if self.decode_time_s else 0.0

    def ttft_percentile(self, q: float) -> float:
        """Nearest-rank TTFT percentile: exact over the raw log while it is
        complete, histogram-backed (within Histogram.rel_error) once the
        capped log has dropped entries."""
        if self.requests and not self.requests_dropped:
            return percentile([r.ttft_s for r in self.requests], q)
        return self.ttft_hist.percentile(q)

    def latency_percentile(self, q: float) -> float:
        if self.requests and not self.requests_dropped:
            return percentile([r.latency_s for r in self.requests], q)
        return self.latency_hist.percentile(q)

    def decode_tok_s_percentile(self, q: float) -> float:
        if self.requests and not self.requests_dropped:
            return percentile([r.decode_tok_s for r in self.requests], q)
        return self.tok_s_hist.percentile(q)

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / max(1, self.prefix_lookups)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens that survived verification."""
        return self.spec_accepted_tokens / max(1, self.spec_draft_tokens)

    @property
    def decode_tok_per_tick(self) -> float:
        """Mean committed tokens per decode tick, summed across slots: one
        token per *active slot* per tick without speculation; up to
        slots x (accepted + 1) with it — the utilization metric batched
        verification moves."""
        return self.decode_tokens / max(1, self.decode_steps)

    def summary(self) -> str:
        n = self.finished_requests
        if self.requests and not self.requests_dropped:
            ttft = np.mean([r.ttft_s for r in self.requests])
            lat = np.mean([r.latency_s for r in self.requests])
        else:
            ttft, lat = self.ttft_hist.mean, self.latency_hist.mean
        out = (
            f"requests={n} prefill_chunks={self.prefill_chunks} "
            f"prefill_tokens={self.prefill_tokens} "
            f"decode_steps={self.decode_steps} "
            f"decode={self.decode_tokens} tok ({self.throughput_tok_s:.1f} tok/s) "
            f"ttft={ttft*1e3:.0f}ms "
            f"(p50={self.ttft_percentile(50)*1e3:.0f}ms "
            f"p95={self.ttft_percentile(95)*1e3:.0f}ms) "
            f"latency={lat*1e3:.0f}ms "
            f"req_tok_s_p50={self.decode_tok_s_percentile(50):.1f} "
            f"p95={self.decode_tok_s_percentile(95):.1f} "
            f"kv_occupancy={self.mean_occupancy:.0%} "
            f"peak_blocks={self.peak_blocks_in_use} "
            f"warmed={self.aot_steps} cold_compiles={self.cold_compiles}"
        )
        if self.kv_pool_bytes:
            out += (
                f" kv_pool={self.kv_pool_bytes / 2**20:.1f}MiB "
                f"({self.kv_pool_blocks} blk x "
                f"{self.kv_bytes_per_block / 2**10:.1f}KiB, "
                f"{self.kv_precision}) "
                f"slots@max_seq={self.kv_slot_capacity}"
            )
        if self.prefix_lookups:
            out += (
                f" prefix_hits={self.prefix_hits}/{self.prefix_lookups} "
                f"({self.prefix_hit_tokens} tok reused)"
            )
        if self.spec_ticks:
            out += (
                f" spec_ticks={self.spec_ticks}/{self.decode_steps} "
                f"accept={self.acceptance_rate:.0%} "
                f"tok/tick={self.decode_tok_per_tick:.2f}"
            )
        if self.preemptions:
            out += (
                f" preemptions={self.preemptions} "
                f"(swap out={self.swap_out_blocks} blk "
                f"in={self.swap_in_blocks} blk "
                f"{self.swap_time_s * 1e3:.0f}ms)"
            )
        if self.sampled_tokens:
            out += f" sampled={self.sampled_tokens} tok"
        if self.precision != "float":
            saved = (1.0 - self.weight_bytes / self.weight_bytes_float
                     if self.weight_bytes_float else 0.0)
            out += (
                f" precision={self.precision} "
                f"weights={self.weight_bytes / 2**20:.1f}MiB "
                f"({saved:.0%} smaller)"
            )
            if self.calib_sites:
                out += f" calib_sites={self.calib_sites}"
        if self.mfu is not None:
            frag = self.mfu.summary()
            if frag:
                out += " " + frag
        return out

    def as_dict(self) -> dict:
        """JSON-serializable snapshot (launch/serve.py --metrics-json):
        scalar gauges, percentile sketches, and the per-phase utilization
        figures."""
        return {
            "requests": self.finished_requests,
            "requests_dropped_from_log": self.requests_dropped,
            "prefill_chunks": self.prefill_chunks,
            "prefill_tokens": self.prefill_tokens,
            "prefill_time_s": self.prefill_time_s,
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "decode_time_s": self.decode_time_s,
            "throughput_tok_s": self.throughput_tok_s,
            "ttft_p50_s": self.ttft_percentile(50),
            "ttft_p95_s": self.ttft_percentile(95),
            "latency_p50_s": self.latency_percentile(50),
            "latency_p95_s": self.latency_percentile(95),
            "req_tok_s_p50": self.decode_tok_s_percentile(50),
            "req_tok_s_p95": self.decode_tok_s_percentile(95),
            "mean_occupancy": self.mean_occupancy,
            "peak_blocks_in_use": self.peak_blocks_in_use,
            "precision": self.precision,
            "kv_precision": self.kv_precision,
            "kv_pool_bytes": self.kv_pool_bytes,
            "prefix_hits": self.prefix_hits,
            "prefix_lookups": self.prefix_lookups,
            "spec_ticks": self.spec_ticks,
            "acceptance_rate": self.acceptance_rate,
            "preemptions": self.preemptions,
            "swap_out_blocks": self.swap_out_blocks,
            "swap_in_blocks": self.swap_in_blocks,
            "swap_time_s": self.swap_time_s,
            "sampled_tokens": self.sampled_tokens,
            "aot_steps": self.aot_steps,
            "cold_compiles": self.cold_compiles,
            "ttft_hist": self.ttft_hist.to_dict(),
            "latency_hist": self.latency_hist.to_dict(),
            "tok_s_hist": self.tok_s_hist.to_dict(),
            "mfu": self.mfu.as_dict() if self.mfu is not None else None,
        }


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class Engine:
    """Continuous-batching serving engine over the paged decode state."""

    def __init__(
        self,
        cfg,
        params=None,
        *,
        slots: int = 4,
        max_seq: int = 256,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        max_chunk: int = 64,
        autotune: bool = False,
        tune_mode: str = "analytic",
        precision: str = "float",
        kv_precision: str = "float",
        calib_batches=None,
        max_queue: Optional[int] = None,
        prefix_cache=False,
        speculative=False,
        sampling: bool = False,
        preempt: bool = False,
        trace=False,
        trace_flow: bool = True,
        request_log: Optional[int] = None,
        seed: int = 0,
        verbose: bool = False,
    ):
        from repro.launch import steps as steps_lib

        if precision != "float":
            from repro.quant import modes as _qmodes

            if precision not in _qmodes.MODES:
                raise ValueError(
                    f"unknown precision {precision!r}; known: {_qmodes.MODES}")
        self.precision = precision
        if kv_precision not in ("float", "int8"):
            raise ValueError(
                f"unknown kv_precision {kv_precision!r}; known: float, int8")
        # Orthogonal to `precision` (weight/activation GeMMs): int8 KV keeps
        # the *pool* int8-resident with per-(block, position, head) scales;
        # the decode kernel dequantizes in-VMEM (kernels/flash_decode.py).
        self.kv_precision = kv_precision
        self._calib_batches = calib_batches
        self._seed = seed
        self.cfg = cfg
        self.params = (params if params is not None
                       else M.init_model(jax.random.PRNGKey(seed), cfg))
        self.slots, self.max_seq = slots, max_seq
        self.block_size = block_size
        self.max_blocks_per_slot = kvc.blocks_for(max_seq, block_size)
        self.num_blocks = num_blocks or kvc.default_pool_blocks(
            slots, max_seq, block_size)
        # No prompt can exceed max_seq, so larger buckets would only be
        # compiled, never dispatched.
        self.max_chunk = min(max_chunk, max_seq)
        self.autotune = autotune
        self.tune_mode = tune_mode
        self.verbose = verbose

        # Speculative decoding (serving/speculative.py): a model-free
        # prompt-lookup drafter proposes up to spec.k tokens per request per
        # tick; one batched verify step scores them all.  False/None -> off,
        # True -> defaults, int -> draft length K, SpecConfig -> as given.
        self.spec = coerce_spec(speculative)
        self.drafter = NgramDrafter(self.spec) if self.spec else None

        # Stochastic sampling (temperature/top-k/top-p, models/model.py
        # sampling section).  The flag only controls *warmup*: a sampling
        # RequestSpec on a sampling=False engine still works, it just pays
        # one cold compile for the sample step.  All-greedy batches always
        # dispatch the plain greedy steps, so greedy traffic stays bitwise
        # identical whatever this flag says.
        self.sampling = bool(sampling)
        # KV-swap preemption: an interactive arrival may evict a decoding
        # batch-class request by serializing its blocks to host memory and
        # restoring them on re-admission.  Attention-only stacks only —
        # recurrent (SSM/xLSTM) per-slot state is not block-addressable, so
        # a swap round trip would silently drop it.
        self.preempt = bool(preempt)
        if self.preempt and any(
                k not in ("attn", "attn_local") for k in cfg.layer_kinds()):
            raise ValueError(
                "preempt requires an attention-only stack; "
                f"{cfg.name} has kinds {cfg.layer_kinds()}")
        self._swapped: Dict[int, tuple] = {}   # rid -> (payload, n_blocks)

        self.scheduler = Scheduler(slots, max_chunk=max_chunk, max_queue=max_queue)
        self.alloc = kvc.BlockAllocator(self.num_blocks, block_size)
        self.tables = kvc.BlockTables(slots, self.max_blocks_per_slot)
        # Prompt-prefix reuse (cluster/prefix_cache.py): requests whose
        # prompts share full, block-aligned prefixes fork the already-written
        # KV blocks (refcounted) and prefill only the uncached suffix.
        # Limited to attention-only stacks — a recurrent (SSM/xLSTM) layer's
        # state is not captured by KV blocks, so a seeded prefix would skip
        # its scan.
        self.prefix_cache = None
        if prefix_cache:
            if any(k not in ("attn", "attn_local") for k in cfg.layer_kinds()):
                raise ValueError(
                    "prefix_cache requires an attention-only stack; "
                    f"{cfg.name} has kinds {cfg.layer_kinds()}")
            if prefix_cache is True or isinstance(prefix_cache, int):
                from repro.cluster.prefix_cache import PrefixCache

                # True: unbounded (pool pressure evicts); int: max_blocks.
                mb = None if prefix_cache is True else int(prefix_cache)
                self.prefix_cache = PrefixCache(self.alloc, max_blocks=mb)
            else:
                # Caller-built cache (e.g. a subclass wired to eng.alloc
                # post-construction): block ids only mean anything inside
                # the allocator that issued them.
                if prefix_cache.alloc is not self.alloc:
                    raise ValueError(
                        "prefix_cache is bound to a different allocator; "
                        "pass True (or a max_blocks int) and let the engine "
                        "build its own, or construct the cache from "
                        "engine.alloc")
                self.prefix_cache = prefix_cache
        self._prefix_match: Dict[int, tuple] = {}  # rid -> (blocks, toks, fresh)
        self._seeded: Dict[int, int] = {}          # rid -> forked block count
        self.state = M.init_paged_decode_state(
            cfg, slots, num_blocks=self.num_blocks, block_size=block_size,
            max_blocks_per_slot=self.max_blocks_per_slot,
            kv_precision=kv_precision,
        )
        self.metrics = EngineMetrics()
        # Live utilization gauges (repro.obs.mfu): a few float adds per tick,
        # so they stay on unconditionally — summary() always carries a
        # per-phase utilization/MFU figure.
        self.mfu = MfuMeter(cfg)
        self.metrics.mfu = self.mfu
        # Raw request-log cap: None keeps every RequestMetrics (exact
        # percentiles, benchmark-friendly); an int bounds the log for
        # long-lived serving and flips percentiles onto the histograms.
        self._request_log = request_log
        # Span/event tracing (repro.obs.trace): off by default — NULL_TRACER
        # makes every record call a no-op method dispatch.  Pass True for a
        # fresh ring, or a Tracer to aggregate several engines into one
        # export (cluster/replica.py names one per replica).
        if isinstance(trace, Tracer):
            self.tracer = trace
        elif trace:
            self.tracer = Tracer(name=f"engine[{cfg.name}]")
        else:
            self.tracer = NULL_TRACER
        tc = self.tracer.intern
        self._ev_tick = tc("tick")
        self._ev_sched = tc("sched")
        self._ev_prefill = tc("prefill")
        self._ev_decode = tc("decode")
        self._ev_verify = tc("verify")
        self._ev_draft = tc("draft")
        self._ev_reset = tc("reset")
        self._ev_kv_in_use = tc("kv_blocks_in_use")
        self._ev_kv_reserved = tc("kv_blocks_reserved")
        self._ev_queue = tc("queue_depth")
        self._ev_req_queued = tc("queued")
        self._ev_req_prefill = tc("req_prefill")
        self._ev_req_decode = tc("req_decode")
        # Request-flow tracing (cross-lane arrows + annotated instants) on
        # top of the spans above.  `trace_flow=False` restores the pre-flow
        # event set — the A/B baseline benchmarks/obs_bench.py measures
        # flow overhead against.
        self._flow = bool(trace_flow) and self.tracer.enabled
        self._ev_submit = tc("submit")
        self._ev_flow = tc("req")            # one flow chain per request
        self._ev_shed = tc("shed")
        self._ev_prefix_hit = tc("prefix_hit")
        self._ev_evict = tc("cache_evict")
        self._ev_preempt = tc("preempt")
        self._ev_restore = tc("restore")
        self._account_kv_pools()

        # The decode state (KV pools included) is *donated* to every step:
        # XLA updates the pools in place instead of copying them per tick.
        # Without donation each step memcpys the whole pool (tens of MB for
        # even small configs) — measured ~1000x slower for the update itself
        # on CPU, and the copies saturate memory bandwidth, which is exactly
        # the resource replica threads must share (cluster/replica.py).
        # Every call site immediately reassigns self.state from the step's
        # return, so the consumed buffers are never touched again.
        self._decode_fn = jax.jit(
            steps_lib.make_paged_serve_step(cfg), donate_argnums=(1,))
        self._chunk_fn = jax.jit(
            steps_lib.make_prefill_chunk_step(cfg), donate_argnums=(1,))
        self._verify_fn = jax.jit(
            steps_lib.make_paged_verify_step(cfg), donate_argnums=(1,))
        self._sample_fn = jax.jit(
            steps_lib.make_paged_sample_step(cfg), donate_argnums=(1,))
        self._verify_sample_fn = jax.jit(
            steps_lib.make_paged_verify_sample_step(cfg), donate_argnums=(1,))
        # Prefill first token under sampling: the final chunk's (1, 1, V)
        # logits feed the same sample_tokens head the decode step uses, so
        # one seed stream covers every generated position.  No donation —
        # logits are a fresh output, not the threaded state.
        self._sample1_fn = jax.jit(
            lambda lg, t, k, p, s, i: M.sample_tokens(
                lg[:, -1], jnp.reshape(s, (1,)), jnp.reshape(i, (1,)),
                jnp.reshape(t, (1,)), jnp.reshape(k, (1,)),
                jnp.reshape(p, (1,))))
        self._reset_fn = jax.jit(
            lambda state, mask: M.reset_slots(cfg, state, mask),
            donate_argnums=(0,))
        self._warmed: set = set()                # step shapes compiled so far
        self._slot_used = [False] * slots        # occupied at least once
        # Scalar construction (jnp.int32) costs ~0.7 ms on CPU jax; slot ids
        # are a fixed set, so build them once.
        self._slot_ids = [jnp.int32(s) for s in range(slots)]
        self._last_token = np.zeros((slots,), np.int32)
        self._reserved: Dict[int, int] = {}      # rid -> blocks reserved
        self._step = 0
        self._t0: Optional[float] = None
        self._submit_t: Dict[int, float] = {}
        self._first_tok_t: Dict[int, float] = {}
        self.results: Dict[int, np.ndarray] = {}

    def share_steps_from(self, other: "Engine") -> None:
        """Reuse another engine's jitted step callables (and their compile
        caches).  Only valid across engines of the same config — same
        traces, same shapes; ReplicaPool uses this so a pool compiles each
        step shape once, and benchmarks/tests use it to not re-pay warmup
        per engine.  The single place that knows the step-field list."""
        self._decode_fn = other._decode_fn
        self._chunk_fn = other._chunk_fn
        self._verify_fn = other._verify_fn
        self._sample_fn = other._sample_fn
        self._verify_sample_fn = other._verify_sample_fn
        self._sample1_fn = other._sample1_fn
        self._reset_fn = other._reset_fn

    def _account_kv_pools(self) -> None:
        """KV-pool residency accounting (metrics): total pool bytes across
        every attention layer (scales included for int8 pools), per-block
        cost, and how many max_seq-length requests the pool can hold at
        once (the null block never serves data)."""
        pools = [
            leaf for leaf in jax.tree_util.tree_leaves(
                self.state.caches,
                is_leaf=lambda x: isinstance(x, kvc.PagedKVCache))
            if isinstance(leaf, kvc.PagedKVCache)
        ]
        m = self.metrics
        m.kv_precision = self.kv_precision
        m.kv_pool_bytes = sum(kvc.pool_bytes(p) for p in pools)
        m.kv_pool_blocks = self.num_blocks
        m.kv_bytes_per_block = m.kv_pool_bytes // self.num_blocks
        m.kv_slot_capacity = (self.num_blocks - 1) // self.max_blocks_per_slot

    # -- warmup: the configuration-pre-loading analogue ----------------------

    def warmup(self) -> None:
        """Autotune GeMM tiles and trace+compile every step shape before
        traffic: the decode step, each prefill-chunk bucket, the slot reset.

        Each step is invoked once on dummy inputs (outputs discarded — the
        steps are functional), populating the jit executable cache; serve
        time then always dispatches through jit's C++ fast path.  An AOT
        ``.lower().compile()`` executable would also pre-compile, but its
        Python-side call path re-validates the params pytree per call
        (measured ~4 ms/step on CPU, double the decode step itself).

        With ``precision != "float"`` warmup additionally covers the paper's
        deployment precision: (optionally) calibrate activation scales,
        quantize the weights int8-resident *once*, and trace every step
        inside the precision context — so the compiled executables are int8
        end to end and serving never quantizes a weight again."""
        buckets = chunk_buckets(self.max_chunk)
        warm_code = self.tracer.intern("warmup")
        self.tracer.begin(warm_code)
        if self.autotune:
            w8a8 = self.precision != "float"
            autotune_for_serving(
                self.cfg, slots=self.slots, mode=self.tune_mode,
                chunks=buckets, verbose=self.verbose,
                dtype="int8" if w8a8 else None,
                backend="w8a8" if w8a8 else "pallas")
            # Decode-attention design point (tuning/decode.py), bound at
            # trace time like the precision mode: every step traced below
            # bakes in the tuned FlashDecodeSpec.  Shares the tuner cache
            # autotune_for_serving just installed.
            from repro import tuning
            from repro.kernels import flash_decode as _fd

            dspec = tuning.tune_decode_for_serving(
                self.cfg, slots=self.slots, block_size=self.block_size,
                max_blocks=self.max_blocks_per_slot, mode=self.tune_mode,
                verbose=self.verbose)
            if dspec is not None:
                _fd.set_decode_spec(dspec)
        if self.precision != "float":
            self._quantize_weights()
        tokens = jnp.zeros((self.slots, 1), jnp.int32)
        active = jnp.zeros((self.slots,), bool)
        slot0 = self._slot_ids[0]
        # The steps donate their state input, so warmup *threads* the state
        # through every call instead of discarding outputs, then rebuilds a
        # fresh zero state (the chunk steps advanced slot 0's length).
        state = self.state
        with self._precision_ctx():
            _, state = self._decode_fn(self.params, state, tokens, active)
            self._warmed.add("decode")
            logits1 = None
            for c in buckets:
                logits1, state = self._chunk_fn(
                    self.params, state, jnp.zeros((1, c), jnp.int32), slot0)
                self._warmed.add(f"chunk{c}")
            zt = np.zeros((self.slots,), np.float32)
            zk = np.zeros((self.slots,), np.int32)
            op = np.ones((self.slots,), np.float32)
            if self.sampling:
                _, state = self._sample_fn(self.params, state, tokens, active,
                                           zt, zk, op, zk, zk)
                self._warmed.add("decode_sample")
                # Warm the prefill-token sampler on real chunk logits so the
                # compiled executable matches serve-time dtype exactly.
                self._sample1_fn(logits1, np.float32(0.0), np.int32(0),
                                 np.float32(1.0), np.int32(0), np.int32(0))
                self._warmed.add("sample1")
            if self.spec is not None:
                # Every verify width the drafter can produce (speculative
                # K buckets), compiled before traffic like the chunk sizes.
                lim = jnp.ones((self.slots,), jnp.int32)
                no_eos = jnp.full((self.slots,), -1, jnp.int32)
                for s in verify_buckets(self.spec.k):
                    _, _, state = self._verify_fn(
                        self.params, state,
                        jnp.zeros((self.slots, s), jnp.int32), active,
                        lim, no_eos)
                    self._warmed.add(f"verify{s}")
                    if self.sampling:
                        _, _, state = self._verify_sample_fn(
                            self.params, state,
                            jnp.zeros((self.slots, s), jnp.int32), active,
                            lim, no_eos, zt, zk, op, zk, zk)
                        self._warmed.add(f"verify_sample{s}")
            state = self._reset_fn(state, jnp.zeros((self.slots,), bool))
            self._warmed.add("reset")
            jax.block_until_ready(state)
        self.state = M.init_paged_decode_state(
            self.cfg, self.slots, num_blocks=self.num_blocks,
            block_size=self.block_size,
            max_blocks_per_slot=self.max_blocks_per_slot,
            kv_precision=self.kv_precision)
        self.metrics.aot_steps = len(self._warmed)
        self.tracer.end(warm_code)
        if self.verbose:
            extra = (f" + verify {verify_buckets(self.spec.k)}"
                     if self.spec is not None else "")
            print(f"warmup: {len(self._warmed)} step shapes compiled "
                  f"(decode + chunks {buckets}{extra} + reset)"
                  + (f" [{self.precision}]" if self.precision != "float" else ""))

    def _precision_ctx(self):
        """Context the engine traces its steps under.  Trace-time dispatch:
        the precision mode binds when a step is traced (quant/modes.py), so
        warmup and any cold compile enter this context; executing the
        already-compiled steps needs no context."""
        import contextlib

        if self.precision == "float":
            return contextlib.nullcontext()
        from repro.quant import modes as qmodes

        return qmodes.precision(self.precision)

    def _quantize_weights(self) -> None:
        """Calibrate (for "w8a8-calibrated") and swap the float params for
        the int8-resident pytree; the float copy is dropped — the memory
        saving is real, not additive."""
        from repro import quant

        scales = None
        if self.precision == "w8a8-calibrated":
            batches = self._calib_batches
            if batches is None:
                batches = quant.synthetic_batches(
                    self.cfg, n=2, batch=2,
                    seq=min(32, self.max_seq), seed=self._seed)
            scales = quant.collect_scales(self.params, self.cfg, batches)
            self.metrics.calib_sites = len(scales)
            if self.verbose:
                print(f"calibrated {len(scales)} activation sites "
                      f"({scales.observer}, {scales.batches} batches)")
        self.metrics.weight_bytes_float = quant.weight_bytes(self.params)
        self.params = quant.quantize_params(
            self.params, cfg=self.cfg, scales=scales)
        self.metrics.weight_bytes = quant.weight_bytes(self.params)
        self.metrics.precision = self.precision
        if self.verbose:
            mb = 2**20
            print(f"quantized {quant.quantized_leaf_count(self.params)} "
                  f"weights int8-resident: "
                  f"{self.metrics.weight_bytes_float / mb:.1f}MiB -> "
                  f"{self.metrics.weight_bytes / mb:.1f}MiB")

    def _run_compiled(self, key: str, fn, *args):
        if key not in self._warmed:
            self.metrics.cold_compiles += 1
            self._warmed.add(key)
            with self._precision_ctx():   # cold trace: bind the precision
                return fn(*args)
        return fn(*args)

    # -- request lifecycle ---------------------------------------------------

    def submit(self, request, max_new: Optional[int] = None, *,
               eos_token: Optional[int] = None,
               trace_id: Optional[int] = None) -> Optional[Request]:
        """Queue a request: a ``RequestSpec``, or the legacy
        ``(prompt, max_new)`` form (deprecated, shimmed through
        ``repro.serving.request.as_spec``).  The spec's ``trace_id`` (or
        the keyword, for legacy callers) threads an externally-minted id
        (the router's cluster-wide request id) into this request's flow
        chain and lifecycle spans; engine-local submissions mint their own,
        namespaced by the tracer's pid so ids never collide across replica
        lanes in one export."""
        spec = as_spec(request, max_new, eos_token=eos_token,
                       trace_id=trace_id)
        if spec.prompt_len + spec.max_new > self.max_seq:
            raise ValueError(
                f"prompt {spec.prompt_len} + max_new {spec.max_new} exceeds "
                f"max_seq {self.max_seq}")
        if (kvc.blocks_for(spec.prompt_len + spec.max_new, self.block_size)
                > self.num_blocks - 1):
            raise ValueError(
                f"request needs more KV blocks than the whole pool "
                f"({self.num_blocks - 1}); raise num_blocks")
        req = self.scheduler.submit(spec, step=self._step)
        tr = self.tracer
        if req is not None:
            req.trace_id = (int(spec.trace_id) if spec.trace_id is not None
                            else (tr.pid << 24) + req.rid)
            self._submit_t[req.rid] = time.monotonic()
            if self._flow:
                # Flow events bind to the duration slice open at their
                # timestamp, so the chain's first link sits in a tiny
                # "submit" slice (a step when the router already started
                # the chain in its admit slice).
                tr.begin(self._ev_submit)
                if spec.trace_id is None:
                    tr.flow_start(self._ev_flow, req.trace_id)
                else:
                    tr.flow_step(self._ev_flow, req.trace_id)
                tr.end(self._ev_submit)
            tr.async_begin(self._ev_req_queued, req.trace_id)
            tr.counter(self._ev_queue, len(self.scheduler.queue))
        elif self._flow:
            tr.instant(self._ev_shed, len(self.scheduler.queue))
        return req

    def _can_admit(self, req: Request) -> bool:
        need = kvc.blocks_for(req.prompt_len + req.max_new, self.block_size)
        if req.swapped:
            # Preempted victim re-admitting: its cache already diverged from
            # any shared prefix (it decoded past the prompt), so the bytes
            # are restored verbatim into fresh private blocks — no prefix
            # fork, full worst-case reservation like a fresh admit.
            return self.alloc.can_reserve(need)
        if self.prefix_cache is None:
            return self.alloc.can_reserve(need)
        # Prefix path: match full blocks of an already-prefilled identical
        # prompt prefix, fork them (refcount, zero KV bytes moved), and
        # reserve only the *fresh* worst case.  Under pool pressure the
        # cache gives blocks back (LRU) before we refuse admission.  The
        # fork happens *before* eviction so an eviction sweep that reaches
        # our own matched nodes can only drop the cache's refs — the blocks
        # stay alive under ours.
        blocks, tokens = self.prefix_cache.lookup(req.prompt)
        if blocks:
            kvc.fork_blocks(self.alloc, blocks)
        n_fresh = need - len(blocks)
        if not self.alloc.can_reserve(n_fresh):
            shortfall = n_fresh - self.alloc.available
            if self._flow:
                self.tracer.instant(self._ev_evict, shortfall)
            self.prefix_cache.evict(shortfall)
            if not self.alloc.can_reserve(n_fresh):
                if blocks:
                    self.alloc.free(blocks)     # un-fork: admission refused
                return False
        req.cached_tokens = tokens
        self._prefix_match[req.rid] = (blocks, tokens, n_fresh)
        return True

    def _admit(self) -> None:
        self._admit_once()
        if not self.preempt:
            return
        # Preemption sweep: while a queued request outranks running decode
        # work, swap the lowest-class, youngest decoding victim out and
        # retry admission.  Bounded by the slot count (each pass frees at
        # most one slot, and victims must strictly outrank the head).
        for _ in range(self.slots):
            victim = self._pick_victim()
            if victim is None:
                break
            self._swap_out(victim)
            self._admit_once()

    def _admit_once(self) -> None:
        to_reset, seeds, restores = [], [], []
        for slot, req in self.scheduler.admit(self._can_admit):
            # Request lifecycle track: the queued span ends here, the prefill
            # span opens (closed on the prompt-complete prefill chunk) — or,
            # for a restored victim, the decode span reopens directly.
            self.tracer.async_end(self._ev_req_queued, req.trace_id)
            if req.swapped:
                self.tracer.async_begin(self._ev_req_decode, req.trace_id)
                n = kvc.blocks_for(req.prompt_len + req.max_new,
                                   self.block_size)
                if not self.alloc.reserve(n):
                    raise RuntimeError(
                        f"reservation of {n} blocks failed post-admit")
                self._reserved[req.rid] = n
                self._seeded[req.rid] = 0   # restored blocks are private
                restores.append((slot, req))
                if self._slot_used[slot]:
                    to_reset.append(slot)
                self._slot_used[slot] = True
                continue
            self.tracer.async_begin(self._ev_req_prefill, req.trace_id)
            blocks, ptoks, n_fresh = self._prefix_match.pop(
                req.rid, ((), 0, None))
            n = (n_fresh if n_fresh is not None else
                 kvc.blocks_for(req.prompt_len + req.max_new, self.block_size))
            if not self.alloc.reserve(n):   # _can_admit just vouched for this
                raise RuntimeError(f"reservation of {n} blocks failed post-admit")
            self._reserved[req.rid] = n
            self._seeded[req.rid] = len(blocks)
            if self.prefix_cache is not None:
                self.metrics.prefix_lookups += 1
                if blocks:
                    self.metrics.prefix_hits += 1
                    self.metrics.prefix_hit_tokens += ptoks
                    if self._flow:
                        self.tracer.instant(self._ev_prefix_hit, ptoks)
                    seeds.append((slot, list(blocks), ptoks))
            # A *refilled* slot needs its recurrent state and length zeroed
            # (the rest of the batch keeps decoding undisturbed); a
            # never-used slot is already zeroed — no step needed.
            if self._slot_used[slot]:
                to_reset.append(slot)
            self._slot_used[slot] = True
        if to_reset:
            mask = np.zeros((self.slots,), bool)
            mask[to_reset] = True
            self.tracer.begin(self._ev_reset)
            self.state = self._run_compiled(
                "reset", self._reset_fn, self.state, jnp.asarray(mask))
            self.tracer.end(self._ev_reset)
        if seeds:
            # Install the forked prefix *after* any reset: the slot's table
            # row starts with the shared blocks and its length starts at the
            # (block-aligned) cached-token count, so every later KV write —
            # prefill of the suffix, then decode — lands at positions >= the
            # shared boundary, i.e. only ever in refcount-1 blocks.
            lengths = np.array(self.state.lengths)
            for slot, blocks, ptoks in seeds:
                self.tables.seed(slot, blocks)
                lengths[slot] = ptoks
            self.state = self.state._replace(lengths=jnp.asarray(lengths))
        if restores:
            self._restore(restores)

    # -- KV-swap preemption --------------------------------------------------

    def _pick_victim(self) -> Optional[Request]:
        """The decoding request to evict for the queue head: strictly lower
        class than the head, latest-submitted first (it has done the least
        work and will re-queue behind no one of its own class).  None when
        the head would gain nothing (no queue, or no lower-class victim —
        preemption never reorders within a class)."""
        head = self.scheduler.next_queued()
        if head is None:
            return None
        head_rank = priority_rank(head.priority)
        victims = [
            r for r in self.scheduler.slots
            if r is not None and r.phase is Phase.DECODE and r.out_tokens
            and priority_rank(r.priority) > head_rank
        ]
        if not victims:
            return None
        return max(victims, key=lambda r: (priority_rank(r.priority),
                                           r.submit_step, r.rid))

    def _swap_out(self, victim: Request) -> None:
        """Serialize the victim's KV blocks to host memory, release its
        blocks + reservation (the accounting mirror of _finish), and return
        it to the front of its class queue."""
        t0 = time.monotonic()
        slot = victim.slot
        ids = list(self.tables.blocks[slot])
        payload = kvc.swap_out_blocks(self.state.caches, ids)
        self._swapped[victim.rid] = (payload, len(ids))
        # Reservation unwind mirrors _finish: seeded (forked-prefix) blocks
        # were never reserved, so only fresh draws count against it.
        fresh = len(ids) - self._seeded.pop(victim.rid, 0)
        unused = max(0, self._reserved.pop(victim.rid, fresh) - fresh)
        self.scheduler.preempt(victim)
        self.tables.release(slot, self.alloc, unreserve=unused)
        self.metrics.preemptions += 1
        self.metrics.swap_out_blocks += len(ids)
        self.metrics.swap_time_s += time.monotonic() - t0
        tr = self.tracer
        tr.async_end(self._ev_req_decode, victim.trace_id)
        tr.async_begin(self._ev_req_queued, victim.trace_id)
        if self._flow:
            tr.instant(self._ev_preempt, victim.trace_id)

    def _restore(self, restores) -> None:
        """Swap preempted requests' KV payloads back into freshly-allocated
        blocks; runs after the reset step (which zeroed the slot) so the
        restored lengths/tables are what the next step sees."""
        t0 = time.monotonic()
        lengths = np.array(self.state.lengths)
        caches = self.state.caches
        for slot, req in restores:
            payload, n_blocks = self._swapped.pop(req.rid)
            ids = self.alloc.alloc(n_blocks)
            self.tables.seed(slot, ids)
            caches = kvc.swap_in_blocks(caches, ids, payload)
            # Device length between ticks is one behind req.length: the
            # newest emitted token is the *next* step's input — its KV is
            # written when it is fed, exactly as if never preempted.
            lengths[slot] = req.length - 1
            self._last_token[slot] = req.out_tokens[-1]
            req.swapped = False
            self.metrics.swap_in_blocks += n_blocks
            if self._flow:
                self.tracer.instant(self._ev_restore, req.trace_id)
        self.state = self.state._replace(
            caches=caches, lengths=jnp.asarray(lengths))
        self.metrics.swap_time_s += time.monotonic() - t0

    def _sync_tables(self) -> None:
        if self.tables.dirty:
            self.state = self.state._replace(block_tables=self.tables.array())

    def _finish(self, req: Request) -> None:
        slot = self.scheduler.release(req)
        drawn = len(self.tables.blocks[slot])
        # Seeded (forked-prefix) blocks were never reserved — only the fresh
        # draws count against this request's reservation.
        fresh_drawn = drawn - self._seeded.pop(req.rid, 0)
        unused = max(0, self._reserved.pop(req.rid, fresh_drawn) - fresh_drawn)
        self.tables.release(slot, self.alloc, unreserve=unused)
        self.results[req.rid] = np.asarray(req.out_tokens, np.int32)
        if self.drafter is not None:
            # Committed stream into the drafter corpus: greedy decoding is
            # deterministic, so a later repeat/templated request re-generates
            # this stream and the drafter proposes its true continuation.
            self.drafter.remember(
                np.concatenate([req.prompt, self.results[req.rid]]))
        now = time.monotonic()
        t_submit = self._submit_t.pop(req.rid)   # fully consumed here; a
        t_first = self._first_tok_t.pop(req.rid, now)  # long-lived engine
        self.metrics.note_request(RequestMetrics(  # must not leak these
            rid=req.rid, prompt_len=req.prompt_len,
            new_tokens=len(req.out_tokens),
            ttft_s=t_first - t_submit,
            latency_s=now - t_submit,
            queue_steps=(req.first_token_step or self._step) - req.submit_step,
            cached_tokens=req.cached_tokens,
            priority=req.priority, tenant=req.tenant,
            preemptions=req.preemptions,
        ), self._request_log)
        if self._flow:
            # Lands inside the enclosing tick slice (_record_token runs
            # after the phase span closed, before the tick ends) — the
            # arrowhead points at the tick that finished the request.
            self.tracer.flow_end(self._ev_flow, req.trace_id)
        self.tracer.async_end(self._ev_req_decode, req.trace_id)

    def _sampling_args(self, reqs: List[Request]):
        """Per-slot sampling-knob arrays for a decode/verify batch, or None
        when every request in it is greedy — the all-greedy fast path keeps
        dispatching the plain compiled steps, so greedy traffic is bitwise
        identical with or without sampling support.  Greedy rows inside a
        mixed batch get temperature 0 and emit argmax on device."""
        if all(r.sampling.is_greedy for r in reqs):
            return None
        temp = np.zeros((self.slots,), np.float32)
        top_k = np.zeros((self.slots,), np.int32)
        top_p = np.ones((self.slots,), np.float32)
        seeds = np.zeros((self.slots,), np.int32)
        gen_idx = np.zeros((self.slots,), np.int32)
        for r in reqs:
            sp = r.sampling
            temp[r.slot] = max(sp.temperature, 0.0)
            top_k[r.slot] = sp.top_k
            top_p[r.slot] = sp.top_p
            seeds[r.slot] = r.sample_seed
            gen_idx[r.slot] = len(r.out_tokens)
        return temp, top_k, top_p, seeds, gen_idx

    def _record_token(self, req: Request, token: int) -> None:
        if req.first_token_step is None:
            self._first_tok_t[req.rid] = time.monotonic()
        self.scheduler.on_token(req, token, self._step)
        self._last_token[req.slot if req.slot >= 0 else 0] = token
        if req.phase is Phase.FINISHED:
            self._finish(req)

    # -- speculative decode: draft -> verify -> rollback ---------------------

    def _decode_speculative(self, reqs: List[Request]) -> bool:
        """One speculative decode tick over the decoding slots: the n-gram
        drafter proposes per-request continuations, one batched verify step
        scores every drafted position, and rejected-position KV blocks are
        rolled back.  Returns False (without touching the device) when no
        request drafted anything — the caller falls through to the plain
        decode step, so incompressible traffic pays zero speculative
        overhead beyond the host-side lookup."""
        drafts: Dict[int, np.ndarray] = {}
        self.tracer.begin(self._ev_draft)     # host-side n-gram lookups
        for r in reqs:
            if r.remaining > 1:    # a 1-token budget can't use a draft
                # remaining - 1: the bonus token always rides along, so the
                # last draft a request could accept is its (remaining-1)-th —
                # drafting more only widens the verify GEMM for nothing.
                d = self.drafter.draft(r.context,
                                       k=min(self.spec.k, r.remaining - 1))
                if len(d):
                    drafts[r.rid] = d
        self.tracer.end(self._ev_draft)
        if not drafts:
            return False
        width = bucket_for(max(len(d) for d in drafts.values()), self.spec.k)
        tokens = np.zeros((self.slots, width), np.int32)
        limits = np.zeros((self.slots,), np.int32)
        eos = np.full((self.slots,), -1, np.int32)
        active = np.zeros((self.slots,), bool)
        for r in reqs:
            d = drafts.get(r.rid)
            nd = 0 if d is None else len(d)
            # Real draft positions need covered blocks (writes at
            # r.length - 1 .. r.length - 1 + nd); padding columns beyond the
            # draft resolve to the null block and need none.
            self.tables.ensure(r.slot, r.length + nd, self.alloc)
            tokens[r.slot, 0] = self._last_token[r.slot]
            if nd:
                tokens[r.slot, 1:1 + nd] = d
            limits[r.slot] = min(nd + 1, r.remaining)
            eos[r.slot] = -1 if r.eos_token is None else r.eos_token
            active[r.slot] = True
        self._sync_tables()
        samp = self._sampling_args(reqs)
        t_dec = time.monotonic()
        # numpy args go straight into the jitted call: the C++ fast path
        # converts them in ~µs, where a standalone jnp.asarray dispatches an
        # un-jitted XLA copy (~100-700µs each on CPU — real money against a
        # ~1ms verify step).
        self.tracer.begin(self._ev_verify)
        if self._flow:
            for r in reqs:
                self.tracer.flow_step(self._ev_flow, r.trace_id)
        if samp is None:
            greedy, n_new, self.state = self._run_compiled(
                f"verify{width}", self._verify_fn, self.params, self.state,
                tokens, active, limits, eos)
        else:
            greedy, n_new, self.state = self._run_compiled(
                f"verify_sample{width}", self._verify_sample_fn, self.params,
                self.state, tokens, active, limits, eos, *samp)
        greedy, n_new = np.asarray(greedy), np.asarray(n_new)
        self.tracer.end(self._ev_verify)
        dt_verify = time.monotonic() - t_dec
        self.metrics.decode_time_s += dt_verify
        emitted = 0
        for r in reqs:
            slot, n = r.slot, int(n_new[r.slot])
            drafted = len(drafts.get(r.rid, ()))
            self.scheduler.on_spec(r, drafted, max(0, n - 1))
            self.metrics.spec_draft_tokens += drafted
            self.metrics.spec_accepted_tokens += max(0, n - 1)
            for t in greedy[slot, :n]:
                self._record_token(r, int(t))
            emitted += n
            # Rollback: blocks drawn for rejected draft positions go back to
            # the pool (and this request's reservation).  A finished request
            # released everything already; an accept-all tick may legally
            # need *more* blocks than it holds (covered by next tick's
            # ensure), hence the guard.
            if r.phase is not Phase.FINISHED:
                held = len(self.tables.blocks[slot])
                if kvc.blocks_for(r.length, self.block_size) < held:
                    _, pair = self.tables.rewind(slot, r.length, self.alloc)
                    # The engine only ever speculates past the shared-prefix
                    # boundary, so divergence cannot trigger here.
                    assert pair is None, "spec rewind crossed a shared block"
        self.metrics.decode_steps += 1
        self.metrics.decode_tokens += emitted
        self.metrics.spec_ticks += 1
        if samp is not None:
            self.metrics.sampled_tokens += emitted
        # Verify rows: every slot runs the widened step (padding included).
        self.mfu.note("verify", tokens=emitted, rows=self.slots * width,
                      time_s=dt_verify)
        return True

    # -- the serve loop ------------------------------------------------------

    def tick(self) -> bool:
        """Admit, then execute one scheduler action.  Returns False when no
        work remains."""
        tr = self.tracer
        tr.begin(self._ev_tick)
        tr.begin(self._ev_sched)      # host scheduling: admit + pick action
        self._admit()
        action = self.scheduler.next_action()
        tr.end(self._ev_sched)
        if action is None:
            tr.end(self._ev_tick)
            return self.scheduler.has_work
        self._step += 1
        if action[0] == "prefill":
            _, req, chunk = action
            self.tables.ensure(req.slot, req.prefilled + chunk, self.alloc)
            self._sync_tables()
            tokens = jnp.asarray(
                req.prompt[None, req.prefilled:req.prefilled + chunk])
            tr.begin(self._ev_prefill)
            if self._flow:
                tr.flow_step(self._ev_flow, req.trace_id)
            t_pre = time.monotonic()
            logits, self.state = self._run_compiled(
                f"chunk{chunk}", self._chunk_fn,
                self.params, self.state, tokens, self._slot_ids[req.slot])
            # Sync so the span/MFU time covers the device step, not just its
            # dispatch.  Chunks are state-dependent (the next chunk consumes
            # this one's KV writes), so total prefill wall time is unchanged.
            logits = jax.block_until_ready(logits)
            dt_pre = time.monotonic() - t_pre
            tr.end(self._ev_prefill)
            self.scheduler.on_prefill(req, chunk, self._step)
            self.metrics.prefill_chunks += 1
            self.metrics.prefill_tokens += chunk
            self.metrics.prefill_time_s += dt_pre
            self.mfu.note("prefill", tokens=chunk, rows=chunk, time_s=dt_pre)
            if req.phase is Phase.DECODE:
                # Prompt complete: close the request's prefill span, open its
                # decode span (closed in _finish).
                tr.async_end(self._ev_req_prefill, req.trace_id)
                tr.async_begin(self._ev_req_decode, req.trace_id)
            if req.phase is Phase.DECODE and self.prefix_cache is not None:
                # Prompt fully in the pool: publish its full blocks for
                # later requests (the cache takes its own refs; the partial
                # tail block keeps receiving decode writes and is excluded).
                n_full = req.prompt_len // self.block_size
                if n_full:
                    self.prefix_cache.insert(
                        req.prompt[: n_full * self.block_size],
                        self.tables.blocks[req.slot][:n_full])
            if req.phase is Phase.DECODE:
                # Prompt complete: the chunk's last logits yield the first
                # generated token (no separate step for it).  Index on the
                # numpy copy — slicing a device array dispatches un-jitted
                # primitives that would compile tiny kernels at serve time.
                if req.sampling.is_greedy:
                    self._record_token(
                        req, int(np.argmax(np.asarray(logits)[0, -1])))
                else:
                    sp = req.sampling
                    tok = self._run_compiled(
                        "sample1", self._sample1_fn, logits,
                        np.float32(sp.temperature), np.int32(sp.top_k),
                        np.float32(sp.top_p), np.int32(req.sample_seed),
                        np.int32(len(req.out_tokens)))
                    self.metrics.sampled_tokens += 1
                    self._record_token(req, int(np.asarray(tok)[0]))
        elif self.spec is not None and self._decode_speculative(action[1]):
            pass                              # spec tick ran (metrics inside)
        else:
            _, reqs = action
            # The step writes at position r.length - 1 (the last recorded
            # token's KV goes in on the step that consumes it), so covering
            # r.length tokens suffices — +1 would draw blocks a step early.
            for r in reqs:
                self.tables.ensure(r.slot, r.length, self.alloc)
            self._sync_tables()
            # numpy args feed the jitted call directly — see the note in
            # _decode_speculative; an explicit jnp.asarray here costs more
            # than the decode step's own dispatch.
            tokens = self._last_token[:, None]
            active = np.zeros((self.slots,), bool)
            active[[r.slot for r in reqs]] = True
            samp = self._sampling_args(reqs)
            t_dec = time.monotonic()
            tr.begin(self._ev_decode)
            if self._flow:
                for r in reqs:
                    tr.flow_step(self._ev_flow, r.trace_id)
            if samp is None:
                logits, self.state = self._run_compiled(
                    "decode", self._decode_fn, self.params, self.state,
                    tokens, active)
                # np.asarray blocks on the result — the span covers the step.
                next_tok = np.argmax(np.asarray(logits)[:, -1], axis=-1)
            else:
                sampled, self.state = self._run_compiled(
                    "decode_sample", self._sample_fn, self.params, self.state,
                    tokens, active, *samp)
                next_tok = np.asarray(sampled)
                self.metrics.sampled_tokens += len(reqs)
            tr.end(self._ev_decode)
            dt_dec = time.monotonic() - t_dec
            self.metrics.decode_time_s += dt_dec
            # Decode rows: all slots execute (padding rows included) —
            # tokens counts only the active requests' commits.
            self.mfu.note("decode", tokens=len(reqs), rows=self.slots,
                          time_s=dt_dec)
            for r in reqs:
                self._record_token(r, int(next_tok[r.slot]))
            self.metrics.decode_steps += 1
            self.metrics.decode_tokens += len(reqs)
        self.metrics.peak_blocks_in_use = max(
            self.metrics.peak_blocks_in_use, self.alloc.in_use)
        self.metrics.occupancy_sum += self.alloc.occupancy()
        self.metrics.occupancy_samples += 1
        tr.counter(self._ev_kv_in_use, self.alloc.in_use)
        tr.counter(self._ev_kv_reserved, self.alloc.reserved)
        tr.end(self._ev_tick)
        return True

    def run(self, max_ticks: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Drive the loop until the queue and all slots drain."""
        self._t0 = time.monotonic()
        ticks = 0
        while self.scheduler.has_work:
            if max_ticks is not None and ticks >= max_ticks:
                break
            if not self.tick():
                break
            ticks += 1
        self.metrics.elapsed_s += time.monotonic() - self._t0
        return self.results
