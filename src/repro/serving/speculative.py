"""Self-speculative drafting: prompt-lookup / n-gram proposal.

Speculative decoding is the serving stack's answer to the paper's core
diagnosis — utilization, not peak compute, is what a decode loop loses.
Each decode tick runs every hot matmul as an M=slots GEMV; the drafter
proposes up to K likely next tokens per request, and one batched
``paged_verify_step`` scores all of them at M = slots * (K + 1) — K
sequential starved ticks folded into one well-fed GEMM (README
§Speculative maps this onto the paper's output buffering / input
pre-fetching).

The drafter here is deliberately *model-free*: prompt lookup (n-gram
matching over the request's own token history).  No second model means no
extra weights, no extra compile, and a drafter cheap enough for the CPU CI
host — while still capturing the regime speculative decoding wins in
(repetitive continuations: code, structured text, copied spans).  Greedy
verification makes the output token-identical to non-speculative decoding
whatever the drafter proposes; a bad draft only costs the wasted columns of
one GEMM.

Verification contract under sampling (models/model.py
``paged_verify_sample_step``): the n-gram drafter is a deterministic
point-mass proposal, so stochastic rejection sampling reduces to accepting
draft token ``d_j`` with probability ``p̃(d_j)`` — the model's
temperature/top-k/top-p-adjusted probability of the drafted token — drawn
against a per-(seed, position) uniform.  On first rejection the replacement
token resamples from ``p̃`` with the rejected draft token masked out, which
makes every emitted position exactly ``p̃``-distributed: the same law a
non-speculative sampled decode of that request would produce (though not
the same draw, since the uniforms are consumed in a different pattern).
Greedy requests (``temperature <= 0``) degenerate to the argmax accept
rule above — token-identical to ``paged_verify_step``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs.

    k           — max drafted tokens per request per tick (the verify GEMM
                  covers k + 1 positions worst-case).
    ngram_max   — longest history suffix the drafter tries to match.
    ngram_min   — shortest suffix worth matching; below this, proposals are
                  noise and every miss wastes a verify column.
    corpus_size — recently *committed* streams (prompt + generated tokens of
                  finished requests) the drafter may also match against, most
                  recent first; 0 keeps drafting strictly per-request.
                  Greedy decoding is deterministic, so repeat/templated
                  traffic — regeneration storms, shared templates, the same
                  workloads prefix caching targets — re-generates streams the
                  corpus already holds, and lookups there draft the *true*
                  continuation (acceptance ~1).
    """

    k: int = 4
    ngram_max: int = 3
    ngram_min: int = 2
    corpus_size: int = 8

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"speculative k must be >= 1, got {self.k}")
        if not 1 <= self.ngram_min <= self.ngram_max:
            raise ValueError(
                f"need 1 <= ngram_min <= ngram_max, got "
                f"{self.ngram_min}..{self.ngram_max}")
        if self.corpus_size < 0:
            raise ValueError(f"corpus_size must be >= 0, got {self.corpus_size}")


def coerce_spec(value: Union[None, bool, int, SpecConfig]) -> Optional[SpecConfig]:
    """Engine(speculative=...) sugar: False/None -> off, True -> defaults,
    int -> draft length K, SpecConfig -> itself."""
    if value is None or value is False:
        return None
    if value is True:
        return SpecConfig()
    if isinstance(value, int):
        return SpecConfig(k=value)
    if isinstance(value, SpecConfig):
        return value
    raise TypeError(f"speculative must be bool, int or SpecConfig, "
                    f"got {type(value).__name__}")


class NgramDrafter:
    """Prompt-lookup drafter: propose the continuation of the most recent
    earlier occurrence of the history's suffix n-gram — in the request's own
    token history first, then in the engine's recent-stream corpus.

    Pure host-side numpy over int32 token ids; deterministic — the same
    history and corpus always draft the same tokens, so speculative-on runs
    are reproducible (and whatever is drafted, greedy verification keeps the
    committed tokens exact).
    """

    def __init__(self, config: SpecConfig):
        self.config = config
        self._corpus: list = []            # most recent last
        # Lookup economics (repro.obs): how often drafting was attempted,
        # how often it proposed anything, and how many tokens it proposed.
        self.draft_calls = 0
        self.draft_hits = 0
        self.drafted_tokens = 0

    @property
    def hit_rate(self) -> float:
        """Fraction of draft() calls that proposed at least one token."""
        return self.draft_hits / self.draft_calls if self.draft_calls else 0.0

    def remember(self, stream: np.ndarray) -> None:
        """Retain a committed stream (prompt + generated tokens of a
        finished request) for cross-request lookup."""
        if self.config.corpus_size < 1:
            return
        self._corpus.append(np.asarray(stream, np.int32))
        if len(self._corpus) > self.config.corpus_size:
            del self._corpus[0]

    @staticmethod
    def _lookup(hay: np.ndarray, suffix: np.ndarray, k: int,
                exclude_tail: bool) -> Optional[np.ndarray]:
        """Continuation after the most recent occurrence of `suffix` in
        `hay` (None if absent).  ``exclude_tail`` drops the trivial
        self-match of a history against its own suffix by requiring at
        least one continuation token."""
        n = len(suffix)
        end = len(hay) - 1 if exclude_tail else len(hay)
        if end < n:
            return None
        windows = np.lib.stride_tricks.sliding_window_view(hay[:end], n)
        hits = np.nonzero((windows == suffix).all(axis=1))[0]
        if len(hits) == 0:
            return None
        start = int(hits[-1]) + n
        proposal = hay[start:start + k]
        return proposal if len(proposal) else None

    def draft(self, context: np.ndarray, k: Optional[int] = None) -> np.ndarray:
        """Propose up to k tokens following `context` (1-D int32 history:
        prompt + generated so far).  Returns a possibly-empty (d,) array,
        d <= k; empty means "no match — decode normally this tick".

        Longer suffix matches win over shorter; at equal length the
        request's own history wins over the corpus, and more recent corpus
        streams over older ones."""
        cfg = self.config
        k = cfg.k if k is None else min(k, cfg.k)
        context = np.asarray(context, np.int32)
        L = len(context)
        self.draft_calls += 1
        if k < 1 or L < 1:
            return np.empty((0,), np.int32)
        for n in range(min(cfg.ngram_max, L), cfg.ngram_min - 1, -1):
            suffix = context[L - n:]
            found = self._lookup(context, suffix, k, exclude_tail=True)
            if found is None:
                for stream in reversed(self._corpus):
                    found = self._lookup(stream, suffix, k, exclude_tail=False)
                    if found is not None:
                        break
            if found is not None:
                self.draft_hits += 1
                self.drafted_tokens += len(found)
                return np.asarray(found, np.int32)
        return np.empty((0,), np.int32)


def verify_buckets(k: int) -> list:
    """Verify-step token widths (S = drafts + 1) the engine pre-compiles:
    power-of-two draft lengths up to k, plus k itself — the same
    finite-bucket trick as prefill chunks, so every verify shape the server
    can ever dispatch is AOT-compiled during warmup."""
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    widths = set()
    d = 1
    while d < k:
        widths.add(d + 1)
        d *= 2
    widths.add(k + 1)
    return sorted(widths)


def bucket_for(draft_len: int, k: int) -> int:
    """Smallest pre-compiled verify width covering draft_len drafts."""
    for s in verify_buckets(k):
        if s >= draft_len + 1:
            return s
    raise ValueError(f"draft of {draft_len} exceeds k={k}")
