"""Chunked-prefill planning: power-of-two chunk schedules.

A prompt of length L is processed in chunks drawn from the bucket set
{C, C/2, ..., 2, 1} (C = the engine's max chunk), largest-first, so every
chunk is *exact* — no padding tokens, no masked positions, and recurrent
(SSM/xLSTM) states advance by precisely the real tokens.  The bucket set is
finite and known ahead of time, which is what makes the engine's
configuration-pre-loading analogue work: every chunk shape the server can
ever see is AOT-compiled during warmup, before traffic.
"""

from __future__ import annotations

from typing import List


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def chunk_buckets(max_chunk: int) -> List[int]:
    """Every chunk size the planner can emit, descending: C, C/2, ..., 1."""
    if max_chunk < 1:
        raise ValueError(f"max_chunk must be >= 1, got {max_chunk}")
    c = _pow2_floor(max_chunk)
    out = []
    while c >= 1:
        out.append(c)
        c //= 2
    return out


def plan_chunks(prompt_len: int, max_chunk: int) -> List[int]:
    """Chunk schedule for one prompt: greedy largest power-of-two <= remaining.

    sum(plan) == prompt_len exactly, every entry is a bucket size, and the
    schedule length is O(prompt_len / max_chunk + log2(max_chunk)).
    """
    if prompt_len < 0:
        raise ValueError(f"prompt_len must be >= 0, got {prompt_len}")
    cap = _pow2_floor(max_chunk)
    plan, rest = [], prompt_len
    while rest:
        c = min(cap, _pow2_floor(rest))
        plan.append(c)
        rest -= c
    return plan


def next_chunk(remaining: int, max_chunk: int) -> int:
    """First entry of plan_chunks(remaining, max_chunk) (0 when done)."""
    if remaining <= 0:
        return 0
    return min(_pow2_floor(max_chunk), _pow2_floor(remaining))
