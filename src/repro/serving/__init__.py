"""Serving engine: request scheduler + paged KV cache + chunked prefill.

The three components map the paper's utilization mechanisms onto the
request path (see EXPERIMENTS.md §Serving):

  configuration pre-loading  -> Engine.warmup(): autotune + AOT-compile the
                                decode step and every prefill chunk bucket
                                before traffic
  input pre-fetch / output   -> chunked prefill: C prompt tokens per step,
  buffering                     interleaved with decode batches
  strided memory access      -> paged KV cache: block pool + per-request
                                block tables

Only ``kv_cache`` is imported eagerly (models/attention.py depends on it);
the engine/scheduler live behind a lazy ``__getattr__`` so the model layer
never pulls in its own callers.
"""

from repro.serving.kv_cache import (
    BlockAllocator,
    BlockTables,
    NULL_BLOCK,
    PagedKVCache,
    blocks_for,
    copy_blocks,
    default_pool_blocks,
    fork_blocks,
    gather_kv,
    init_paged_kv,
    pool_bytes,
    quantize_kv_tokens,
    write_kv,
)

# The eager kv_cache re-exports plus the lazy table below; pyflakes reads
# re-exports off __all__ (bare pyflakes has no noqa support).
__all__ = [
    "BlockAllocator",
    "BlockTables",
    "NULL_BLOCK",
    "PagedKVCache",
    "blocks_for",
    "copy_blocks",
    "default_pool_blocks",
    "fork_blocks",
    "gather_kv",
    "init_paged_kv",
    "pool_bytes",
    "quantize_kv_tokens",
    "write_kv",
]

_LAZY = {
    "Engine": ("repro.serving.engine", "Engine"),
    "EngineMetrics": ("repro.serving.engine", "EngineMetrics"),
    "GREEDY": ("repro.serving.request", "GREEDY"),
    "NgramDrafter": ("repro.serving.speculative", "NgramDrafter"),
    "PRIORITIES": ("repro.serving.request", "PRIORITIES"),
    "Request": ("repro.serving.scheduler", "Request"),
    "RequestMetrics": ("repro.serving.engine", "RequestMetrics"),
    "RequestSpec": ("repro.serving.request", "RequestSpec"),
    "SamplingParams": ("repro.serving.request", "SamplingParams"),
    "Scheduler": ("repro.serving.scheduler", "Scheduler"),
    "SpecConfig": ("repro.serving.speculative", "SpecConfig"),
    "as_spec": ("repro.serving.request", "as_spec"),
    "priority_rank": ("repro.serving.request", "priority_rank"),
    "plan_chunks": ("repro.serving.prefill", "plan_chunks"),
    "chunk_buckets": ("repro.serving.prefill", "chunk_buckets"),
    "percentile": ("repro.serving.engine", "percentile"),
    "verify_buckets": ("repro.serving.speculative", "verify_buckets"),
}


__all__ += sorted(_LAZY)


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        module, attr = _LAZY[name]
        return getattr(importlib.import_module(module), attr)
    raise AttributeError(f"module 'repro.serving' has no attribute {name!r}")
