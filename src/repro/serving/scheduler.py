"""Request scheduler: admission queue, slot assignment, continuous batching.

The scheduler is pure host-side policy — it never touches device arrays.
Each tick the engine asks for one action:

  ("prefill", request, chunk_len)  — advance one request's prompt by one
                                     exact power-of-two chunk
  ("decode", [requests])           — one decode step for every slot in the
                                     DECODE phase
  None                             — nothing runnable (queue empty or all
                                     admitted work blocked)

Prefill chunks and decode batches interleave round-robin: a slot mid-prefill
never starves the decoding slots and vice versa (the serving analogue of
overlapping input pre-fetch with compute).  Admission is gated by the
caller-supplied reservation check, so a request only occupies a slot when
the KV block pool can cover its worst case — backpressure lands in the
queue, not mid-flight.

Admission is class-aware: one FIFO deque per priority class
(repro.serving.request.PRIORITIES, best-first), drained strictly by class
rank.  Within a class, FIFO order is preserved and a blocked head still
blocks everything behind it — including lower classes, so a batch request
can never leapfrog an interactive one that is merely waiting on KV blocks
(which would hand the blocks to the wrong class).  ``preempt`` returns a
decoding victim to the *front* of its class queue with its progress intact;
the engine swaps its KV blocks to host memory and restores them when the
victim re-admits (phase goes straight back to DECODE, no re-prefill).
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.prefill import next_chunk
from repro.serving.request import (
    GREEDY,
    PRIORITIES,
    RequestSpec,
    SamplingParams,
    as_spec,
    priority_rank,
)


class Phase(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (L,) int32
    max_new: int
    eos_token: Optional[int] = None
    # -- filled in by the scheduler/engine --
    phase: Phase = Phase.QUEUED
    slot: int = -1
    trace_id: int = -1                 # distributed-trace flow id: minted
                                       # by the router (cluster-wide) or the
                                       # engine (pid-namespaced) at submit
    prefilled: int = 0                 # prompt tokens already in the cache
    cached_tokens: int = 0             # prompt tokens covered by a shared
                                       # KV prefix at admission (prefill
                                       # starts from here, not zero)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submit_step: int = 0
    first_token_step: Optional[int] = None
    finish_step: Optional[int] = None
    # -- speculative-decoding accounting (engine's spec tick path) --
    spec_drafted: int = 0               # draft tokens proposed over lifetime
    spec_accepted: int = 0              # draft tokens verification accepted
    # -- multi-tenant scheduling (RequestSpec-carried) --
    sampling: SamplingParams = GREEDY
    sample_seed: int = 0               # resolved: spec seed, else rid
    priority: str = PRIORITIES[0]
    tenant: str = "default"
    preemptions: int = 0               # times this request was swapped out
    swapped: bool = False              # in queue with KV parked on the host

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def remaining(self) -> int:
        """Tokens this request may still emit."""
        return self.max_new - len(self.out_tokens)

    @property
    def context(self) -> np.ndarray:
        """Full committed token history (prompt + generated) — what the
        self-speculative drafter matches n-grams over."""
        if not self.out_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out_tokens, np.int32)])

    @property
    def length(self) -> int:
        """Tokens currently held in the slot's cache."""
        return self.prefilled + len(self.out_tokens)

    @property
    def done(self) -> bool:
        if len(self.out_tokens) >= self.max_new:
            return True
        return bool(self.out_tokens) and self.out_tokens[-1] == self.eos_token


class Scheduler:
    """Slot-based continuous batching: per-class FIFO admission."""

    def __init__(self, slots: int, *, max_chunk: int = 32,
                 max_queue: Optional[int] = None):
        self.n_slots = slots
        self.max_chunk = max_chunk
        self.max_queue = max_queue
        self.queues: Dict[str, Deque[Request]] = {
            p: deque() for p in PRIORITIES}
        self.slots: List[Optional[Request]] = [None] * slots
        self._next_rid = 0
        self._prefer_prefill = True   # round-robin flip between phases
        self.rejected = 0
        self.admitted_total = 0       # requests that ever reached a slot
        self.peak_queue_depth = 0     # admission-queue high-water mark
        self.preemptions = 0          # decode slots returned to the queue

    @property
    def queue(self) -> List[Request]:
        """Queued requests in admission order (class rank, then FIFO).
        A view, not the storage — per-class deques are in ``queues``; the
        property keeps every ``len(scheduler.queue)`` / ``queue[0]``
        consumer (engine gauges, obs sources, tests) working unchanged."""
        out: List[Request] = []
        for p in PRIORITIES:
            out.extend(self.queues[p])
        return out

    # -- admission -----------------------------------------------------------

    def submit(self, request, max_new: Optional[int] = None, *,
               eos_token: Optional[int] = None,
               step: int = 0) -> Optional[Request]:
        """Enqueue a request (``RequestSpec`` or the legacy
        ``(prompt, max_new)`` form); returns None when the admission queue
        is full."""
        spec = as_spec(request, max_new, eos_token=eos_token)
        depth = sum(len(q) for q in self.queues.values())
        if self.max_queue is not None and depth >= self.max_queue:
            self.rejected += 1
            return None
        rid = self._next_rid
        seed = spec.sampling.seed if spec.sampling.seed is not None else rid
        req = Request(
            rid=rid, prompt=spec.prompt, max_new=spec.max_new,
            eos_token=spec.eos_token, submit_step=step,
            sampling=spec.sampling, sample_seed=int(seed),
            priority=spec.priority, tenant=spec.tenant,
        )
        self._next_rid += 1
        self.queues[spec.priority].append(req)
        if depth + 1 > self.peak_queue_depth:
            self.peak_queue_depth = depth + 1
        return req

    def next_queued(self) -> Optional[Request]:
        """The request the next free slot would admit (head of the best
        non-empty class queue), or None when nothing is queued."""
        for p in PRIORITIES:
            if self.queues[p]:
                return self.queues[p][0]
        return None

    def admit(
        self, can_admit: Callable[[Request], bool]
    ) -> List[Tuple[int, Request]]:
        """Move queued requests into free slots while `can_admit` (the
        engine's block-reservation check) allows, best class first; within
        a class FIFO order is preserved and a blocked head blocks
        everything behind it — including lower classes, so blocks freed by
        finishing work always go to the most urgent waiter (no starvation
        of large requests, no class inversion)."""
        admitted = []
        for slot in range(self.n_slots):
            if self.slots[slot] is not None:
                continue
            head = self.next_queued()
            if head is None or not can_admit(head):
                break
            req = self.queues[head.priority].popleft()
            if req.swapped:
                # Preempted victim re-admitting: its cache contents are
                # restored verbatim by the engine, so it resumes decoding —
                # prefilled/out_tokens progress survives the round trip.
                req.slot, req.phase = slot, Phase.DECODE
            else:
                # Start-from-cached-prefix: the engine's admission check
                # may have found a shared KV prefix for this prompt
                # (req.cached_tokens); prefill then covers only the
                # uncached suffix.
                req.slot, req.phase = slot, Phase.PREFILL
                req.prefilled = req.cached_tokens
                self.admitted_total += 1
            self.slots[slot] = req
            admitted.append((slot, req))
        return admitted

    def preempt(self, req: Request) -> int:
        """Evict a decoding request back to the *front* of its class queue
        (it has strict FIFO seniority over everything queued behind it);
        the engine owns the KV swap-out that makes this safe.  Returns the
        freed slot."""
        slot = req.slot
        assert self.slots[slot] is req and req.phase is Phase.DECODE
        self.slots[slot] = None
        req.slot = -1
        req.phase = Phase.QUEUED
        req.preemptions += 1
        req.swapped = True
        self.queues[req.priority].appendleft(req)
        self.preemptions += 1
        return slot

    # -- tick policy ---------------------------------------------------------

    def prefilling(self) -> List[Request]:
        return [r for r in self.slots if r is not None and r.phase is Phase.PREFILL]

    def decoding(self) -> List[Request]:
        return [r for r in self.slots if r is not None and r.phase is Phase.DECODE]

    @property
    def has_work(self) -> bool:
        return (any(self.queues.values())
                or any(r is not None for r in self.slots))

    def next_action(self):
        pre, dec = self.prefilling(), self.decoding()
        if pre and (self._prefer_prefill or not dec):
            self._prefer_prefill = False
            req = pre[0]
            chunk = next_chunk(req.prompt_len - req.prefilled, self.max_chunk)
            return ("prefill", req, chunk)
        # pre exhausted (the branch above runs whenever dec is empty)
        self._prefer_prefill = True
        if dec:
            return ("decode", dec)
        return None

    # -- bookkeeping (engine callbacks) --------------------------------------

    def on_prefill(self, req: Request, chunk: int, step: int) -> None:
        req.prefilled += chunk
        if req.prefilled >= req.prompt_len:
            req.phase = Phase.DECODE

    def on_token(self, req: Request, token: int, step: int) -> None:
        if req.first_token_step is None:
            req.first_token_step = step
        req.out_tokens.append(int(token))
        if req.done:
            req.phase = Phase.FINISHED
            req.finish_step = step

    def on_spec(self, req: Request, drafted: int, accepted: int) -> None:
        """Account one speculative verification for this request: `drafted`
        tokens were proposed, `accepted` of them survived verification.
        The committed tokens themselves still flow through on_token — this
        records only the draft economics (engine acceptance-rate metrics)."""
        req.spec_drafted += drafted
        req.spec_accepted += accepted

    def release(self, req: Request) -> int:
        """Detach a finished request from its slot; returns the slot."""
        slot = req.slot
        assert self.slots[slot] is req
        self.slots[slot] = None
        req.slot = -1
        return slot
