"""RequestSpec: the single request-description type for every submit surface.

Before this module each submit signature grew its own keyword args —
``Engine.submit(prompt, max_new, eos_token=...)``,
``Scheduler.submit(prompt, max_new, eos_token=..., step=...)``, and
``Router.submit(prompt, max_new)`` (which could not forward ``eos_token``
to replicas at all).  Multi-tenant scheduling adds priority class, tenant
id, sampling params, and a PRNG seed; accreting those onto three divergent
signatures is how APIs rot.  Instead every surface accepts one frozen
``RequestSpec`` and the legacy positional ``(prompt, max_new, **kw)`` form
funnels through a single shim, :func:`as_spec`, which owns the one
deprecation-warning path.

Design rules:

  * ``RequestSpec`` is *description*, not state: frozen, no mutable
    progress fields (those live on ``serving.scheduler.Request`` /
    ``cluster.replica.ClusterRequest``).  The prompt is normalized to a
    read-only int32 ndarray at construction so every consumer downstream
    (block math, prefix hashing, device upload) sees one dtype.
  * ``SamplingParams`` defaults to greedy (``temperature=0``) so a default
    spec reproduces today's argmax paths token-for-token — the engine
    routes all-greedy batches through the *same compiled steps* as before.
  * ``seed=None`` means "derive from the request id": streams stay
    reproducible run-to-run without forcing callers to invent seeds.
  * Priority classes are a fixed, ordered vocabulary (``PRIORITIES``,
    best-first).  The scheduler admits strictly by class rank and the
    router sheds batch traffic first; free-form class strings would make
    both comparisons meaningless.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import numpy as np

__all__ = ["GREEDY", "PRIORITIES", "RequestSpec", "SamplingParams",
           "as_spec", "priority_rank"]

# Admission order, best-first: rank 0 preempts rank 1, never vice versa.
PRIORITIES: Tuple[str, ...] = ("interactive", "batch")
_RANK = {p: i for i, p in enumerate(PRIORITIES)}


def priority_rank(priority: str) -> int:
    """Smaller = more urgent.  Raises on unknown class names (a typo'd
    class silently treated as batch would be a debugging tarpit)."""
    try:
        return _RANK[priority]
    except KeyError:
        raise ValueError(
            f"unknown priority class {priority!r}; expected one of "
            f"{PRIORITIES}") from None


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Token-sampling knobs.  ``temperature <= 0`` selects greedy argmax
    (exactly today's decode paths); ``top_k=0`` / ``top_p=1.0`` disable
    the respective truncations.  ``seed=None`` derives the PRNG stream
    from the request id at submit time."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None

    def __post_init__(self):
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


@dataclasses.dataclass(frozen=True, eq=False)
class RequestSpec:
    """Immutable description of one generation request, accepted by
    ``Engine.submit``, ``Scheduler.submit``, and ``Router.submit``."""

    prompt: np.ndarray
    max_new: int
    eos_token: Optional[int] = None
    sampling: SamplingParams = GREEDY
    priority: str = "interactive"
    tenant: str = "default"
    trace_id: Optional[int] = None

    def __post_init__(self):
        arr = np.ascontiguousarray(np.asarray(self.prompt, np.int32).ravel())
        arr.flags.writeable = False
        object.__setattr__(self, "prompt", arr)
        if arr.size == 0:
            raise ValueError("empty prompt")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")
        priority_rank(self.priority)          # validate the class name
        if not isinstance(self.sampling, SamplingParams):
            raise TypeError("sampling must be a SamplingParams, got "
                            f"{type(self.sampling).__name__}")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


def as_spec(request, max_new: Optional[int] = None, *,
            eos_token: Optional[int] = None,
            trace_id: Optional[int] = None,
            warn: bool = True) -> RequestSpec:
    """Normalize a submit argument to a ``RequestSpec``.

    The ONE legacy-shim path: a bare token array (plus ``max_new`` /
    ``eos_token`` keywords) builds a default greedy spec and emits the
    deprecation warning; an actual ``RequestSpec`` passes through
    untouched (extra keywords then must not conflict with it).
    """
    if isinstance(request, RequestSpec):
        if max_new is not None and max_new != request.max_new:
            raise TypeError("pass max_new inside the RequestSpec, not "
                            "alongside it")
        if eos_token is not None and eos_token != request.eos_token:
            raise TypeError("pass eos_token inside the RequestSpec, not "
                            "alongside it")
        if trace_id is not None and request.trace_id is None:
            return dataclasses.replace(request, trace_id=trace_id)
        return request
    if max_new is None:
        raise TypeError("legacy submit(prompt, max_new) form requires "
                        "max_new")
    if warn:
        warnings.warn(
            "submit(prompt, max_new, ...) is deprecated; pass a "
            "repro.serving.RequestSpec instead",
            DeprecationWarning, stacklevel=3)
    return RequestSpec(prompt=request, max_new=int(max_new),
                       eos_token=eos_token, trace_id=trace_id)
