"""Checkpointing: atomic, async, sharding-agnostic.

Contract for fault tolerance and elastic scaling:
  * atomic commit — writes go to `step_N.tmp/`, fsync'd, then renamed to
    `step_N/`; a crashed writer never corrupts the latest checkpoint;
  * logical arrays — leaves are stored unsharded (np.asarray gathers), so a
    restart may resume on a *different* mesh shape (elastic re-mesh): the
    restorer device_puts each leaf with the new target sharding;
  * async — AsyncCheckpointer snapshots to host then writes in a background
    thread, overlapping with training (output-buffering at job scale);
  * GC — keep_last prunes old steps.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, step: int, tree: Any, *, keep_last: int = 3) -> str:
    """Blocking save.  Returns the committed directory."""
    leaves, treedef = _flatten(tree)
    tmp = os.path.join(path, f"step_{step}.tmp")
    final = os.path.join(path, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    arrs = {}
    dtypes = {}
    for i, leaf in enumerate(leaves):
        a = np.asarray(leaf)          # gathers sharded arrays to host
        if a.dtype == jax.numpy.bfloat16:
            dtypes[str(i)] = "bfloat16"
            a = a.astype(np.float32)  # npz has no bf16; restore re-casts
        arrs[str(i)] = a
    np.savez(os.path.join(tmp, "arrays.npz"), **arrs)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(
            {"step": step, "num_leaves": len(leaves), "bf16_leaves": dtypes},
            f,
        )
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)            # atomic commit
    _gc(path, keep_last)
    return final


def _gc(path: str, keep_last: int) -> None:
    steps = sorted(latest_steps(path))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(path, f"step_{s}"), ignore_errors=True)


def latest_steps(path: str):
    if not os.path.isdir(path):
        return []
    out = []
    for d in os.listdir(path):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(path, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
    return out


def latest_step(path: str) -> Optional[int]:
    steps = latest_steps(path)
    return max(steps) if steps else None


def restore_checkpoint(path: str, step: int, template: Any, shardings: Any = None):
    """Restore into `template`'s structure; device_put with `shardings` if
    given (supports restoring onto a different mesh: elastic re-mesh)."""
    d = os.path.join(path, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves, treedef = _flatten(template)
    assert manifest["num_leaves"] == len(leaves), "checkpoint/template mismatch"
    bf16 = set(manifest.get("bf16_leaves", {}))
    out = []
    for i, leaf in enumerate(leaves):
        a = data[str(i)]
        if str(i) in bf16:
            a = a.astype(jax.numpy.bfloat16)
        out.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a background thread."""

    def __init__(self, path: str, keep_last: int = 3):
        self.path = path
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now

        def work():
            try:
                save_checkpoint(self.path, step, host_tree, keep_last=self.keep_last)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
