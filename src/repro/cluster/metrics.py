"""Cluster-wide metric aggregation.

One struct answering the system-level questions a single ``EngineMetrics``
cannot: tail TTFT across every replica *including router queue wait*,
per-replica occupancy (is the load balancer actually balancing?), prefix
cache effectiveness, and the shed rate the backpressure policy produced.
Percentiles reuse ``repro.obs.percentile`` (the shared nearest-rank
helper) so per-engine and cluster-wide tails are computed with one
definition; ``slo_snapshot`` feeds the merged result into the SLO monitor
(obs/slo.py).

Aggregation is histogram-native (repro.obs.hist): each engine's streaming
TTFT/rate sketches merge in O(replicas x buckets), so cluster tails stay
cheap and exact-enough (within Histogram.rel_error) even when engines run
with a capped request log.  While every engine's raw log is complete, the
engine-TTFT percentiles are computed exactly from the concatenated lists —
merged-histogram and raw-list answers agree to within the bucket width
(tests/test_cluster.py pins this).  Per-phase utilization/MFU meters fold
the same way (repro.obs.mfu.MfuMeter.merged).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.obs import Histogram, MfuMeter, percentile


@dataclasses.dataclass
class ClusterMetrics:
    replicas: int = 0
    requests: int = 0             # finished
    offered: int = 0              # submitted to the router (incl. shed)
    shed: int = 0
    shed_by_class: Dict[str, int] = dataclasses.field(default_factory=dict)
    preemptions: int = 0          # KV swap-outs across all engines
    swap_time_s: float = 0.0      # host<->device KV swap wall time
    tenants: Dict[str, dict] = dataclasses.field(default_factory=dict)
    elapsed_s: float = 0.0        # caller-timed serving window
    decode_tokens: int = 0
    prefill_tokens: int = 0
    ttft_mean_s: float = 0.0      # router wait + engine TTFT
    ttft_p50_s: float = 0.0
    ttft_p95_s: float = 0.0
    req_tok_s_p50: float = 0.0    # per-request decode rate percentiles
    req_tok_s_p95: float = 0.0
    per_replica_requests: List[int] = dataclasses.field(default_factory=list)
    per_replica_occupancy: List[float] = dataclasses.field(default_factory=list)
    prefix_lookups: int = 0
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0
    # Merged streaming sketches across all engines (engine-side TTFT — the
    # handles-based ttft_* fields above additionally include router wait)
    # and the pool-wide per-phase utilization meter.  None until aggregate()
    # fills them.
    ttft_hist: Optional[Histogram] = None
    latency_hist: Optional[Histogram] = None
    tok_s_hist: Optional[Histogram] = None
    mfu: Optional[MfuMeter] = None

    @property
    def shed_rate(self) -> float:
        return self.shed / max(1, self.offered)

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / max(1, self.prefix_lookups)

    @property
    def throughput_tok_s(self) -> float:
        """Generated tokens over the serving window — the system number a
        capacity plan cares about (per-engine decode-tick throughput lives
        in each EngineMetrics)."""
        return self.decode_tokens / self.elapsed_s if self.elapsed_s else 0.0

    def summary(self) -> str:
        occ = "/".join(f"{o:.0%}" for o in self.per_replica_occupancy)
        out = (
            f"replicas={self.replicas} requests={self.requests}"
            f"/{self.offered} shed={self.shed} ({self.shed_rate:.0%}) "
            f"decode={self.decode_tokens} tok "
            f"({self.throughput_tok_s:.1f} tok/s over {self.elapsed_s:.2f}s) "
            f"ttft p50={self.ttft_p50_s * 1e3:.0f}ms "
            f"p95={self.ttft_p95_s * 1e3:.0f}ms "
            f"req_tok_s p50={self.req_tok_s_p50:.1f} "
            f"p95={self.req_tok_s_p95:.1f} "
            f"occupancy=[{occ}] "
            f"balance={self.per_replica_requests}"
        )
        if self.prefix_lookups:
            out += (f" prefix_hit_rate={self.prefix_hit_rate:.0%} "
                    f"({self.prefix_hit_tokens} tok reused)")
        if self.preemptions:
            out += (f" preemptions={self.preemptions} "
                    f"(swap {self.swap_time_s * 1e3:.0f}ms)")
        if self.tenants:
            frag = " ".join(
                f"{t}:{s['admitted']}/{s['offered']}"
                for t, s in sorted(self.tenants.items()))
            out += f" tenants=[{frag}]"
        if self.mfu is not None:
            frag = self.mfu.summary()
            if frag:
                out += " " + frag
        return out

    def as_dict(self) -> dict:
        """JSON-serializable snapshot (launch/serve.py --metrics-json)."""
        return {
            "replicas": self.replicas,
            "requests": self.requests,
            "offered": self.offered,
            "shed": self.shed,
            "shed_rate": self.shed_rate,
            "shed_by_class": dict(self.shed_by_class),
            "preemptions": self.preemptions,
            "swap_time_s": self.swap_time_s,
            "tenants": {t: dict(s) for t, s in self.tenants.items()},
            "elapsed_s": self.elapsed_s,
            "decode_tokens": self.decode_tokens,
            "prefill_tokens": self.prefill_tokens,
            "throughput_tok_s": self.throughput_tok_s,
            "ttft_mean_s": self.ttft_mean_s,
            "ttft_p50_s": self.ttft_p50_s,
            "ttft_p95_s": self.ttft_p95_s,
            "req_tok_s_p50": self.req_tok_s_p50,
            "req_tok_s_p95": self.req_tok_s_p95,
            "per_replica_requests": list(self.per_replica_requests),
            "per_replica_occupancy": list(self.per_replica_occupancy),
            "prefix_lookups": self.prefix_lookups,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "ttft_hist": (self.ttft_hist.to_dict()
                          if self.ttft_hist is not None else None),
            "latency_hist": (self.latency_hist.to_dict()
                             if self.latency_hist is not None else None),
            "tok_s_hist": (self.tok_s_hist.to_dict()
                           if self.tok_s_hist is not None else None),
            "mfu": self.mfu.as_dict() if self.mfu is not None else None,
        }


def aggregate(pool, router=None, *, elapsed_s: float = 0.0,
              handles: Optional[list] = None) -> ClusterMetrics:
    """Fold a pool (and optionally its router / resolved handles) into one
    ClusterMetrics.  With handles, TTFT includes router + inbox wait; without
    (e.g. driving engines directly), engine-side TTFT is used."""
    engines = pool.engines
    m = ClusterMetrics(replicas=len(engines), elapsed_s=elapsed_s)
    per_req, dropped = [], 0
    m.ttft_hist, m.tok_s_hist = Histogram(), Histogram()
    m.latency_hist = Histogram()
    for e in engines:
        m.decode_tokens += e.metrics.decode_tokens
        m.prefill_tokens += e.metrics.prefill_tokens
        m.prefix_lookups += e.metrics.prefix_lookups
        m.prefix_hits += e.metrics.prefix_hits
        m.prefix_hit_tokens += e.metrics.prefix_hit_tokens
        m.per_replica_requests.append(e.metrics.finished_requests)
        m.per_replica_occupancy.append(e.metrics.mean_occupancy)
        m.preemptions += e.metrics.preemptions
        m.swap_time_s += e.metrics.swap_time_s
        per_req.extend(e.metrics.requests)
        dropped += e.metrics.requests_dropped
        m.ttft_hist.merge(e.metrics.ttft_hist)
        m.latency_hist.merge(e.metrics.latency_hist)
        m.tok_s_hist.merge(e.metrics.tok_s_hist)
    m.mfu = MfuMeter.merged([e.metrics.mfu for e in engines])
    m.requests = len(per_req) + dropped
    # Every request's first token leaves a prefill chunk, so fold those
    # tokens into the generated total alongside decode-step tokens.
    m.decode_tokens += m.requests
    if handles is None and router is not None:
        handles = [h for h in router.handles if h.done.is_set()]
    if handles:
        # Handle timestamps include router/inbox wait — finer than the
        # engine-side sketches, so prefer them when available.
        ttfts = [h.ttft_s for h in handles if h.ttft_s is not None]
        m.ttft_mean_s = sum(ttfts) / len(ttfts) if ttfts else 0.0
        m.ttft_p50_s = percentile(ttfts, 50)
        m.ttft_p95_s = percentile(ttfts, 95)
    elif not dropped:
        # Complete raw logs: exact concatenated-list percentiles.
        ttfts = [r.ttft_s for r in per_req]
        m.ttft_mean_s = sum(ttfts) / len(ttfts) if ttfts else 0.0
        m.ttft_p50_s = percentile(ttfts, 50)
        m.ttft_p95_s = percentile(ttfts, 95)
    else:
        # Capped logs dropped entries: the merged histograms are the source
        # of truth (same nearest-rank semantics, bounded state).
        m.ttft_mean_s = m.ttft_hist.mean
        m.ttft_p50_s = m.ttft_hist.percentile(50)
        m.ttft_p95_s = m.ttft_hist.percentile(95)
    if per_req and not dropped:
        rates = [r.decode_tok_s for r in per_req]
        m.req_tok_s_p50 = percentile(rates, 50)
        m.req_tok_s_p95 = percentile(rates, 95)
    else:
        m.req_tok_s_p50 = m.tok_s_hist.percentile(50)
        m.req_tok_s_p95 = m.tok_s_hist.percentile(95)
    # A request can be shed at the router (in-flight bound) or by an
    # engine-side admission-queue bound after routing; both are refusals.
    engine_shed = sum(1 for h in (handles or []) if h.shed)
    if router is not None:
        m.offered = router.offered
        m.shed = router.shed + engine_shed
        m.shed_by_class = dict(router.shed_by_class)
        m.tenants = router.tenant_stats()
    else:
        m.offered = m.requests + engine_shed
        m.shed = engine_shed
    return m


def slo_snapshot(m: ClusterMetrics) -> dict:
    """ClusterMetrics -> the snapshot dict obs/slo.py::SloMonitor.observe()
    evaluates (same keys as obs.engine_snapshot, so one SLO spec serves
    both the single-engine and cluster paths).  The merged histograms make
    cluster-wide burn equal to the burn of the concatenated per-replica
    request streams."""
    return {
        "ttft": m.ttft_hist,
        "latency": m.latency_hist,
        "tok_s": m.tok_s_hist,
        "shed": m.shed,
        "offered": m.offered,
        "mfu_decode": m.mfu.mfu("decode") if m.mfu is not None else 0.0,
    }
