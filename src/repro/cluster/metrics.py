"""Cluster-wide metric aggregation.

One struct answering the system-level questions a single ``EngineMetrics``
cannot: tail TTFT across every replica *including router queue wait*,
per-replica occupancy (is the load balancer actually balancing?), prefix
cache effectiveness, and the shed rate the backpressure policy produced.
Percentiles reuse ``serving.engine.percentile`` so per-engine and
cluster-wide tails are computed with one definition.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.serving.engine import percentile


@dataclasses.dataclass
class ClusterMetrics:
    replicas: int = 0
    requests: int = 0             # finished
    offered: int = 0              # submitted to the router (incl. shed)
    shed: int = 0
    elapsed_s: float = 0.0        # caller-timed serving window
    decode_tokens: int = 0
    prefill_tokens: int = 0
    ttft_mean_s: float = 0.0      # router wait + engine TTFT
    ttft_p50_s: float = 0.0
    ttft_p95_s: float = 0.0
    req_tok_s_p50: float = 0.0    # per-request decode rate percentiles
    req_tok_s_p95: float = 0.0
    per_replica_requests: List[int] = dataclasses.field(default_factory=list)
    per_replica_occupancy: List[float] = dataclasses.field(default_factory=list)
    prefix_lookups: int = 0
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0

    @property
    def shed_rate(self) -> float:
        return self.shed / max(1, self.offered)

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / max(1, self.prefix_lookups)

    @property
    def throughput_tok_s(self) -> float:
        """Generated tokens over the serving window — the system number a
        capacity plan cares about (per-engine decode-tick throughput lives
        in each EngineMetrics)."""
        return self.decode_tokens / self.elapsed_s if self.elapsed_s else 0.0

    def summary(self) -> str:
        occ = "/".join(f"{o:.0%}" for o in self.per_replica_occupancy)
        out = (
            f"replicas={self.replicas} requests={self.requests}"
            f"/{self.offered} shed={self.shed} ({self.shed_rate:.0%}) "
            f"decode={self.decode_tokens} tok "
            f"({self.throughput_tok_s:.1f} tok/s over {self.elapsed_s:.2f}s) "
            f"ttft p50={self.ttft_p50_s * 1e3:.0f}ms "
            f"p95={self.ttft_p95_s * 1e3:.0f}ms "
            f"req_tok_s p50={self.req_tok_s_p50:.1f} "
            f"p95={self.req_tok_s_p95:.1f} "
            f"occupancy=[{occ}] "
            f"balance={self.per_replica_requests}"
        )
        if self.prefix_lookups:
            out += (f" prefix_hit_rate={self.prefix_hit_rate:.0%} "
                    f"({self.prefix_hit_tokens} tok reused)")
        return out


def aggregate(pool, router=None, *, elapsed_s: float = 0.0,
              handles: Optional[list] = None) -> ClusterMetrics:
    """Fold a pool (and optionally its router / resolved handles) into one
    ClusterMetrics.  With handles, TTFT includes router + inbox wait; without
    (e.g. driving engines directly), engine-side TTFT is used."""
    engines = pool.engines
    m = ClusterMetrics(replicas=len(engines), elapsed_s=elapsed_s)
    per_req = []
    for e in engines:
        m.decode_tokens += e.metrics.decode_tokens
        m.prefill_tokens += e.metrics.prefill_tokens
        m.prefix_lookups += e.metrics.prefix_lookups
        m.prefix_hits += e.metrics.prefix_hits
        m.prefix_hit_tokens += e.metrics.prefix_hit_tokens
        m.per_replica_requests.append(len(e.metrics.requests))
        m.per_replica_occupancy.append(e.metrics.mean_occupancy)
        per_req.extend(e.metrics.requests)
    # Every request's first token leaves a prefill chunk, so fold those
    # tokens into the generated total alongside decode-step tokens.
    m.decode_tokens += len(per_req)
    m.requests = len(per_req)
    if handles is None and router is not None:
        handles = [h for h in router.handles if h.done.is_set()]
    if handles:
        ttfts = [h.ttft_s for h in handles if h.ttft_s is not None]
    else:
        ttfts = [r.ttft_s for r in per_req]
    rates = [r.decode_tok_s for r in per_req]
    m.ttft_mean_s = sum(ttfts) / len(ttfts) if ttfts else 0.0
    m.ttft_p50_s = percentile(ttfts, 50)
    m.ttft_p95_s = percentile(ttfts, 95)
    m.req_tok_s_p50 = percentile(rates, 50)
    m.req_tok_s_p95 = percentile(rates, 95)
    # A request can be shed at the router (in-flight bound) or by an
    # engine-side admission-queue bound after routing; both are refusals.
    engine_shed = sum(1 for h in (handles or []) if h.shed)
    if router is not None:
        m.offered = router.offered
        m.shed = router.shed + engine_shed
    else:
        m.offered = m.requests + engine_shed
        m.shed = engine_shed
    return m
