"""Deterministic serving workloads: seeded arrivals, prompt mixtures,
trace record/replay.

Load tests are only comparable when the load is reproducible, so every
workload here is a pure function of its config (seed included): a
``Trace`` — arrival offsets, token prompts, generation budgets — that can
be saved to JSON, reloaded, and replayed against any submit function (a
bare ``Engine.submit``, a cluster ``Router.submit``) byte-for-byte
identically.  Two canned scenarios cover the cluster benchmarks:

  * ``mixed_traffic`` — Poisson arrivals over a short/long prompt-length
    mixture; the throughput-scaling scenario.
  * ``shared_system_prompt`` — every prompt opens with the same system
    prefix and differs only in a short user suffix; the prefix-cache
    scenario (hit rate and TTFT savings, see benchmarks/cluster_bench.py).
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.serving.request import RequestSpec, SamplingParams


@dataclasses.dataclass(frozen=True)
class TraceItem:
    t: float                      # arrival offset (s) from trace start
    prompt: Tuple[int, ...]       # token ids
    max_new: int
    priority: str = "interactive"  # SLO class (serving.request.PRIORITIES)
    tenant: str = "default"


@dataclasses.dataclass
class Trace:
    items: List[TraceItem]
    meta: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.items)

    @property
    def prompt_tokens(self) -> int:
        return sum(len(it.prompt) for it in self.items)

    @property
    def gen_tokens(self) -> int:
        return sum(it.max_new for it in self.items)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({
                "version": 1,
                "meta": self.meta,
                "items": [
                    {"t": it.t, "prompt": list(it.prompt),
                     "max_new": it.max_new, "priority": it.priority,
                     "tenant": it.tenant}
                    for it in self.items
                ],
            }, f)

    @staticmethod
    def load(path: str) -> "Trace":
        with open(path) as f:
            raw = json.load(f)
        if raw.get("version") != 1:
            raise ValueError(f"unknown trace version {raw.get('version')!r}")
        return Trace(
            items=[TraceItem(t=float(d["t"]),
                             prompt=tuple(int(x) for x in d["prompt"]),
                             max_new=int(d["max_new"]),
                             priority=str(d.get("priority", "interactive")),
                             tenant=str(d.get("tenant", "default")))
                   for d in raw["items"]],
            meta=dict(raw.get("meta", {})),
        )


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Workload spec; ``generate`` is a pure function of this + nothing else.

    ``rate_rps`` is the Poisson arrival rate (exponential inter-arrival
    gaps); ``inf`` front-loads every request at t=0 (a drain test).
    ``mixture`` rows are ``(weight, lo, hi)`` inclusive prompt-length
    ranges; ``shared_prefix`` tokens are prepended to every prompt.
    ``class_mix`` rows are ``(priority, weight)`` SLO-class assignment
    probabilities (empty = all interactive); ``tenants > 1`` spreads
    requests uniformly over synthetic tenant ids ``t0..t{n-1}``.
    """

    n_requests: int = 32
    rate_rps: float = float("inf")
    vocab: int = 256
    mixture: Tuple[Tuple[float, int, int], ...] = ((0.7, 4, 16), (0.3, 16, 48))
    shared_prefix: Tuple[int, ...] = ()
    max_new: Tuple[int, int] = (4, 16)
    seed: int = 0
    class_mix: Tuple[Tuple[str, float], ...] = ()
    tenants: int = 1


def generate(cfg: TrafficConfig) -> Trace:
    """Seeded workload synthesis: same config -> token-identical trace."""
    rng = np.random.default_rng(cfg.seed)
    # Class/tenant labels draw from their own stream so labelling a
    # workload never perturbs the prompt/arrival draws: a labelled trace
    # stays token-identical to its unlabelled twin.
    lrng = np.random.default_rng(cfg.seed + 0x5EED)
    weights = np.asarray([w for w, _, _ in cfg.mixture], np.float64)
    weights = weights / weights.sum()
    cls_names = [c for c, _ in cfg.class_mix]
    cls_w = np.asarray([w for _, w in cfg.class_mix], np.float64)
    if len(cls_names):
        cls_w = cls_w / cls_w.sum()
    items, t = [], 0.0
    for _ in range(cfg.n_requests):
        if np.isfinite(cfg.rate_rps):
            t += float(rng.exponential(1.0 / cfg.rate_rps))
        bucket = int(rng.choice(len(cfg.mixture), p=weights))
        _, lo, hi = cfg.mixture[bucket]
        length = int(rng.integers(lo, hi + 1))
        suffix = rng.integers(0, cfg.vocab, size=length)
        prompt = cfg.shared_prefix + tuple(int(x) for x in suffix)
        max_new = int(rng.integers(cfg.max_new[0], cfg.max_new[1] + 1))
        priority = ("interactive" if not cls_names
                    else cls_names[int(lrng.choice(len(cls_names), p=cls_w))])
        tenant = ("default" if cfg.tenants <= 1
                  else f"t{int(lrng.integers(0, cfg.tenants))}")
        items.append(TraceItem(t=t, prompt=prompt, max_new=max_new,
                               priority=priority, tenant=tenant))
    meta = dataclasses.asdict(cfg)
    meta["shared_prefix_len"] = len(cfg.shared_prefix)
    meta.pop("shared_prefix")            # keep metadata compact
    return Trace(items=items, meta=meta)


# ---------------------------------------------------------------------------
# canned scenarios
# ---------------------------------------------------------------------------


def mixed_traffic(vocab: int, *, n: int = 32, seed: int = 0,
                  rate_rps: float = float("inf"),
                  max_prompt: int = 48, max_new: Tuple[int, int] = (4, 16),
                  class_mix: Optional[Tuple[Tuple[str, float], ...]] = None,
                  tenants: int = 1) -> Trace:
    """Short/long prompt mixture — the throughput-scaling scenario;
    optionally labelled with SLO classes and synthetic tenants (the
    multi-tenant scheduling scenario)."""
    short_hi = max(4, max_prompt // 3)
    return generate(TrafficConfig(
        n_requests=n, rate_rps=rate_rps, vocab=vocab,
        mixture=((0.7, 4, short_hi), (0.3, short_hi, max_prompt)),
        max_new=max_new, seed=seed,
        class_mix=tuple(class_mix) if class_mix else (), tenants=tenants,
    ))


def shared_system_prompt(vocab: int, *, n: int = 16, seed: int = 0,
                         prefix_len: int = 32,
                         suffix: Tuple[int, int] = (2, 8),
                         max_new: Tuple[int, int] = (4, 8),
                         rate_rps: float = float("inf")) -> Trace:
    """Every request opens with one shared system prompt — the prefix-cache
    scenario.  The prefix tokens themselves are drawn from the seed, so the
    whole trace stays a pure function of (vocab, n, seed, ...)."""
    rng = np.random.default_rng(seed)
    prefix = tuple(int(x) for x in rng.integers(0, vocab, size=prefix_len))
    return generate(TrafficConfig(
        n_requests=n, rate_rps=rate_rps, vocab=vocab,
        mixture=((1.0, suffix[0], suffix[1]),),
        shared_prefix=prefix, max_new=max_new,
        seed=seed + 1,                   # distinct stream from the prefix draw
    ))


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------


def replay(trace: Trace, submit: Callable, *,
           speed: Optional[float] = None,
           sampling: Optional[SamplingParams] = None,
           sleep=time.sleep, clock=time.monotonic) -> Tuple[list, int]:
    """Feed a trace through ``submit(spec)`` — any of the three submit
    surfaces (``Engine.submit``, ``Scheduler.submit``, ``Router.submit``)
    accepts the ``RequestSpec`` built per item, which carries the item's
    priority class and tenant (and, optionally, shared ``sampling``
    params for every request).

    ``speed=None`` replays as fast as possible (a drain/throughput test);
    a finite speed replays arrival offsets scaled by it (2.0 = twice real
    time).  ``submit`` returning None counts as shed.  Returns
    ``(accepted_handles, shed_count)``.
    """
    handles, shed = [], 0
    t0 = clock()
    for it in trace.items:
        if speed is not None:
            wait = it.t / speed - (clock() - t0)
            if wait > 0:
                sleep(wait)
        spec = RequestSpec(
            prompt=np.asarray(it.prompt, np.int32), max_new=it.max_new,
            priority=it.priority, tenant=it.tenant,
            sampling=sampling if sampling is not None else SamplingParams())
        h = submit(spec)
        if h is None:
            shed += 1
        else:
            handles.append(h)
    return handles, shed
