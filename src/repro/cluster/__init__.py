"""Multi-replica serving: replica pool + router + prefix cache + traffic.

The cluster layer scales the single-``Engine`` serving stack the same way
the paper scales a single PE: keep every compute unit fed and share the
memory pool.  Five modules:

  replica.py      — N engines, thread-per-replica, device-pinned when
                    ``jax.devices()`` has more than one
  router.py       — bounded admission + shed policy + pure routing
                    policies (round-robin / least-loaded / prefix-affinity)
  prefix_cache.py — radix-tree prompt-prefix cache over the refcounted KV
                    block pool (serving/kv_cache.py)
  traffic.py      — seeded workload generation + trace record/replay
  metrics.py      — cluster-wide aggregation (tail TTFT, occupancy,
                    prefix hit rate, shed rate)

Everything is lazy (mirroring repro.serving): importing ``repro.cluster``
pulls no jax-heavy module until a symbol is touched.
"""

_LAZY = {
    "ClusterMetrics": ("repro.cluster.metrics", "ClusterMetrics"),
    "ClusterRequest": ("repro.cluster.replica", "ClusterRequest"),
    "POLICIES": ("repro.cluster.router", "POLICIES"),
    "PrefixCache": ("repro.cluster.prefix_cache", "PrefixCache"),
    "Replica": ("repro.cluster.replica", "Replica"),
    "ReplicaPool": ("repro.cluster.replica", "ReplicaPool"),
    "ReplicaView": ("repro.cluster.replica", "ReplicaView"),
    "Router": ("repro.cluster.router", "Router"),
    "TenantStats": ("repro.cluster.router", "TenantStats"),
    "Trace": ("repro.cluster.traffic", "Trace"),
    "TraceItem": ("repro.cluster.traffic", "TraceItem"),
    "TrafficConfig": ("repro.cluster.traffic", "TrafficConfig"),
    "aggregate": ("repro.cluster.metrics", "aggregate"),
    "generate": ("repro.cluster.traffic", "generate"),
    "mixed_traffic": ("repro.cluster.traffic", "mixed_traffic"),
    "pick_least_loaded": ("repro.cluster.router", "pick_least_loaded"),
    "pick_prefix_affinity": ("repro.cluster.router", "pick_prefix_affinity"),
    "pick_round_robin": ("repro.cluster.router", "pick_round_robin"),
    "replay": ("repro.cluster.traffic", "replay"),
    "shared_system_prompt": ("repro.cluster.traffic", "shared_system_prompt"),
    "slo_snapshot": ("repro.cluster.metrics", "slo_snapshot"),
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        module, attr = _LAZY[name]
        return getattr(importlib.import_module(module), attr)
    raise AttributeError(f"module 'repro.cluster' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
