"""Cluster front-end: bounded admission, shed policy, pluggable routing.

The router is the cluster's single entry point.  ``submit`` applies
backpressure — a bounded in-flight window; beyond it requests are *shed*
(counted and refused, never silently dropped) — and an async dispatcher
thread moves accepted requests onto replica inboxes under a routing
policy.

Policies are pure functions ``pick(views, prompt, step=, seed=) -> idx``
over plain ``ReplicaView`` snapshots, so they are unit-testable and
deterministic given their inputs:

  * ``round-robin``    — step modulo N; oblivious, perfectly fair.
  * ``least-loaded``   — min (depth, -free KV blocks, idx): queue depth
                         first, then the replica with the most free pool
                         blocks (the KV analogue of picking the bank with
                         the most headroom).
  * ``prefix-affinity``— hash of the prompt's first KV block of tokens
                         picks a home replica, so shared-prefix traffic
                         lands where its prefix is cached (engine-local
                         prefix caches combine with this to act like one
                         cluster-wide cache); falls back to least-loaded
                         when the home replica is overloaded.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import zlib
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.cluster.replica import ClusterRequest, ReplicaPool, ReplicaView
from repro.obs import NULL_TRACER
from repro.serving.request import PRIORITIES, as_spec, priority_rank

# Tokens hashed by prefix-affinity: one engine KV block's worth keeps the
# key aligned with what the prefix cache can actually share.
AFFINITY_TOKENS = 16
# Depth gap beyond which affinity yields to least-loaded (hot-prefix storms
# must not wedge one replica while others idle).
AFFINITY_SLACK = 8


def pick_round_robin(views: List[ReplicaView], prompt, *, step: int,
                     seed: int = 0) -> int:
    return step % len(views)


def pick_least_loaded(views: List[ReplicaView], prompt, *, step: int,
                      seed: int = 0) -> int:
    return min(views, key=lambda v: (v.depth, -v.free_blocks, v.idx)).idx


def pick_prefix_affinity(views: List[ReplicaView], prompt, *, step: int,
                         seed: int = 0) -> int:
    key = np.asarray(prompt[:AFFINITY_TOKENS], np.int64).tobytes()
    home = zlib.crc32(key + seed.to_bytes(8, "little")) % len(views)
    fallback = pick_least_loaded(views, prompt, step=step, seed=seed)
    if views[home].depth > views[fallback].depth + AFFINITY_SLACK:
        return fallback
    return home


POLICIES: Dict[str, Callable] = {
    "round-robin": pick_round_robin,
    "least-loaded": pick_least_loaded,
    "prefix-affinity": pick_prefix_affinity,
}


@dataclasses.dataclass
class TenantStats:
    """Per-tenant admission ledger (offered/admitted/shed counters survive
    the run; in-flight is recomputed live from the handle list)."""

    offered: int = 0
    admitted: int = 0
    shed: int = 0


class Router:
    """Admission queue + dispatcher thread over a ReplicaPool.

    Admission is class- and tenant-aware:

      * ``batch_pending_frac`` shrinks the in-flight window for
        non-interactive classes — batch work sheds at
        ``max_pending * frac`` so a batch flood leaves headroom the
        interactive class can still claim (shed reason ``"window"``).
      * ``tenant_share`` caps any single tenant's in-flight share of the
        window at ``ceil(max_pending * share)`` (shed reason ``"tenant"``)
        so one tenant cannot monopolize admission.

    Dispatch is priority-ordered: accepted requests queue per class and
    the dispatcher always forwards the best class first, so interactive
    work reaches replica inboxes ahead of batch work admitted earlier.
    """

    def __init__(self, pool: ReplicaPool, policy="round-robin", *,
                 max_pending: Optional[int] = None, seed: int = 0,
                 batch_pending_frac: float = 1.0,
                 tenant_share: Optional[float] = None,
                 async_dispatch: bool = True, tracer=None, recorder=None):
        if isinstance(policy, str):
            if policy not in POLICIES:
                raise ValueError(
                    f"unknown policy {policy!r}; known: {sorted(POLICIES)}")
            policy = POLICIES[policy]
        self.pool = pool
        self.policy = policy
        self.max_pending = max_pending     # in-flight bound; None = unbounded
        if not 0.0 < batch_pending_frac <= 1.0:
            raise ValueError(
                f"batch_pending_frac must be in (0, 1], got {batch_pending_frac}")
        if tenant_share is not None and not 0.0 < tenant_share <= 1.0:
            raise ValueError(
                f"tenant_share must be in (0, 1], got {tenant_share}")
        self.batch_pending_frac = batch_pending_frac
        self.tenant_share = tenant_share
        self.seed = seed
        # Distributed request tracing: the router lane mints every accepted
        # request's trace id (= crid, cluster-unique) and starts its flow
        # chain; replicas continue the chain under the same id.  The tracer
        # is written from submit() callers *and* the dispatcher thread, so
        # — unlike the single-writer engine rings — every write here stays
        # under self._lock.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._ev_admit = self.tracer.intern("admit")
        self._ev_route = self.tracer.intern("route")
        self._ev_shed = self.tracer.intern("shed")
        self._ev_flow = self.tracer.intern("req")
        # Anomaly capture (obs/recorder.py): a shed fires a rate-limited
        # incident bundle — the evidence of *why* backpressure hit.
        self.recorder = recorder
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        # Per-class dispatch deques, drained best class first.
        self._queues: Dict[str, Deque[ClusterRequest]] = {
            p: deque() for p in PRIORITIES}
        self._live: List[ClusterRequest] = []
        self.handles: List[ClusterRequest] = []   # every accepted request
        self.offered = 0
        self.shed = 0
        self.shed_by_class: Dict[str, int] = {p: 0 for p in PRIORITIES}
        self.tenants: Dict[str, TenantStats] = {}
        self.dispatched = 0
        self._crid = 0
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        if async_dispatch:
            self._thread = threading.Thread(
                target=self._dispatch_loop, name="router", daemon=True)
            self._thread.start()

    # -- admission -----------------------------------------------------------

    def _in_flight_locked(self) -> int:
        self._live = [h for h in self._live if not h.done.is_set()]
        return len(self._live)

    def _tenant_in_flight_locked(self, tenant: str) -> int:
        # Only meaningful right after _in_flight_locked pruned the list.
        return sum(1 for h in self._live if h.spec.tenant == tenant)

    def _shed_bound_locked(self, priority: str) -> Optional[int]:
        """In-flight window for this class: batch classes see a shrunken
        window so interactive arrivals always find headroom."""
        if self.max_pending is None:
            return None
        if priority_rank(priority) > 0:
            return max(1, int(self.max_pending * self.batch_pending_frac))
        return self.max_pending

    def submit(self, request, max_new: Optional[int] = None, *,
               eos_token: Optional[int] = None) -> Optional[ClusterRequest]:
        """Admit or shed a ``RequestSpec`` (or the legacy ``(prompt,
        max_new)`` form).  Backpressure is an in-flight window: accepted but
        unfinished requests (queued here, queued at a replica, or running)
        count against the class's window; a tenant over its share sheds
        even with window headroom."""
        spec = as_spec(request, max_new, eos_token=eos_token)
        with self._lock:
            self.offered += 1
            stats = self.tenants.setdefault(spec.tenant, TenantStats())
            stats.offered += 1
            in_flight = self._in_flight_locked()
            bound = self._shed_bound_locked(spec.priority)
            reason = None
            if bound is not None and in_flight >= bound:
                reason = "window"
            elif (self.tenant_share is not None
                  and self.max_pending is not None
                  and self._tenant_in_flight_locked(spec.tenant) >= max(
                      1, math.ceil(self.max_pending * self.tenant_share))):
                reason = "tenant"
            if reason is not None:
                self.shed += 1
                self.shed_by_class[spec.priority] += 1
                stats.shed += 1
                self.tracer.instant(self._ev_shed, len(self._live))
                recorder = self.recorder
            else:
                stats.admitted += 1
                h = ClusterRequest(self._crid, spec)
                h.trace_id = h.crid
                self._crid += 1
                self._queues[spec.priority].append(h)
                self._live.append(h)
                self.handles.append(h)
                if self.tracer.enabled:
                    # flows bind to the open slice: chain starts in a tiny
                    # admit slice on the router lane
                    self.tracer.begin(self._ev_admit)
                    self.tracer.flow_start(self._ev_flow, h.trace_id)
                    self.tracer.end(self._ev_admit)
                self._not_empty.notify()
                return h
        # shed path, outside the lock: the recorder snapshots tracers and
        # metric sources, which must not run under the admission lock
        if recorder is not None:
            recorder.trigger("shed", extra={
                "offered": self.offered, "shed": self.shed,
                "max_pending": self.max_pending, "reason": reason,
                "priority": spec.priority, "tenant": spec.tenant})
        return None

    @property
    def shed_rate(self) -> float:
        return self.shed / max(1, self.offered)

    def tenant_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-tenant admission snapshot, live in-flight included."""
        with self._lock:
            self._in_flight_locked()
            return {
                t: {"offered": s.offered, "admitted": s.admitted,
                    "shed": s.shed,
                    "in_flight": self._tenant_in_flight_locked(t)}
                for t, s in sorted(self.tenants.items())}

    # -- dispatch ------------------------------------------------------------

    def _next_locked(self) -> Optional[ClusterRequest]:
        """Pop the head of the best non-empty class queue."""
        for p in PRIORITIES:
            if self._queues[p]:
                return self._queues[p].popleft()
        return None

    def _dispatch_loop(self) -> None:
        while True:
            with self._not_empty:
                while (not any(self._queues.values())) and not self._stop:
                    self._not_empty.wait(0.05)
                h = self._next_locked()
                if h is None:
                    if self._stop:
                        return
                    continue
                step = self.dispatched
                self.dispatched += 1
            # Policy outside the lock: views poll replica state, which may
            # block briefly, and submit() must stay non-blocking.
            idx = self.policy(self.pool.views(), h.prompt,
                              step=step, seed=self.seed)
            self._trace_route(h)
            self.pool.submit_to(idx, h)

    def _trace_route(self, h: ClusterRequest) -> None:
        """Step the request's flow at the routing decision (locked — see
        __init__ on the router tracer's shared-writer discipline)."""
        if self.tracer.enabled:
            with self._lock:
                self.tracer.begin(self._ev_route)
                self.tracer.flow_step(self._ev_flow, h.trace_id)
                self.tracer.end(self._ev_route)

    def dispatch_sync(self) -> None:
        """Drain the admission queue on the caller's thread (the
        deterministic twin of the dispatcher, for run_sync tests)."""
        while True:
            with self._lock:
                h = self._next_locked()
                if h is None:
                    return
                step = self.dispatched
                self.dispatched += 1
            idx = self.policy(self.pool.views(), h.prompt,
                              step=step, seed=self.seed)
            self._trace_route(h)
            self.pool.submit_to(idx, h)

    # -- lifecycle -----------------------------------------------------------

    def drain(self, timeout: float = 120.0) -> None:
        self.pool.drain(list(self.handles), timeout=timeout)

    def close(self) -> None:
        with self._not_empty:
            self._stop = True
            self._not_empty.notify_all()
        if self._thread is not None:
            self._thread.join(5.0)
            self._thread = None
        self.pool.stop()

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
