"""Cluster front-end: bounded admission, shed policy, pluggable routing.

The router is the cluster's single entry point.  ``submit`` applies
backpressure — a bounded in-flight window; beyond it requests are *shed*
(counted and refused, never silently dropped) — and an async dispatcher
thread moves accepted requests onto replica inboxes under a routing
policy.

Policies are pure functions ``pick(views, prompt, step=, seed=) -> idx``
over plain ``ReplicaView`` snapshots, so they are unit-testable and
deterministic given their inputs:

  * ``round-robin``    — step modulo N; oblivious, perfectly fair.
  * ``least-loaded``   — min (depth, -free KV blocks, idx): queue depth
                         first, then the replica with the most free pool
                         blocks (the KV analogue of picking the bank with
                         the most headroom).
  * ``prefix-affinity``— hash of the prompt's first KV block of tokens
                         picks a home replica, so shared-prefix traffic
                         lands where its prefix is cached (engine-local
                         prefix caches combine with this to act like one
                         cluster-wide cache); falls back to least-loaded
                         when the home replica is overloaded.
"""

from __future__ import annotations

import threading
import zlib
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.cluster.replica import ClusterRequest, ReplicaPool, ReplicaView
from repro.obs import NULL_TRACER

# Tokens hashed by prefix-affinity: one engine KV block's worth keeps the
# key aligned with what the prefix cache can actually share.
AFFINITY_TOKENS = 16
# Depth gap beyond which affinity yields to least-loaded (hot-prefix storms
# must not wedge one replica while others idle).
AFFINITY_SLACK = 8


def pick_round_robin(views: List[ReplicaView], prompt, *, step: int,
                     seed: int = 0) -> int:
    return step % len(views)


def pick_least_loaded(views: List[ReplicaView], prompt, *, step: int,
                      seed: int = 0) -> int:
    return min(views, key=lambda v: (v.depth, -v.free_blocks, v.idx)).idx


def pick_prefix_affinity(views: List[ReplicaView], prompt, *, step: int,
                         seed: int = 0) -> int:
    key = np.asarray(prompt[:AFFINITY_TOKENS], np.int64).tobytes()
    home = zlib.crc32(key + seed.to_bytes(8, "little")) % len(views)
    fallback = pick_least_loaded(views, prompt, step=step, seed=seed)
    if views[home].depth > views[fallback].depth + AFFINITY_SLACK:
        return fallback
    return home


POLICIES: Dict[str, Callable] = {
    "round-robin": pick_round_robin,
    "least-loaded": pick_least_loaded,
    "prefix-affinity": pick_prefix_affinity,
}


class Router:
    """Admission queue + dispatcher thread over a ReplicaPool."""

    def __init__(self, pool: ReplicaPool, policy="round-robin", *,
                 max_pending: Optional[int] = None, seed: int = 0,
                 async_dispatch: bool = True, tracer=None, recorder=None):
        if isinstance(policy, str):
            if policy not in POLICIES:
                raise ValueError(
                    f"unknown policy {policy!r}; known: {sorted(POLICIES)}")
            policy = POLICIES[policy]
        self.pool = pool
        self.policy = policy
        self.max_pending = max_pending     # in-flight bound; None = unbounded
        self.seed = seed
        # Distributed request tracing: the router lane mints every accepted
        # request's trace id (= crid, cluster-unique) and starts its flow
        # chain; replicas continue the chain under the same id.  The tracer
        # is written from submit() callers *and* the dispatcher thread, so
        # — unlike the single-writer engine rings — every write here stays
        # under self._lock.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._ev_admit = self.tracer.intern("admit")
        self._ev_route = self.tracer.intern("route")
        self._ev_shed = self.tracer.intern("shed")
        self._ev_flow = self.tracer.intern("req")
        # Anomaly capture (obs/recorder.py): a shed fires a rate-limited
        # incident bundle — the evidence of *why* backpressure hit.
        self.recorder = recorder
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._queue: "deque[ClusterRequest]" = deque()
        self._live: List[ClusterRequest] = []
        self.handles: List[ClusterRequest] = []   # every accepted request
        self.offered = 0
        self.shed = 0
        self.dispatched = 0
        self._crid = 0
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        if async_dispatch:
            self._thread = threading.Thread(
                target=self._dispatch_loop, name="router", daemon=True)
            self._thread.start()

    # -- admission -----------------------------------------------------------

    def _in_flight_locked(self) -> int:
        self._live = [h for h in self._live if not h.done.is_set()]
        return len(self._live)

    def submit(self, prompt, max_new: int) -> Optional[ClusterRequest]:
        """Admit or shed.  Backpressure is an in-flight window: accepted but
        unfinished requests (queued here, queued at a replica, or running)
        count against ``max_pending``; at the bound, new arrivals shed."""
        with self._lock:
            self.offered += 1
            if (self.max_pending is not None
                    and self._in_flight_locked() >= self.max_pending):
                self.shed += 1
                self.tracer.instant(self._ev_shed, len(self._live))
                recorder = self.recorder
            else:
                h = ClusterRequest(self._crid, prompt, max_new)
                h.trace_id = h.crid
                self._crid += 1
                self._queue.append(h)
                self._live.append(h)
                self.handles.append(h)
                if self.tracer.enabled:
                    # flows bind to the open slice: chain starts in a tiny
                    # admit slice on the router lane
                    self.tracer.begin(self._ev_admit)
                    self.tracer.flow_start(self._ev_flow, h.trace_id)
                    self.tracer.end(self._ev_admit)
                self._not_empty.notify()
                return h
        # shed path, outside the lock: the recorder snapshots tracers and
        # metric sources, which must not run under the admission lock
        if recorder is not None:
            recorder.trigger("shed", extra={
                "offered": self.offered, "shed": self.shed,
                "max_pending": self.max_pending})
        return None

    @property
    def shed_rate(self) -> float:
        return self.shed / max(1, self.offered)

    # -- dispatch ------------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while True:
            with self._not_empty:
                while not self._queue and not self._stop:
                    self._not_empty.wait(0.05)
                if self._stop and not self._queue:
                    return
                h = self._queue.popleft()
                step = self.dispatched
                self.dispatched += 1
            # Policy outside the lock: views poll replica state, which may
            # block briefly, and submit() must stay non-blocking.
            idx = self.policy(self.pool.views(), h.prompt,
                              step=step, seed=self.seed)
            self._trace_route(h)
            self.pool.submit_to(idx, h)

    def _trace_route(self, h: ClusterRequest) -> None:
        """Step the request's flow at the routing decision (locked — see
        __init__ on the router tracer's shared-writer discipline)."""
        if self.tracer.enabled:
            with self._lock:
                self.tracer.begin(self._ev_route)
                self.tracer.flow_step(self._ev_flow, h.trace_id)
                self.tracer.end(self._ev_route)

    def dispatch_sync(self) -> None:
        """Drain the admission queue on the caller's thread (the
        deterministic twin of the dispatcher, for run_sync tests)."""
        while True:
            with self._lock:
                if not self._queue:
                    return
                h = self._queue.popleft()
                step = self.dispatched
                self.dispatched += 1
            idx = self.policy(self.pool.views(), h.prompt,
                              step=step, seed=self.seed)
            self._trace_route(h)
            self.pool.submit_to(idx, h)

    # -- lifecycle -----------------------------------------------------------

    def drain(self, timeout: float = 120.0) -> None:
        self.pool.drain(list(self.handles), timeout=timeout)

    def close(self) -> None:
        with self._not_empty:
            self._stop = True
            self._not_empty.notify_all()
        if self._thread is not None:
            self._thread.join(5.0)
            self._thread = None
        self.pool.stop()

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
