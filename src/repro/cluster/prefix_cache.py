"""Radix-tree prompt-prefix cache over the paged KV block pool.

Requests that share a prompt prefix — the shared-system-prompt pattern, or
any repeated prompt — should not re-prefill it: the KV for those tokens is
already sitting in pool blocks written by an earlier request.  This cache
indexes those blocks by their *token content* so a later admission can fork
them (refcount, zero bytes copied; kv_cache.fork_blocks) and prefill only
the uncached suffix.  It is the request-level face of the same idea as the
paper's multi-banked scratchpad: one shared physical pool, many concurrent
streams addressing into it.

Granularity is one KV block: a tree node keys on a ``block_size``-token
tuple and owns exactly the pool block holding those tokens' K/V.  The tree
is a radix trie over block-sized token chunks — a path root..node spells a
block-aligned prompt prefix.  Only *full* blocks are ever cached, so a hit
is always block-aligned and the admitting request's KV writes (which start
at the first uncached position) never touch a shared block; the
copy-on-write machinery in kv_cache.py therefore stays off the hot path.

Ownership: the cache holds one allocator ref per node (taken at insert,
dropped at evict).  A block freed by its writing request thus survives in
the pool while cached, and a block evicted from the cache survives while
any request still reads it — the refcounted pool is the single source of
truth.  Eviction is LRU over leaves (deepest, stalest prefixes go first),
so every cached path stays rooted.

The cache is engine-local and runs on the engine's thread; cluster-level
sharing comes from the router's prefix-affinity policy steering same-prefix
requests to the same replica (see cluster/router.py).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple


class _Node:
    __slots__ = ("children", "block", "stamp", "parent", "key")

    def __init__(self, parent: Optional["_Node"] = None,
                 key: Optional[Tuple[int, ...]] = None,
                 block: Optional[int] = None):
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.block = block
        self.stamp = 0
        self.parent = parent
        self.key = key


class PrefixCache:
    """Block-granular radix cache bound to one BlockAllocator."""

    def __init__(self, alloc, *, max_blocks: Optional[int] = None):
        self.alloc = alloc
        self.block_size = alloc.block_size
        self.max_blocks = max_blocks      # None: bounded only by pool pressure
        self._root = _Node()
        self._clock = 0
        self._count = 0
        # stats (read by EngineMetrics consumers and cluster/metrics.py)
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.inserted_blocks = 0
        self.evicted_blocks = 0

    # -- content keys --------------------------------------------------------

    def _keys(self, tokens) -> List[Tuple[int, ...]]:
        toks = [int(t) for t in tokens]
        bs = self.block_size
        return [tuple(toks[i * bs:(i + 1) * bs])
                for i in range(len(toks) // bs)]

    # -- the request path ----------------------------------------------------

    def lookup(self, tokens) -> Tuple[List[int], int]:
        """Longest cached full-block prefix of `tokens`.

        Capped at ``len(tokens) - 1`` so at least one suffix token remains
        to prefill — the final prefill chunk's logits are what produce the
        request's first generated token.  Returns ``(block_ids, covered)``
        *without* taking refs; the caller forks (kv_cache.fork_blocks) the
        ids it actually uses.
        """
        self.lookups += 1
        self.lookup_tokens += len(tokens)
        self._clock += 1
        usable = (len(tokens) - 1) // self.block_size
        node, out = self._root, []
        for key in self._keys(tokens)[:usable]:
            child = node.children.get(key)
            if child is None:
                break
            child.stamp = self._clock
            out.append(child.block)
            node = child
        if out:
            self.hits += 1
            self.hit_tokens += len(out) * self.block_size
        return out, len(out) * self.block_size

    def insert(self, tokens, blocks: List[int]) -> int:
        """Publish `blocks` — full, already-written pool blocks spelling
        `tokens` — taking one cache-owned ref per *newly adopted* block.

        Existing nodes keep their block (first writer wins): a concurrent
        duplicate prefill keeps sole ownership of its copy and frees it at
        finish, so refcounts stay exact.  Returns the adopted count.
        """
        keys = self._keys(tokens)
        if len(keys) * self.block_size != len(tokens):
            raise ValueError(
                f"insert must be block-aligned: {len(tokens)} tokens vs "
                f"block_size {self.block_size}")
        if len(blocks) != len(keys):
            raise ValueError(f"{len(blocks)} blocks for {len(keys)} chunks")
        self._clock += 1
        node, adopted = self._root, 0
        for key, b in zip(keys, blocks):
            child = node.children.get(key)
            if child is None:
                self.alloc.ref([b])          # the cache's own share
                child = _Node(parent=node, key=key, block=b)
                node.children[key] = child
                self._count += 1
                self.inserted_blocks += 1
                adopted += 1
            child.stamp = self._clock
            node = child
        if self.max_blocks is not None and self._count > self.max_blocks:
            self.evict(self._count - self.max_blocks)
        return adopted

    # -- eviction ------------------------------------------------------------

    def _leaves(self) -> List[_Node]:
        stack, out = [self._root], []
        while stack:
            n = stack.pop()
            for c in n.children.values():
                (stack if c.children else out).append(c)
        return out

    def evict(self, n_blocks: int) -> int:
        """Drop up to `n_blocks` LRU leaves, freeing the cache's refs.

        A freed block returns to the pool immediately iff no in-flight
        request still shares it (the allocator keeps it alive otherwise).
        Leaves-first keeps every remaining cached path rooted; evicting a
        leaf may expose its parent, which the next sweep considers.
        """
        freed = 0
        while freed < n_blocks:
            leaves = self._leaves()
            if not leaves:
                break
            leaves.sort(key=lambda nd: nd.stamp)
            for nd in leaves:
                if freed >= n_blocks:
                    break
                self.alloc.free([nd.block])
                del nd.parent.children[nd.key]
                self._count -= 1
                self.evicted_blocks += 1
                freed += 1
        return freed

    def clear(self) -> int:
        return self.evict(self._count)

    # -- introspection -------------------------------------------------------

    @property
    def cached_blocks(self) -> int:
        return self._count

    @property
    def cached_tokens(self) -> int:
        return self._count * self.block_size

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.lookups)

    def __repr__(self) -> str:
        return (f"PrefixCache(blocks={self._count}, hits={self.hits}/"
                f"{self.lookups}, hit_tokens={self.hit_tokens})")
