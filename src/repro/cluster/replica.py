"""Replica pool: N serving engines, thread-per-replica, device-pinned.

One ``Engine`` saturates one device's compute the way the paper's single
core saturates its MXU; the pool is the system layer above it — N engines
each driven by their own thread through the existing ``tick()`` loop, fed
by a router (cluster/router.py).  When ``jax.devices()`` exposes more than
one device, replicas pin round-robin via ``jax.default_device``; otherwise
they share the default device and the win comes from overlap (one
replica's host-side scheduling runs while another's device step computes —
XLA releases the GIL during execution).

Engines are single-thread-confined: only the owning replica thread calls
``submit``/``tick`` on its engine.  The router hands work over through a
thread-safe inbox; results come back through ``ClusterRequest`` handles
(future-like: ``result()`` blocks, ``done`` is an Event).

Construction cost is shared where correctness allows: params are
initialized once and handed to every engine (device_put per replica when
pinned), and replicas of the same config reuse replica 0's jitted step
functions, so the pool compiles each step shape once, not N times.
"""

from __future__ import annotations

import contextlib
import dataclasses
import queue as queue_lib
import threading
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.obs import Tracer, write_chrome_trace
from repro.serving.engine import Engine
from repro.serving.request import RequestSpec, as_spec


class ClusterRequest:
    """Handle for one routed request; resolves when its engine finishes.

    Carries the full ``RequestSpec`` (not just prompt/max_new), so
    eos_token, sampling params, priority class and tenant all survive the
    router -> replica hop; ``prompt``/``max_new`` stay as read-through
    properties for existing policy/metrics code."""

    __slots__ = ("crid", "spec", "replica", "tokens", "shed",
                 "error", "done", "t_submit", "t_engine_submit", "t_done",
                 "engine_metrics", "trace_id")

    def __init__(self, crid: int, request, max_new: Optional[int] = None):
        self.crid = crid
        self.spec: RequestSpec = as_spec(request, max_new)
        self.trace_id = -1                   # minted at router admission
        self.replica: Optional[int] = None
        self.tokens: Optional[np.ndarray] = None
        self.shed = False
        self.error: Optional[BaseException] = None
        self.done = threading.Event()
        self.t_submit = time.monotonic()
        self.t_engine_submit: Optional[float] = None
        self.t_done: Optional[float] = None
        self.engine_metrics = None           # serving.engine.RequestMetrics

    @property
    def prompt(self) -> np.ndarray:
        return self.spec.prompt

    @property
    def max_new(self) -> int:
        return self.spec.max_new

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self.done.wait(timeout):
            raise TimeoutError(f"request {self.crid} still in flight")
        if self.error is not None:
            raise self.error
        if self.shed:
            raise RuntimeError(f"request {self.crid} was shed")
        return self.tokens

    @property
    def ttft_s(self) -> Optional[float]:
        """Cluster TTFT: router/inbox wait + the engine-side TTFT."""
        if self.engine_metrics is None or self.t_engine_submit is None:
            return None
        return (self.t_engine_submit - self.t_submit
                + self.engine_metrics.ttft_s)


@dataclasses.dataclass(frozen=True)
class ReplicaView:
    """Load snapshot a routing policy sees — plain data, so policies stay
    pure functions of their inputs (testable without a live pool)."""

    idx: int
    inbox: int           # routed but not yet engine-submitted
    queued: int          # in the engine's admission queue
    active: int          # occupying a slot (prefill or decode)
    free_blocks: int     # KV pool blocks not allocated

    @property
    def depth(self) -> int:
        return self.inbox + self.queued + self.active


class Replica:
    """One engine + its driver thread + its inbox."""

    def __init__(self, idx: int, cfg, *, device=None, params=None,
                 share_steps_from: Optional[Engine] = None, **engine_kwargs):
        self.idx = idx
        self.device = device
        with self._device_ctx():
            if params is not None and device is not None:
                params = jax.device_put(params, device)
            self.engine = Engine(cfg, params=params, **engine_kwargs)
        if share_steps_from is not None:
            # Same cfg => same traces; sharing the jitted callables means the
            # pool compiles each step shape once (jit dispatch is
            # thread-safe; the steps are functional).
            self.engine.share_steps_from(share_steps_from)
        self.inbox: "queue_lib.Queue[ClusterRequest]" = queue_lib.Queue()
        self._pending: Dict[int, ClusterRequest] = {}   # engine rid -> handle
        self._metrics_seen = 0
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None

    def _device_ctx(self):
        return (jax.default_device(self.device) if self.device is not None
                else contextlib.nullcontext())

    def warmup(self) -> None:
        with self._device_ctx():
            self.engine.warmup()

    # -- router-facing -------------------------------------------------------

    def submit(self, handle: ClusterRequest) -> None:
        handle.replica = self.idx
        if self.error is not None:          # dead replica: fail fast, don't
            handle.error = self.error       # park work in an undrained inbox
            handle.done.set()
            return
        self.inbox.put(handle)
        self._wake.set()

    def view(self) -> ReplicaView:
        eng = self.engine
        return ReplicaView(
            idx=self.idx,
            inbox=self.inbox.qsize(),
            queued=len(eng.scheduler.queue),
            active=sum(r is not None for r in eng.scheduler.slots),
            free_blocks=eng.alloc.free_blocks,
        )

    # -- the drive loop ------------------------------------------------------

    def _drain_inbox(self) -> None:
        while True:
            try:
                h = self.inbox.get_nowait()
            except queue_lib.Empty:
                return
            try:
                h.t_engine_submit = time.monotonic()
                # Thread the router-minted trace id into the engine so the
                # request's flow chain crosses from the router lane into
                # this replica's lane under one id.
                req = self.engine.submit(
                    h.spec,
                    trace_id=(h.trace_id if h.trace_id >= 0 else None))
            except Exception as e:          # oversize prompt etc: fail the
                h.error = e                 # handle, not the replica thread
                h.done.set()
                continue
            if req is None:                 # engine-side queue bound hit
                h.shed = True
                h.done.set()
            else:
                self._pending[req.rid] = h

    def _resolve(self) -> None:
        if not self._pending:
            return
        em = self.engine.metrics
        reqs = em.requests
        # _metrics_seen counts *finished* requests ever observed; with a
        # capped request log (Engine(request_log=N)) the raw list's head is
        # trimmed, so the unseen suffix starts at seen - dropped.
        by_rid = {}
        for m in reqs[self._metrics_seen - em.requests_dropped:]:
            by_rid[m.rid] = m
        self._metrics_seen = em.finished_requests
        for rid, m in by_rid.items():
            h = self._pending.pop(rid, None)
            if h is None:
                continue
            h.engine_metrics = m
            h.tokens = self.engine.results[rid]
            h.t_done = time.monotonic()
            h.done.set()

    def step(self) -> bool:
        """One synchronous pump: drain inbox, tick once, resolve finishes.
        Returns True while the engine still has work."""
        self._drain_inbox()
        busy = False
        if self.engine.scheduler.has_work:
            busy = self.engine.tick()
            self._resolve()
        return busy or not self.inbox.empty()

    def _run(self) -> None:
        try:
            with self._device_ctx():
                while not self._stop.is_set():
                    if not self.step():
                        # idle: sleep until the router wakes us (bounded so
                        # a lost wakeup can only cost one nap)
                        self._wake.wait(0.005)
                        self._wake.clear()
        except BaseException as e:          # pragma: no cover - defensive
            self.error = e
            self._fail_outstanding(e)

    def _fail_outstanding(self, e: BaseException) -> None:
        """Resolve every handle this replica owns — in flight *and* still in
        the inbox — with the error, so no waiter hangs on a dead replica."""
        for h in self._pending.values():
            h.error = e
            h.done.set()
        self._pending.clear()
        while True:
            try:
                h = self.inbox.get_nowait()
            except queue_lib.Empty:
                return
            h.error = e
            h.done.set()

    def start(self) -> None:
        if self._thread is not None:
            return
        self._thread = threading.Thread(
            target=self._run, name=f"replica-{self.idx}", daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None


class ReplicaPool:
    """N replicas over one config: build, warm, start, submit, drain."""

    def __init__(self, cfg, n: int, *, devices="auto", seed: int = 0,
                 trace: bool = False, **engine_kwargs):
        if n < 1:
            raise ValueError(f"need at least one replica, got {n}")
        self.cfg = cfg
        # Pool-level tracing: one Tracer per replica (pid=i), each confined
        # to its replica thread — no cross-thread writes, and the export
        # shows one process row per replica on a shared clock.
        self.tracers: List[Tracer] = []
        if trace:
            self.tracers = [Tracer(name=f"replica{i}[{cfg.name}]", pid=i)
                            for i in range(n)]
        if devices == "auto":
            avail = jax.devices()
            devices = ([avail[i % len(avail)] for i in range(n)]
                       if len(avail) > 1 else [None] * n)
        elif devices is None:
            devices = [None] * n
        if len(devices) != n:
            raise ValueError(f"{len(devices)} devices for {n} replicas")
        from repro.models import model as M

        params = engine_kwargs.pop("params", None)
        if params is None:
            params = M.init_model(jax.random.PRNGKey(seed), cfg)
        self.replicas: List[Replica] = []
        for i in range(n):
            kw = dict(engine_kwargs)
            if self.tracers:
                kw["trace"] = self.tracers[i]
            self.replicas.append(Replica(
                i, cfg, device=devices[i], params=params,
                share_steps_from=self.replicas[0].engine if i else None,
                seed=seed, **kw))

    def __len__(self) -> int:
        return len(self.replicas)

    @property
    def engines(self) -> List[Engine]:
        return [r.engine for r in self.replicas]

    def warmup(self, verbose: bool = False) -> None:
        # Serial on purpose: replica 0 pays the compiles, the rest hit the
        # shared jit caches — the pool-level configuration-pre-loading
        # analogue (one warmup amortized across the pool).
        for r in self.replicas:
            t0 = time.monotonic()
            r.warmup()
            if verbose:
                print(f"replica[{r.idx}] warm in "
                      f"{(time.monotonic() - t0) * 1e3:.0f}ms")

    def start(self) -> None:
        for r in self.replicas:
            r.start()

    def stop(self) -> None:
        for r in self.replicas:
            r.stop()

    def export_trace(self, path: str, *, metadata: Optional[dict] = None,
                     extra_tracers=()) -> dict:
        """Write the pool's Chrome-trace JSON (requires trace=True); one
        process lane per replica.  `extra_tracers` adds non-pool lanes on
        the same clock (launch/serve.py appends the router's tracer so
        admission flows connect to replica lanes).  Call after stop() /
        run_sync() — the rings are single-writer and read here from the
        caller's thread."""
        if not self.tracers:
            raise RuntimeError(
                "pool was built without tracing; pass ReplicaPool(trace=True)")
        return write_chrome_trace(path, self.tracers + list(extra_tracers),
                                  metadata=metadata)

    def submit_to(self, idx: int, handle: ClusterRequest) -> None:
        self.replicas[idx].submit(handle)

    def views(self) -> List[ReplicaView]:
        return [r.view() for r in self.replicas]

    def run_sync(self, max_ticks: Optional[int] = None) -> None:
        """Threadless drive: round-robin one tick per replica until every
        inbox and engine drains.  The deterministic twin of start()/drain()
        — tests use it to get scheduling-order-independent runs."""
        ticks = 0
        while True:
            busy = False
            for r in self.replicas:
                busy = r.step() or busy
            ticks += 1
            if not busy:
                return
            if max_ticks is not None and ticks >= max_ticks:
                raise TimeoutError(f"pool still busy after {max_ticks} ticks")

    def drain(self, handles, timeout: float = 120.0) -> None:
        """Block until every accepted handle resolves (threaded mode).

        A dead replica's exception is re-raised here (checked while
        waiting, not only at the end — a handle routed to a replica that
        died before picking it up would otherwise turn the root cause into
        an unhelpful TimeoutError)."""
        deadline = time.monotonic() + timeout
        for h in handles:
            while not h.done.wait(min(0.25, max(0.0, deadline - time.monotonic()))):
                for r in self.replicas:
                    if r.error is not None:
                        raise r.error
                if time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"request {h.crid} unresolved after {timeout}s "
                        f"(replica {h.replica})")
        for r in self.replicas:
            if r.error is not None:
                raise r.error
