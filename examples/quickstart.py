"""Quickstart: the OpenGeMM framework in five minutes (CPU-friendly).

1. Generate an accelerator instance from the paper's Table-1 config and
   simulate its utilization on a GeMM workload (the paper's evaluation).
2. Run the same GeMM through the TPU kernel generator (interpret mode on
   CPU) and check it against the oracle.
3. Train a tiny LM whose every matmul routes through the OpenGeMM op.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GemmShape, OpenGeMMConfig, OpenGeMMSimulator
from repro.kernels import ops, ref


def part1_simulate():
    print("== 1. accelerator generation + utilization simulation ==")
    cfg = OpenGeMMConfig()  # the paper's 8x8x8 case study
    sim = OpenGeMMSimulator(cfg)
    for mkn in [(32, 32, 32), (128, 128, 128), (197, 768, 768)]:
        shape = GemmShape(*mkn)
        rep = sim.report([shape] * 10)
        print(f"  GeMM {mkn}: overall utilization {rep.ou*100:.1f}%  "
              f"({rep.gops():.1f} GOPS of {cfg.peak_gops():.1f} peak)")


def part2_kernel():
    print("== 2. TPU kernel generator (interpret mode) ==")
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    out = ops.gemm(a, b, backend="interpret")
    np.testing.assert_allclose(out, ref.gemm_ref(a, b), rtol=1e-5, atol=1e-4)
    print("  pallas kernel matches oracle; tile spec:",
          OpenGeMMConfig().tpu_kernel_spec(GemmShape(256, 512, 256)))

    # int8 deployment path (the paper's P_A=P_B=8, P_C=32)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 128))
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 96)) * 0.1
    y = ops.linear(x, w, quant="int8", backend="interpret")
    err = float(jnp.max(jnp.abs(y - x @ w)) / jnp.max(jnp.abs(x @ w)))
    print(f"  int8 quantized linear: rel err {err:.4f}")


def part3_train():
    print("== 3. tiny LM training through the OpenGeMM op ==")
    from repro.launch import train as train_launcher

    train_launcher.main([
        "--arch", "gemma3-1b", "--preset", "smoke",
        "--steps", "30", "--batch", "4", "--seq", "32", "--ckpt-every", "1000",
    ])


if __name__ == "__main__":
    part1_simulate()
    part2_kernel()
    part3_train()
