"""OpenGeMM int8 deployment mode: quantize a trained model's matmuls to the
paper's P_A=P_B=8 / P_C=32 regime and measure the quality delta.

The paper's accelerator is an int8 engine; this example shows the framework
running the same architecture in float and in int8-GeMM mode (per-row
activation scales, per-column weight scales, int32 accumulation — the exact
kernel epilogue of kernels/gemm.py), comparing perplexity on held-out
synthetic data.

Run:  PYTHONPATH=src python examples/int8_deployment.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import SyntheticLMData
from repro.kernels import ops, ref
from repro.models import model as M


def eval_loss(params, cfg, batches, quant=None):
    # quant mode is routed through kernels.ops.linear by monkey-patched default
    losses = []
    for b in batches:
        logits = M.forward(params, cfg, {k: jnp.asarray(v) for k, v in b.items()})
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ll = jnp.take_along_axis(logp, jnp.asarray(b["labels"])[..., None], -1)
        losses.append(float(-jnp.mean(ll)))
    return float(np.mean(losses))


def main():
    cfg = configs.get_smoke("qwen3-14b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    data = SyntheticLMData(cfg.vocab, batch=4, seq=64)
    batches = [data.batch_at(i) for i in range(4)]

    f32 = eval_loss(params, cfg, batches)

    # int8 weight quantization error per layer (the deployment transform):
    w = params["blocks"]["sub0"]["mixer"]["wq"][0]
    q, s = ref.quantize_ref(jnp.asarray(w, jnp.float32), axis=0)
    werr = float(jnp.max(jnp.abs(ref.dequantize_ref(q, s) - w)))
    print(f"per-column int8 weight quant: max abs err {werr:.5f}")

    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    y_f = x @ w.astype(jnp.float32)
    y_q = ops.linear(x, w.astype(jnp.float32), quant="int8", backend="interpret")
    rel = float(jnp.linalg.norm(y_q - y_f) / jnp.linalg.norm(y_f))
    print(f"int8 GeMM path rel err vs f32: {rel:.4f}")
    print(f"f32 eval loss: {f32:.4f} (int8 path verified at op level; "
          f"full-model int8 eval runs on TPU via ops.set_default_backend)")


if __name__ == "__main__":
    main()
