"""OpenGeMM int8 deployment mode, end to end through `repro.quant`.

The paper's accelerator is an int8 engine (P_A = P_B = 8, P_C = 32); this
example walks its deployment recipe on a smoke-scale model with no
monkey-patching — the same subsystem the serving engine uses under
``Engine(cfg, precision="w8a8")``:

  1. calibrate activation scales over held-out batches (observers);
  2. quantize the weights int8-resident once (`quantize_params`);
  3. inspect where precision goes (`report.layer_error_rows`);
  4. measure the end-to-end quality delta float vs w8a8 vs w8a8-calibrated.

Run:  PYTHONPATH=src python examples/int8_deployment.py
"""

import jax

from repro import configs, quant
from repro.data import SyntheticLMData
from repro.models import model as M


def main():
    cfg = configs.get_smoke("qwen3-14b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    data = SyntheticLMData(cfg.vocab, batch=4, seq=64)
    calib = [data.batch_at(i) for i in range(2)]        # calibration split
    heldout = [data.batch_at(i) for i in range(2, 6)]   # evaluation split

    # 1. calibrate: absmax observers over the calibration batches
    table = quant.collect_scales(params, cfg, calib, observer="absmax")
    print(f"calibrated {len(table)} activation sites "
          f"({table.observer}, {table.batches} batches)")

    # 2. quantize once: int8 weights + f32 per-column scales, static
    #    activation scales attached for the calibrated mode
    qparams = quant.quantize_params(params, cfg=cfg, scales=table)
    fb, qb = quant.weight_bytes(params), quant.weight_bytes(qparams)
    print(f"weights: {fb / 2**20:.2f}MiB float -> {qb / 2**20:.2f}MiB "
          f"int8-resident ({1 - qb / fb:.0%} smaller, "
          f"{quant.quantized_leaf_count(qparams)} matrices)")

    # 3. per-layer quantization error (worst layers first)
    rows = quant.layer_error_rows(params, qparams)
    print("\nper-layer int8 weight error:")
    print(quant.format_error_table(rows, top=8))

    # 4. end-to-end quality delta on held-out batches
    for mode in ("w8a8", "w8a8-calibrated"):
        d = quant.quality_delta(params, qparams, cfg, heldout, mode=mode)
        print(f"\n{mode}: NLL {d['float_nll']:.4f} (float) -> "
              f"{d['quant_nll']:.4f} ({mode}), delta {d['delta_nll']:+.4f} "
              f"({d['rel_delta']:+.2%})")

    worst = rows[0]
    print(f"\nworst-quantizing layer: {worst['path']} "
          f"(rel err {worst['rel_err']:.4f}, "
          f"column-scale spread {worst['scale_spread']:.1f}x)")


if __name__ == "__main__":
    main()
