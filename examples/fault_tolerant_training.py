"""Fault-tolerant training demo: checkpoint / crash / restart / resume.

Trains a ~100M-class model, injects a failure mid-run, and shows the
supervisor restoring from the latest atomic checkpoint and finishing with
the same final state a failure-free run reaches (bitwise, because data is
addressed by step cursor).

Run:  PYTHONPATH=src python examples/fault_tolerant_training.py
"""

import shutil

import numpy as np

from repro.launch import train as T


def run(fail_at, ckpt_dir):
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    argv = [
        "--arch", "qwen3-14b", "--preset", "smoke",
        "--steps", "60", "--batch", "4", "--seq", "32",
        "--ckpt-every", "20", "--ckpt-dir", ckpt_dir,
    ]
    if fail_at is not None:
        argv += ["--fail-at", str(fail_at)]
    return T.main(argv)


if __name__ == "__main__":
    print("== clean run ==")
    clean = run(None, "/tmp/repro_ft_clean")
    print("== failure at step 35 (restart from step-20 checkpoint) ==")
    failed = run(35, "/tmp/repro_ft_fail")
    assert failed["restarts"] == 1, failed["restarts"]
    l_clean = [m["loss"] for m in clean["metrics"]][-1]
    l_fail = [m["loss"] for m in failed["metrics"]][-1]
    print(f"final loss clean={l_clean:.4f} vs restarted={l_fail:.4f}")
    np.testing.assert_allclose(l_clean, l_fail, rtol=1e-4)
    print("restart converged to the failure-free trajectory ✓")
