"""Batched serving demo: prefill + continuous decode over request slots,
for a dense LM and for the hybrid (Jamba-style) arch whose SSM layers give
O(1)-state decode.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch import serve

if __name__ == "__main__":
    print("== dense (gemma3 family) ==")
    serve.main(["--arch", "gemma3-1b", "--requests", "4", "--gen-len", "12"])
    print("== hybrid (jamba family: mamba + attention + MoE) ==")
    serve.main(["--arch", "jamba-1.5-large-398b", "--requests", "2", "--gen-len", "8"])
    print("== recurrent (xlstm family) ==")
    serve.main(["--arch", "xlstm-1.3b", "--requests", "2", "--gen-len", "8"])
