"""Batched serving demo: the serving engine (scheduler + paged KV cache +
chunked prefill) over the three serving families — dense, hybrid (Jamba:
SSM layers give O(1)-state decode), and recurrent (xLSTM).

More requests than slots, so continuous batching refills finished slots from
the admission queue; `--compare-prefill` on the dense arch prints the
chunked-vs-token-by-token prefill speedup (EXPERIMENTS.md §Serving).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch import serve

if __name__ == "__main__":
    print("== dense (gemma3 family) ==")
    serve.main(["--arch", "gemma3-1b", "--requests", "8", "--slots", "4",
                "--prompt-len", "64", "--gen-len", "12", "--compare-prefill"])
    print("== hybrid (jamba family: mamba + attention + MoE) ==")
    serve.main(["--arch", "jamba-1.5-large-398b", "--requests", "4",
                "--slots", "2", "--gen-len", "8"])
    print("== recurrent (xlstm family) ==")
    serve.main(["--arch", "xlstm-1.3b", "--requests", "4", "--slots", "2",
                "--gen-len", "8"])
