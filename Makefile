# One memorable invocation per tier-1 task (see README.md).
PY ?= python
# src for the repro package, . so `benchmarks` resolves as a package.
export PYTHONPATH := src:.$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-all bench bench-smoke lint

# Tier-1 verify: deterministic suite; hypothesis modules auto-skip if absent.
test:
	$(PY) -m pytest -x -q

# Includes the property-based modules (pip install -r requirements-dev.txt).
test-all:
	$(PY) -m pytest -q

# All paper-reproduction benchmarks as CSV (see EXPERIMENTS.md).
bench:
	$(PY) benchmarks/run.py

# Smoke of every benchmark section: real code paths, wall-clock-heavy
# sections shrunken (REPRO_BENCH_FAST); wired into CI so benchmark
# scripts cannot silently rot.
bench-smoke:
	$(PY) benchmarks/run.py --fast

# Import/syntax sweep; uses pyflakes when available, else compileall only.
lint:
	$(PY) -m compileall -q src benchmarks examples tests
	-$(PY) -m pyflakes src benchmarks examples tests 2>/dev/null || true
