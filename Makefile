# One memorable invocation per tier-1 task (see README.md).
PY ?= python
# src for the repro package, . so `benchmarks` resolves as a package.
export PYTHONPATH := src:.$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-all bench bench-smoke lint

# Tier-1 verify: deterministic suite; hypothesis modules auto-skip if absent.
test:
	$(PY) -m pytest -x -q

# Includes the property-based modules (pip install -r requirements-dev.txt).
test-all:
	$(PY) -m pytest -q

# All paper-reproduction benchmarks as CSV (see EXPERIMENTS.md).
bench:
	$(PY) benchmarks/run.py

# Smoke of every benchmark section: real code paths, wall-clock-heavy
# sections shrunken (REPRO_BENCH_FAST); wired into CI so benchmark
# scripts cannot silently rot.  Per-section begin/end lines land on
# stderr (timeout attribution) and BENCH_smoke.json is written for
# benchmarks/compare.py / the CI artifact.
bench-smoke:
	$(PY) benchmarks/run.py --fast

# Syntax sweep (compileall), then pyflakes — whose findings FAIL the
# target (CI's lint job depends on that).  The one allowed skip is
# pyflakes being genuinely absent locally (pip install -r
# requirements-dev.txt); the skip is loud, never silent.
lint:
	$(PY) -m compileall -q src benchmarks examples tests
	@if $(PY) -c "import pyflakes" 2>/dev/null; then \
		$(PY) -m pyflakes src benchmarks examples tests; \
	else \
		echo "lint: pyflakes not installed; syntax sweep only" \
		     "(pip install -r requirements-dev.txt)"; \
	fi
