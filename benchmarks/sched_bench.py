"""Scheduler benchmark: interactive p95 latency under batch load, FIFO vs
class-aware preemption (EXPERIMENTS.md §Scheduling).

Paper artifact: none directly — this measures the serving-path analogue of
the paper's control argument: a lightweight programmable scheduler in front
of a fixed datapath decides *which* work the datapath runs, and that
decision (not the datapath) sets tail latency for the latency-class.

Scenario (deterministic, tick-driven): one decode slot, a backlog of
``batch``-class requests with long generations occupying it, and
``interactive``-class arrivals every few ticks wanting a short generation.
Without preemption (``preempt=False``) the interactive request waits for
the batch resident's remaining decode — pure head-of-line blocking.  With
``preempt=True`` the engine swaps the batch victim's KV blocks to host
memory, serves the interactive request immediately, then restores the
victim (token-identical; tests/test_scheduling.py proves the round-trip).

Output rows (CSV via benchmarks/run.py):
  sched/interactive_p95_ms_fifo     interactive-class p95 latency, FIFO
  sched/interactive_p95_ms_preempt  same arrivals, preemption on (derived =
                                    the FIFO row: the delta that matters)
  sched/interactive_p95_speedup     FIFO / preempt p95 ratio (derived = 1.0,
                                    the acceptance bar: preemption must not
                                    lose)
  sched/preempt_swap_ms             mean swap-out + restore wall clock per
                                    preemption (the price of the ratio)
  sched/preemptions                 victims swapped in the preempt run

Both engines share one warmed step cache (``share_steps_from``), and the
two modes run interleaved best-of-N so host load spikes hit both alike.
Latencies come from the engine's own submit->finish RequestMetrics.

Expected runtime: ~1 min on CPU (dominated by the single warmup compile).
REPRO_BENCH_FAST=1 (or `benchmarks/run.py --fast` / `make bench-smoke`)
shrinks generations/arrivals to a smoke run of the same code paths.
"""

from __future__ import annotations

import os

import numpy as np

from repro import configs
from repro.serving.engine import Engine
from repro.serving.request import RequestSpec
from repro.tuning import env_truthy

FAST = env_truthy(os.environ.get("REPRO_BENCH_FAST"))

ARCH = "gemma3-1b"
PROMPT_LEN = 8
BATCH_GEN = 12 if FAST else 40     # batch-class generation length
N_BATCH = 2                        # backlog depth keeping the slot busy
INT_GEN = 4                        # interactive-class generation length
N_INT = 3 if FAST else 8           # interactive arrivals per run
GAP_TICKS = 6 if FAST else 8       # ticks between interactive arrivals
WARM_TICKS = 2                     # batch decode ticks before first arrival
ITERS = 2 if FAST else 3
BLOCK_SIZE = 4


def _engine(cfg, warm, *, preempt):
    eng = Engine(cfg, slots=1, max_seq=PROMPT_LEN + BATCH_GEN + 1,
                 block_size=BLOCK_SIZE, preempt=preempt)
    if warm is not None:
        eng.share_steps_from(warm)
    return eng


def _scenario(eng, rng):
    """Batch backlog + periodic interactive arrivals; returns the
    interactive-class latencies (seconds) plus swap accounting."""
    batch = [rng.integers(0, eng.cfg.vocab, size=PROMPT_LEN).astype(np.int32)
             for _ in range(N_BATCH)]
    inter = [rng.integers(0, eng.cfg.vocab, size=PROMPT_LEN).astype(np.int32)
             for _ in range(N_INT)]
    for p in batch:
        eng.submit(RequestSpec(prompt=p, max_new=BATCH_GEN,
                               priority="batch", tenant="bulk"))
    for _ in range(WARM_TICKS):
        eng.tick()
    for p in inter:
        eng.submit(RequestSpec(prompt=p, max_new=INT_GEN,
                               priority="interactive", tenant="live"))
        for _ in range(GAP_TICKS):
            eng.tick()
    eng.run()
    lats = [r.latency_s for r in eng.metrics.requests
            if r.priority == "interactive"]
    assert len(lats) == N_INT, "scenario must finish every interactive request"
    return (float(np.percentile(lats, 95)),
            eng.metrics.preemptions, eng.metrics.swap_time_s)


def run():
    cfg = configs.get_smoke(ARCH)
    warm = _engine(cfg, None, preempt=True)
    warm.warmup()

    fifo_p95 = pre_p95 = float("inf")
    preemptions, swap_s = 0, 0.0
    for i in range(ITERS):
        # fresh engines per iteration (state + metrics reset), shared steps;
        # interleaved so a host load spike degrades both modes alike
        f, _, _ = _scenario(_engine(cfg, warm, preempt=False),
                            np.random.default_rng(i))
        p, n_pre, t_swap = _scenario(_engine(cfg, warm, preempt=True),
                                     np.random.default_rng(i))
        fifo_p95, pre_p95 = min(fifo_p95, f), min(pre_p95, p)
        if n_pre:                      # keep one run's swap accounting
            preemptions, swap_s = n_pre, t_swap

    swap_ms = swap_s * 1e3 / preemptions if preemptions else 0.0
    return [
        {"name": "sched/interactive_p95_ms_fifo",
         "value": round(fifo_p95 * 1e3, 1), "derived": ""},
        {"name": "sched/interactive_p95_ms_preempt",
         "value": round(pre_p95 * 1e3, 1), "derived": round(fifo_p95 * 1e3, 1)},
        {"name": "sched/interactive_p95_speedup",
         "value": round(fifo_p95 / pre_p95, 2) if pre_p95 else "",
         "derived": 1.0},
        {"name": "sched/preempt_swap_ms",
         "value": round(swap_ms, 2), "derived": "informational"},
        {"name": "sched/preemptions",
         "value": preemptions, "derived": ""},
    ]


def rows():
    return run()


if __name__ == "__main__":
    print("name,value,derived")
    for r in rows():
        print(f"{r['name']},{r['value']},{r['derived']}")
