"""Table 2 reproduction: utilization + cycle count on real DNN workloads
(MobileNetV2, ResNet18, ViT-B-16, BERT-base through im2col GeMM extraction).

Paper artifact: Table 2 (Sec. 4.3) — per-model SU/TU/OU percentages and
total cycle counts on the case-study instance.

Output rows (CSV via benchmarks/run.py):
  table2/<model>/{su,tu,ou}   reproduced percentage (derived: paper value)
  table2/<model>/cycles       reproduced cycle count (derived: paper value)

Expected runtime: ~5 s.  Batch sizes are back-derived (the paper omits
them) — see EXPERIMENTS.md "Back-derivations".
"""

from __future__ import annotations

from repro.core.simulator import OpenGeMMSimulator
from repro.core.workloads import TABLE2_MODELS, TABLE2_PAPER


def run():
    sim = OpenGeMMSimulator()
    out = {}
    for name, fn in TABLE2_MODELS.items():
        rep = sim.report_grouped(fn())
        su_p, tu_p, ou_p, cc_p = TABLE2_PAPER[name]
        out[name] = {
            "su": rep.su * 100, "tu": rep.tu * 100, "ou": rep.ou * 100,
            "cycles": rep.total_cycles,
            "paper": {"su": su_p, "tu": tu_p, "ou": ou_p, "cycles": cc_p},
        }
    return out


def rows():
    out = []
    for name, r in run().items():
        for k in ("su", "tu", "ou"):
            out.append({
                "name": f"table2/{name}/{k}", "value": round(r[k], 2),
                "derived": f"paper={r['paper'][k]}",
            })
        out.append({
            "name": f"table2/{name}/cycles", "value": f"{r['cycles']:.3e}",
            "derived": f"paper={r['paper']['cycles']:.2e}",
        })
    return out


if __name__ == "__main__":
    print(f"{'model':14s} {'SU%':>7s} {'TU%':>7s} {'OU%':>7s} {'cycles':>10s}   (paper values)")
    for name, r in run().items():
        p = r["paper"]
        print(f"{name:14s} {r['su']:7.2f} {r['tu']:7.2f} {r['ou']:7.2f} "
              f"{r['cycles']:10.3e}   ({p['su']}, {p['tu']}, {p['ou']}, {p['cycles']:.2e})")
