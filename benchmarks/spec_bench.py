"""Speculative-decoding benchmark: decode tok/s with and without batched
verification, across acceptance regimes.

Paper artifact: none directly — this measures the serving-stack analogue of
the paper's utilization mechanisms (README §Speculative).  Non-speculative
decode issues one token per tick, so every hot matmul is an M=slots GEMV;
the drafter + batched ``paged_verify_step`` fold K sequential GEMV ticks
into one M = slots*(K+1) GEMM.  The speedup is therefore a direct function
of the acceptance rate, so the benchmark runs two traces:

  * repetitive  — a regeneration storm: every request re-serves the same
    prompt (retries / shared templates / multi-sample, the same traffic
    prefix caching targets).  Greedy decoding is deterministic, so the
    drafter's recent-stream corpus proposes the *true* continuation and
    acceptance approaches 1.  Acceptance bar: >= 1.5x decode tok/s.
  * random-ish  — i.i.d. random prompts: drafts come only from each
    request's own n-gram statistics, acceptance is low, and the row
    records whatever the mechanism costs/gains in that regime (no bar —
    the point is that misses are cheap, not that they win).

Output rows (CSV via benchmarks/run.py):
  spec/decode_tok_s_base        non-speculative decode tok/s (repetitive)
  spec/decode_tok_s_rep         speculative decode tok/s, repetitive trace
  spec/speedup_rep              ratio (derived = 1.5, the acceptance bar)
  spec/accept_rep               drafted-token acceptance rate, repetitive
  spec/tok_per_tick_rep         committed tokens per decode tick (slots*1
                                without speculation)
  spec/speedup_rand             speculative/non-speculative ratio, random
  spec/accept_rand              acceptance rate, random-ish trace

Both engines are pre-compiled (Engine.warmup covers decode, chunk and every
verify-width bucket) and timings are best-of-N with base/spec interleaved,
so rows measure steady-state dispatch and shared-host load hits both paths
alike.  Expected runtime: ~1 min on CPU.  REPRO_BENCH_FAST=1 shrinks the
trace to a smoke run of the same code paths.
"""

from __future__ import annotations

import os

import numpy as np

from repro import configs
from repro.serving.engine import Engine
from repro.serving.speculative import SpecConfig
from repro.tuning import env_truthy

FAST = env_truthy(os.environ.get("REPRO_BENCH_FAST"))

ARCH = "gemma3-1b"
SLOTS = 2
PROMPT_LEN = 12 if FAST else 16
GEN_LEN = 16 if FAST else 48
N_REQ = 4 if FAST else 8
ITERS = 2 if FAST else 3
DRAFT_K = 6
BAR_REP = 1.5


def _decode_span(eng, prompts, gen_len):
    """Submit prompts, run to completion; returns (tokens, seconds) spent in
    decode ticks (prefill excluded — the mechanism under test is decode)."""
    t0_tok, t0_t = eng.metrics.decode_tokens, eng.metrics.decode_time_s
    for p in prompts:
        eng.submit(p, max_new=gen_len)
    eng.run()
    return (eng.metrics.decode_tokens - t0_tok,
            eng.metrics.decode_time_s - t0_t)


def run():
    cfg = configs.get_smoke(ARCH)
    max_seq = PROMPT_LEN + GEN_LEN + 1
    rng = np.random.default_rng(0)
    template = rng.integers(0, cfg.vocab, size=PROMPT_LEN).astype(np.int32)
    # repetitive: the same prompt every request AND every iteration — the
    # corpus keeps matching.  random-ish: fresh prompts each iteration, so
    # the corpus never helps and drafts come only from per-request n-grams.
    traces = {
        "rep": lambda it: [template] * N_REQ,
        "rand": lambda it: [
            rng.integers(0, cfg.vocab, size=PROMPT_LEN).astype(np.int32)
            for _ in range(N_REQ)],
    }

    import jax
    from repro.models import model as M

    params = M.init_model(jax.random.PRNGKey(0), cfg)
    spec_cfg = SpecConfig(k=DRAFT_K)

    def engines():
        base = Engine(cfg, params=params, slots=SLOTS, max_seq=max_seq,
                      block_size=16, max_chunk=16)
        spec = Engine(cfg, params=params, slots=SLOTS, max_seq=max_seq,
                      block_size=16, max_chunk=16, speculative=spec_cfg)
        base.warmup()
        spec.warmup()
        return base, spec

    out = {}
    for trace, make_prompts in traces.items():
        # Fresh engines per trace so the drafter corpus and metrics are
        # trace-local; base/spec interleaved per iteration so host load
        # spikes hit both alike.
        base, spec = engines()
        b_best = s_best = 0.0
        for it in range(ITERS):
            prompts = make_prompts(it)
            tok, sec = _decode_span(base, prompts, GEN_LEN)
            b_best = max(b_best, tok / sec if sec else 0.0)
            tok, sec = _decode_span(spec, prompts, GEN_LEN)
            s_best = max(s_best, tok / sec if sec else 0.0)
        m = spec.metrics
        out[trace] = {
            "base": b_best, "spec": s_best,
            "speedup": s_best / b_best if b_best else 0.0,
            "accept": m.acceptance_rate,
            "tok_per_tick": m.decode_tok_per_tick,
        }
        assert m.cold_compiles == 0, "warmup missed a verify bucket"

    rep, rand = out["rep"], out["rand"]
    return [
        {"name": "spec/decode_tok_s_base",
         "value": round(rep["base"], 1), "derived": ""},
        {"name": "spec/decode_tok_s_rep",
         "value": round(rep["spec"], 1), "derived": round(rep["base"], 1)},
        {"name": "spec/speedup_rep",
         "value": round(rep["speedup"], 2), "derived": BAR_REP},
        {"name": "spec/accept_rep",
         "value": round(rep["accept"], 3), "derived": ""},
        {"name": "spec/tok_per_tick_rep",
         "value": round(rep["tok_per_tick"], 2), "derived": SLOTS},
        {"name": "spec/speedup_rand",
         "value": round(rand["speedup"], 2),
         "derived": "no bar: misses must be cheap, not winning"},
        {"name": "spec/accept_rand",
         "value": round(rand["accept"], 3), "derived": ""},
    ]


def rows():
    return run()


if __name__ == "__main__":
    print("name,value,derived")
    for r in rows():
        print(f"{r['name']},{r['value']},{r['derived']}")
