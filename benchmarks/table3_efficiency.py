"""Table 3 / Sec 4.4 reproduction: peak performance, efficiency, and the
derived system metrics of the case-study OpenGeMM instance.

Paper artifact: Table 3 and the Sec. 4.4 efficiency figures.  Paper:
204.8 GOPS peak (8x8x8 @ 200 MHz), 0.531 mm^2 cell / 0.62 mm^2 P&R area,
43.8 mW on (32,32,32) block GeMM, 4.68 TOPS/W, 329 GOPS/mm^2,
7.55 TOPS/W/mm^2.  Peak numbers are analytic; power/area are technology
constants we take from the paper (no synthesis here) — what we *reproduce*
is every derived metric being consistent with the utilization model.

Output rows (CSV via benchmarks/run.py): table3/<metric> with the paper's
reference value in `derived`.  Expected runtime: <5 s.
"""

from __future__ import annotations

from repro.core.dataflow import GemmShape
from repro.core.generator import OpenGeMMConfig
from repro.core.simulator import OpenGeMMSimulator

POWER_W = 0.0438          # paper Sec 4.4, (32,32,32) workload @ 200 MHz
AREA_PNR_MM2 = 0.62       # paper Table 3
AREA_CELL_MM2 = 0.531     # paper Sec 4.4


def run():
    cfg = OpenGeMMConfig()
    sim = OpenGeMMSimulator(cfg)
    peak_gops = cfg.peak_gops()
    rep = sim.report([GemmShape(32, 32, 32)] * 10)
    eff_gops = rep.gops()
    return {
        "peak_gops": peak_gops,
        "spm_kib": cfg.spm_bytes / 1024,
        "sustained_gops_32cubed": eff_gops,
        "tops_per_w_peak": peak_gops / 1e3 / POWER_W,
        "tops_per_w_sustained": eff_gops / 1e3 / POWER_W,
        "gops_per_mm2": peak_gops / AREA_PNR_MM2,
        "ops_area_eff": peak_gops / 1e3 / POWER_W / AREA_PNR_MM2,
    }


def rows():
    r = run()
    paper = {
        "peak_gops": 204.8, "spm_kib": 270 * 1024 / 1024,
        "tops_per_w_peak": 4.68, "gops_per_mm2": 329, "ops_area_eff": 7.55,
    }
    out = []
    for k, v in r.items():
        out.append({
            "name": f"table3/{k}", "value": round(v, 3),
            "derived": f"paper={paper.get(k, 'n/a')}",
        })
    return out


if __name__ == "__main__":
    for row in rows():
        print(f"{row['name']:32s} {row['value']:>10} ({row['derived']})")
