"""Table 3 / Sec 4.4 reproduction: peak performance, efficiency, and the
derived system metrics of the case-study OpenGeMM instance.

Paper artifact: Table 3 and the Sec. 4.4 efficiency figures.  Paper:
204.8 GOPS peak (8x8x8 @ 200 MHz), 0.531 mm^2 cell / 0.62 mm^2 P&R area,
43.8 mW on (32,32,32) block GeMM, 4.68 TOPS/W, 329 GOPS/mm^2,
7.55 TOPS/W/mm^2.  Peak numbers are analytic; power/area are technology
constants we take from the paper (no synthesis here) — what we *reproduce*
is every derived metric being consistent with the utilization model.

The 4.68 TOPS/W headline presumes the int8 datapath (P_A=P_B=8): every MAC
is an int8 MAC.  The `int8_gemm_speedup_host` row ties that presumption to
this reproduction by *measuring* the int8-vs-f32 GeMM wall-clock ratio of
the deployment path (ops.gemm_w8a8, the same kernel the w8a8 serving engine
dispatches) on the local host — int8 wins on TPU MXUs (the paper's regime,
and the regime the efficiency figures assume), while CPU hosts typically
show < 1 (XLA's CPU int8 matmul is not VNNI-tuned); the row keeps the
number honest either way.

Output rows (CSV via benchmarks/run.py): table3/<metric> with the paper's
reference value in `derived`.  Expected runtime: <15 s.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core.dataflow import GemmShape
from repro.core.generator import OpenGeMMConfig
from repro.core.simulator import OpenGeMMSimulator
from repro.tuning import env_truthy

POWER_W = 0.0438          # paper Sec 4.4, (32,32,32) workload @ 200 MHz
AREA_PNR_MM2 = 0.62       # paper Table 3
AREA_CELL_MM2 = 0.531     # paper Sec 4.4

# Host-measurement GeMM extent (square problem); REPRO_BENCH_FAST shrinks
# it so `make bench-smoke` exercises the path without the full timing.
_FAST = env_truthy(os.environ.get("REPRO_BENCH_FAST"))
GEMM_MKN = 128 if _FAST else 512


def measure_int8_speedup(n: int = GEMM_MKN, iters: int = 2 if _FAST else 5) -> float:
    """Wall-clock f32-GeMM / w8a8-GeMM ratio on this host (>1: int8 wins)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    wq, sw = ref.quantize_ref(w, axis=0)

    f32 = jax.jit(lambda a, b: ops.gemm(a, b, backend="xla"))
    w8a8 = jax.jit(lambda a, bq, s: ops.gemm_w8a8(a, bq, s, backend="xla"))

    def best(fn, *args):
        fn(*args).block_until_ready()          # compile + warm
        t = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn(*args).block_until_ready()
            t = min(t, time.perf_counter() - t0)
        return t

    return best(f32, x, w) / best(w8a8, x, wq, sw.reshape(1, -1))


def run():
    cfg = OpenGeMMConfig()
    sim = OpenGeMMSimulator(cfg)
    peak_gops = cfg.peak_gops()
    rep = sim.report([GemmShape(32, 32, 32)] * 10)
    eff_gops = rep.gops()
    return {
        "peak_gops": peak_gops,
        "spm_kib": cfg.spm_bytes / 1024,
        "sustained_gops_32cubed": eff_gops,
        "tops_per_w_peak": peak_gops / 1e3 / POWER_W,
        "tops_per_w_sustained": eff_gops / 1e3 / POWER_W,
        "gops_per_mm2": peak_gops / AREA_PNR_MM2,
        "ops_area_eff": peak_gops / 1e3 / POWER_W / AREA_PNR_MM2,
    }


def rows():
    r = run()
    paper = {
        "peak_gops": 204.8, "spm_kib": 270 * 1024 / 1024,
        "tops_per_w_peak": 4.68, "gops_per_mm2": 329, "ops_area_eff": 7.55,
    }
    out = []
    for k, v in r.items():
        out.append({
            "name": f"table3/{k}", "value": round(v, 3),
            "derived": f"paper={paper.get(k, 'n/a')}",
        })
    out.append({
        "name": "table3/int8_gemm_speedup_host",
        "value": round(measure_int8_speedup(), 3),
        "derived": "paper=int8 datapath assumed by 4.68 TOPS/W (>1 on MXU)",
    })
    return out


if __name__ == "__main__":
    for row in rows():
        print(f"{row['name']:32s} {row['value']:>10} ({row['derived']})")
