"""Fig. 7 reproduction: area-normalized throughput (GOPS/mm^2) of OpenGeMM
vs the Gemmini OS/WS cycle model, matrix sizes (8,8,8)..(128,128,128).

Paper artifact: Fig. 7 (Sec. 4.5).  Paper claims: 3.75x-16.40x vs Gemmini
OS, 3.58x-15.66x vs WS; Gemmini avg temporal utilization ~6.25% on these
sizes [32].

Output rows (CSV via benchmarks/run.py):
  fig7/<size>/opengemm_gops_mm2   absolute GOPS/mm^2
  fig7/<size>/speedup_vs_{os,ws}  ratio vs the Gemmini variant

Expected runtime: <5 s.  See EXPERIMENTS.md for the Gemmini model's pinning
to the measured ~6% utilization regime.
"""

from __future__ import annotations

from repro.core.dataflow import GemmShape
from repro.core.gemmini_model import GemminiConfig, GemminiModel
from repro.core.simulator import OpenGeMMSimulator

SIZES = [8, 16, 24, 32, 48, 64, 96, 128]
OPENGEMM_AREA_MM2 = 0.62   # paper Table 3 (after P&R estimate)
OPENGEMM_FREQ = 200e6


def run():
    sim = OpenGeMMSimulator()
    os_model = GemminiModel(GemminiConfig(weight_stationary=False))
    ws_model = GemminiModel(GemminiConfig(weight_stationary=True))
    out = []
    for s in SIZES:
        g = GemmShape(s, s, s)
        rep = sim.report([g] * 10)
        og_gops_mm2 = rep.gops(OPENGEMM_FREQ) / OPENGEMM_AREA_MM2
        r = {
            "size": s,
            "opengemm": og_gops_mm2,
            "gemmini_os": os_model.gops_per_mm2(g),
            "gemmini_ws": ws_model.gops_per_mm2(g),
            "gemmini_os_tu": os_model.temporal_utilization(g),
            "gemmini_ws_tu": ws_model.temporal_utilization(g),
        }
        r["speedup_os"] = r["opengemm"] / r["gemmini_os"]
        r["speedup_ws"] = r["opengemm"] / r["gemmini_ws"]
        out.append(r)
    return out


def summary():
    rs = run()
    so = [r["speedup_os"] for r in rs]
    sw = [r["speedup_ws"] for r in rs]
    tus = [r["gemmini_ws_tu"] for r in rs] + [r["gemmini_os_tu"] for r in rs]
    return {
        "speedup_os_min": min(so), "speedup_os_max": max(so),
        "speedup_ws_min": min(sw), "speedup_ws_max": max(sw),
        "gemmini_avg_tu": sum(tus) / len(tus),
    }


def rows():
    s = summary()
    return [
        {"name": "fig7/speedup_os", "value": f"{s['speedup_os_min']:.2f}-{s['speedup_os_max']:.2f}",
         "derived": "paper=3.75-16.40"},
        {"name": "fig7/speedup_ws", "value": f"{s['speedup_ws_min']:.2f}-{s['speedup_ws_max']:.2f}",
         "derived": "paper=3.58-15.66"},
        {"name": "fig7/gemmini_avg_tu", "value": round(s["gemmini_avg_tu"], 4),
         "derived": "paper~=0.0625"},
    ]


if __name__ == "__main__":
    print(f"{'size':>5s} {'OpenGeMM':>10s} {'Gem-OS':>8s} {'Gem-WS':>8s} "
          f"{'spd-OS':>7s} {'spd-WS':>7s}  (GOPS/mm^2)")
    for r in run():
        print(f"{r['size']:5d} {r['opengemm']:10.1f} {r['gemmini_os']:8.1f} "
              f"{r['gemmini_ws']:8.1f} {r['speedup_os']:6.2f}x {r['speedup_ws']:6.2f}x")
    s = summary()
    print(f"\nspeedup ranges: OS {s['speedup_os_min']:.2f}-{s['speedup_os_max']:.2f}x "
          f"(paper 3.75-16.40), WS {s['speedup_ws_min']:.2f}-{s['speedup_ws_max']:.2f}x "
          f"(paper 3.58-15.66); gemmini avg TU {s['gemmini_avg_tu']*100:.1f}% (paper ~6.25%)")
