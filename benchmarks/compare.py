"""Diff two machine-readable benchmark reports (BENCH_smoke.json).

Usage:
  python benchmarks/compare.py BASE.json HEAD.json [--tolerance 0.25]

Compares every numeric row shared by the two reports and prints one line
per row that moved beyond the tolerance (relative change), plus rows that
appeared or disappeared.  Exit code is 0 even when rows regress — CI runs
this as a *report* step, not a gate: smoke-mode numbers on shared runners
are too noisy to block merges on, but a 2x regression (or a vanished row)
should be visible in the job log, not discovered at the next full
`make bench`.  ``--fail-on-change`` flips it into a gate for local use.

Row direction is not assumed: the report prints the signed relative change
and lets the reader decide (a "regression" in a *_ms row is an increase;
in a *_tok_s row a decrease).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple


def load_rows(path: str) -> Tuple[Dict[str, object], dict]:
    with open(path) as f:
        report = json.load(f)
    rows = {}
    for section, body in report.get("sections", {}).items():
        for row in body.get("rows", []):
            rows[row["name"]] = row["value"]
    return rows, report


def as_number(v):
    if isinstance(v, bool):
        return None
    if isinstance(v, (int, float)):
        return float(v)
    try:
        return float(v)
    except (TypeError, ValueError):
        return None


def compare(base_rows, head_rows, tolerance: float):
    """Yields (kind, name, detail) for every difference worth printing."""
    for name in sorted(set(base_rows) | set(head_rows)):
        if name not in head_rows:
            yield "removed", name, f"was {base_rows[name]}"
            continue
        if name not in base_rows:
            yield "added", name, f"now {head_rows[name]}"
            continue
        b, h = as_number(base_rows[name]), as_number(head_rows[name])
        if b is None or h is None:
            if base_rows[name] != head_rows[name]:
                yield "changed", name, f"{base_rows[name]} -> {head_rows[name]}"
            continue
        if b == 0.0:
            if h != 0.0:
                yield "changed", name, f"{b} -> {h}"
            continue
        rel = (h - b) / abs(b)
        if abs(rel) > tolerance:
            yield "changed", name, f"{b} -> {h} ({rel:+.0%})"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("base", help="baseline BENCH_smoke.json")
    ap.add_argument("head", help="candidate BENCH_smoke.json")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative change below this is noise (default 0.25)")
    ap.add_argument("--fail-on-change", action="store_true",
                    help="exit 1 when any row moved beyond tolerance")
    args = ap.parse_args(argv)

    base_rows, base_report = load_rows(args.base)
    head_rows, head_report = load_rows(args.head)
    diffs = list(compare(base_rows, head_rows, args.tolerance))
    n_num = sum(1 for n in base_rows if as_number(base_rows[n]) is not None)
    print(f"compared {len(set(base_rows) & set(head_rows))} shared rows "
          f"({n_num} numeric in base), tolerance {args.tolerance:.0%}")
    for section, body in head_report.get("sections", {}).items():
        base_s = base_report.get("sections", {}).get(section, {})
        if base_s.get("seconds") and body.get("seconds"):
            print(f"  # {section}: {base_s['seconds']}s -> {body['seconds']}s")
    if not diffs:
        print("no rows moved beyond tolerance")
        return 0
    for kind, name, detail in diffs:
        print(f"  {kind:8s} {name}: {detail}")
    if head_report.get("errors"):
        print(f"head report has section errors: {head_report['errors']}")
    return 1 if args.fail_on_change else 0


if __name__ == "__main__":
    sys.exit(main())
