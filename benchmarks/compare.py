"""Diff two machine-readable benchmark reports (BENCH_smoke.json).

Usage:
  python benchmarks/compare.py BASE.json HEAD.json [--fail-on-change]

Compares every row shared by the two reports and prints one line per row
that moved beyond its tolerance, plus rows that appeared or disappeared.

With ``--fail-on-change`` (how CI runs it) the comparison is a *gate*:
exit 1 when any **gating** difference exists.  What gates:

  * a numeric row moved beyond its per-row tolerance (the table below —
    wall-clock rows get wide tolerances because shared-runner noise is
    routinely 2-3x; deterministic counters/ratios stay tight);
  * a row present in the baseline vanished (a silently-dropped benchmark
    is itself a regression).  Rows *added* by the head report never gate —
    that is just a PR growing coverage;
  * the head report recorded section errors (a section that crashed must
    not pass by producing no rows).

What never gates, but is still printed:

  * rows marked **informational** — ``value == "informational"`` (how
    cluster_bench reports an unmeetable-bar row) or a ``derived`` field
    containing the word "informational" (how obs_bench marks its
    noise-dominated A/B overhead rows);
  * percentage-delta and NLL-delta rows (pure noise amplifiers: a µs-level
    wobble swings them across zero).

Row direction is not assumed: the report prints the signed relative change
and lets the reader decide (a "regression" in a *_ms row is an increase;
in a *_tok_s row a decrease).
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import sys
from typing import Dict, Optional, Tuple

# Per-row tolerance overrides, first fnmatch wins; None = informational
# (report-only, never gates).  Everything else gates at the --tolerance
# default.
PER_ROW_TOLERANCE: Tuple[Tuple[str, Optional[float]], ...] = (
    ("*overhead_pct", None),       # (on-off)/off of two µs-scale timings
    ("*nll_delta", None),          # tiny float deltas wobble across zero
    ("*reduction*", None),         # percentage-of-timing rows
    ("*_ns", 3.0),                 # wall-clock rows: shared CI runners
    ("*_us", 3.0),                 # routinely jitter 2-3x between runs;
    ("*_us_*", 3.0),               # gate only on catastrophic blowups
    ("*_ms", 3.0),
    ("*tok_s*", 2.0),
    ("*speedup*", 1.0),
    ("sched/preemptions", 0.5),    # tick-driven, but batch-finish timing
                                   # can shift a victim count by one

    ("*trace_events", 0.5),        # tick counts wobble with scheduling
)


def tolerance_for(name: str, default: float) -> Optional[float]:
    for pat, tol in PER_ROW_TOLERANCE:
        if fnmatch.fnmatch(name, pat):
            return tol
    return default


def is_informational(row: Optional[dict]) -> bool:
    if not isinstance(row, dict):
        return False
    if row.get("value") == "informational":
        return True
    return "informational" in str(row.get("derived", ""))


def load_rows(path: str) -> Tuple[Dict[str, dict], dict]:
    with open(path) as f:
        report = json.load(f)
    rows = {}
    for section, body in report.get("sections", {}).items():
        for row in body.get("rows", []):
            rows[row["name"]] = row
    return rows, report


def as_number(v):
    if isinstance(v, bool):
        return None
    if isinstance(v, (int, float)):
        return float(v)
    try:
        return float(v)
    except (TypeError, ValueError):
        return None


def compare(base_rows: Dict[str, dict], head_rows: Dict[str, dict],
            tolerance: float):
    """Yields (kind, name, detail, gates) for every difference worth
    printing; `gates` is True when the difference should fail a gating
    run."""
    for name in sorted(set(base_rows) | set(head_rows)):
        base, head = base_rows.get(name), head_rows.get(name)
        info = is_informational(base) or is_informational(head)
        if head is None:
            yield "removed", name, f"was {base['value']}", not info
            continue
        if base is None:
            # new coverage, not a regression
            yield "added", name, f"now {head['value']}", False
            continue
        tol = tolerance_for(name, tolerance)
        exempt = info or tol is None
        b, h = as_number(base["value"]), as_number(head["value"])
        if b is None or h is None:
            if base["value"] != head["value"]:
                yield ("changed", name,
                       f"{base['value']} -> {head['value']}", not exempt)
            continue
        if b == 0.0:
            if h != 0.0:
                yield "changed", name, f"{b} -> {h}", not exempt
            continue
        rel = (h - b) / abs(b)
        # informational rows still print past the default tolerance so big
        # moves stay visible in the log — they just never gate
        print_tol = tol if tol is not None else tolerance
        if abs(rel) > print_tol:
            yield "changed", name, f"{b} -> {h} ({rel:+.0%})", not exempt


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("base", help="baseline BENCH_smoke.json")
    ap.add_argument("head", help="candidate BENCH_smoke.json")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="default relative tolerance for rows without a "
                         "per-row override (default 0.25)")
    ap.add_argument("--fail-on-change", action="store_true",
                    help="gate: exit 1 on any gating difference (beyond-"
                         "tolerance move, removed row, head section error)")
    args = ap.parse_args(argv)

    base_rows, base_report = load_rows(args.base)
    head_rows, head_report = load_rows(args.head)
    diffs = list(compare(base_rows, head_rows, args.tolerance))
    n_num = sum(1 for n in base_rows
                if as_number(base_rows[n]["value"]) is not None)
    print(f"compared {len(set(base_rows) & set(head_rows))} shared rows "
          f"({n_num} numeric in base), default tolerance "
          f"{args.tolerance:.0%}")
    for section, body in head_report.get("sections", {}).items():
        base_s = base_report.get("sections", {}).get(section, {})
        if base_s.get("seconds") and body.get("seconds"):
            print(f"  # {section}: {base_s['seconds']}s -> {body['seconds']}s")
    gating = [d for d in diffs if d[3]]
    for kind, name, detail, gates in diffs:
        mark = "" if gates else " [non-gating]"
        print(f"  {kind:8s} {name}: {detail}{mark}")
    errors = head_report.get("errors")
    if errors:
        print(f"head report has section errors: {errors}")
    if not diffs and not errors:
        print("no rows moved beyond tolerance")
        return 0
    if args.fail_on_change and (gating or errors):
        print(f"FAIL: {len(gating)} gating difference(s)"
              + (f", {len(errors)} section error(s)" if errors else ""))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
