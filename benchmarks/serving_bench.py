"""Serving engine benchmark: chunked prefill vs token-by-token, and
engine decode throughput.

Paper artifact: none directly — this measures the serving-path analogues of
the paper's mechanisms (EXPERIMENTS.md §Serving).  The headline row is the
wall-clock prefill speedup of the engine's chunked prefill over the legacy
token-by-token loop (decode steps over a padded batch) at prompt length 64
on the dense smoke arch; the acceptance bar is >= 2x.

Output rows (CSV via benchmarks/run.py):
  serving/prefill_speedup_p64   chunked-vs-token-by-token wall-clock ratio
                                (derived column = 2.0, the acceptance bar)
  serving/prefill_ms_p64        chunked prefill wall-clock, ms (derived =
                                the token-by-token baseline's ms)
  serving/decode_tok_s          aggregate decode throughput, tokens/s

Both paths run on pre-compiled steps (the engine via Engine.warmup(), the
baseline via warm_token_by_token) and each is timed best-of-5, so the
ratio measures steady-state step-count/batching effects, not compile time
or shared-host noise.  Typical result 2.3-2.9x.

Expected runtime: ~60 s on CPU (dominated by warmup compiles).
"""

from __future__ import annotations

import numpy as np

from repro import configs
from repro.launch.serve import compare_prefill
from repro.serving.engine import Engine

ARCH = "gemma3-1b"
PROMPT_LEN = 64
SLOTS = 4
GEN_LEN = 16


def run():
    cfg = configs.get_smoke(ARCH)
    max_seq = PROMPT_LEN + GEN_LEN + 1
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=PROMPT_LEN).astype(np.int32)
               for _ in range(SLOTS)]

    t_legacy, t_chunked = compare_prefill(
        cfg, None, prompts, slots=SLOTS, max_seq=max_seq, block_size=16,
        max_chunk=64, iters=5)

    # decode throughput over a fresh engine (full gen lengths)
    eng2 = Engine(cfg, slots=SLOTS, max_seq=max_seq, block_size=16,
                  max_chunk=64)
    eng2.warmup()
    for p in prompts:
        eng2.submit(p, max_new=GEN_LEN)
    eng2.run()

    return [
        {"name": f"serving/prefill_speedup_p{PROMPT_LEN}",
         "value": round(t_legacy / t_chunked, 2), "derived": 2.0},
        {"name": f"serving/prefill_ms_p{PROMPT_LEN}",
         "value": round(t_chunked * 1e3, 1), "derived": round(t_legacy * 1e3, 1)},
        {"name": "serving/decode_tok_s",
         "value": round(eng2.metrics.throughput_tok_s, 1), "derived": ""},
    ]


def rows():
    return run()


if __name__ == "__main__":
    print("name,value,derived")
    for r in rows():
        print(f"{r['name']},{r['value']},{r['derived']}")
