"""Serving engine benchmark: chunked prefill vs token-by-token, engine
decode throughput, and float vs w8a8 (int8-resident) decode throughput.

Paper artifact: none directly — this measures the serving-path analogues of
the paper's mechanisms (EXPERIMENTS.md §Serving, §Quantization).  The
headline rows are the wall-clock prefill speedup of the engine's chunked
prefill over the legacy token-by-token loop (acceptance bar >= 2x at prompt
64 on the dense smoke arch) and the w8a8-vs-float decode-throughput delta
(the paper's int8 deployment precision carried through the serving stack).

Output rows (CSV via benchmarks/run.py):
  serving/prefill_speedup_p64   chunked-vs-token-by-token wall-clock ratio
                                (derived column = 2.0, the acceptance bar)
  serving/prefill_ms_p64        chunked prefill wall-clock, ms (derived =
                                the token-by-token baseline's ms)
  serving/decode_tok_s          float decode throughput, tokens/s
  serving/decode_tok_s_w8a8     w8a8 decode throughput, tokens/s (derived =
                                the float row: the delta the gate requires)
  serving/w8a8_decode_speedup   w8a8-vs-float decode-throughput ratio
                                (int8 datapath effect on this host)
  serving/w8a8_weight_savings   int8-resident weight-memory saving fraction
  serving/w8a8_nll_delta        end-to-end quality delta (quant NLL - float
                                NLL on held-out synthetic batches, via
                                quant/report.py)

All engines are pre-compiled (Engine.warmup) and decode timings are
best-of-N interleaved, so rows measure steady-state dispatch, not compiles
or shared-host noise.  NOTE: the w8a8 throughput ratio is *host-dependent* —
on CPU (xla int8 matmul) int8 usually loses to f32; on TPU the int8 MXU
path is the paper's regime.  The row exists to keep the number measured,
whatever it is.

Expected runtime: ~2 min on CPU (dominated by warmup compiles).
REPRO_BENCH_FAST=1 (or `benchmarks/run.py --fast` / `make bench-smoke`)
shrinks prompts/iterations to a smoke run of the same code paths.
"""

from __future__ import annotations

import os

import numpy as np

from repro import configs
from repro.launch.serve import compare_prefill
from repro.serving.engine import Engine
from repro.tuning import env_truthy

FAST = env_truthy(os.environ.get("REPRO_BENCH_FAST"))

ARCH = "gemma3-1b"
PROMPT_LEN = 16 if FAST else 64
SLOTS = 2 if FAST else 4
GEN_LEN = 8 if FAST else 16
ITERS = 2 if FAST else 5
MAX_CHUNK = 16 if FAST else 64


def _decode_run(eng, prompts, gen_len):
    """Submit all prompts, run to completion; returns decode-tick seconds."""
    t0_tokens, t0_time = eng.metrics.decode_tokens, eng.metrics.decode_time_s
    for p in prompts:
        eng.submit(p, max_new=gen_len)
    eng.run()
    return (eng.metrics.decode_tokens - t0_tokens,
            eng.metrics.decode_time_s - t0_time)


def _quality_rows(cfg):
    """Float-vs-w8a8 NLL on held-out synthetic batches (quant/report.py)."""
    import jax

    from repro import quant
    from repro.models import model as M

    params = M.init_model(jax.random.PRNGKey(0), cfg)
    qparams = quant.quantize_params(params, cfg=cfg)
    rng = np.random.default_rng(7)
    batches = []
    for _ in range(1 if FAST else 2):
        toks = rng.integers(0, cfg.vocab, size=(2, 32)).astype(np.int32)
        batches.append({"tokens": toks, "labels": np.roll(toks, -1, axis=1)})
    return quant.quality_delta(params, qparams, cfg, batches, mode="w8a8")


def run():
    cfg = configs.get_smoke(ARCH)
    max_seq = PROMPT_LEN + GEN_LEN + 1
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=PROMPT_LEN).astype(np.int32)
               for _ in range(SLOTS)]

    t_legacy, t_chunked = compare_prefill(
        cfg, None, prompts, slots=SLOTS, max_seq=max_seq, block_size=16,
        max_chunk=MAX_CHUNK, iters=ITERS)

    # float vs w8a8 decode throughput, engines interleaved per iteration so
    # host load spikes hit both alike
    f_eng = Engine(cfg, slots=SLOTS, max_seq=max_seq, block_size=16,
                   max_chunk=MAX_CHUNK)
    q_eng = Engine(cfg, slots=SLOTS, max_seq=max_seq, block_size=16,
                   max_chunk=MAX_CHUNK, precision="w8a8")
    f_eng.warmup()
    q_eng.warmup()
    f_best = q_best = 0.0
    for _ in range(ITERS):
        toks, secs = _decode_run(f_eng, prompts, GEN_LEN)
        f_best = max(f_best, toks / secs if secs else 0.0)
        toks, secs = _decode_run(q_eng, prompts, GEN_LEN)
        q_best = max(q_best, toks / secs if secs else 0.0)

    delta = _quality_rows(cfg)
    savings = (1.0 - q_eng.metrics.weight_bytes
               / max(q_eng.metrics.weight_bytes_float, 1))

    p = PROMPT_LEN
    return [
        {"name": f"serving/prefill_speedup_p{p}",
         "value": round(t_legacy / t_chunked, 2), "derived": 2.0},
        {"name": f"serving/prefill_ms_p{p}",
         "value": round(t_chunked * 1e3, 1), "derived": round(t_legacy * 1e3, 1)},
        {"name": "serving/decode_tok_s",
         "value": round(f_best, 1), "derived": ""},
        {"name": "serving/decode_tok_s_w8a8",
         "value": round(q_best, 1), "derived": round(f_best, 1)},
        {"name": "serving/w8a8_decode_speedup",
         "value": round(q_best / f_best, 3) if f_best else "",
         "derived": "host-dependent (int8 MXU on TPU)"},
        {"name": "serving/w8a8_weight_savings",
         "value": round(savings, 3), "derived": "~0.66 (int8 + f32 scales)"},
        {"name": "serving/w8a8_nll_delta",
         "value": round(delta["delta_nll"], 5),
         "derived": round(delta["float_nll"], 5)},
    ]


def rows():
    return run()


if __name__ == "__main__":
    print("name,value,derived")
    for r in rows():
        print(f"{r['name']},{r['value']},{r['derived']}")
