"""Aggregate dry-run JSON results into the EXPERIMENTS.md roofline table.

Paper artifact: none — this is the mesh-level scaling side of the ROADMAP.
Reads benchmarks/results/dryrun*/[*.json] written by `repro.launch.dryrun`
and emits one row per (arch, shape, mesh):

  roofline/<arch>/<shape>/<mesh>   MFU % (derived: bound + time breakdown)

Expected runtime: <1 s (pure aggregation; empty when no dry-run results
exist on disk).
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load(results_dir: str = RESULTS):
    out = []
    for fn in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(fn) as f:
            out.append(json.load(f))
    return out


def rows(results_dir: str = RESULTS):
    out = []
    for r in load(results_dir):
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        out.append({
            "name": name,
            "value": round(float(r["mfu"]) * 100, 2),
            "derived": (
                f"bound={r['bound']},compute_ms={float(r['compute_s'])*1e3:.1f},"
                f"mem_ms={float(r['memory_s'])*1e3:.1f},"
                f"coll_ms={float(r['collective_s'])*1e3:.1f},"
                f"useful={float(r['useful_flops_ratio']):.2f}"
            ),
        })
    return out


def markdown_table(results_dir: str = RESULTS) -> str:
    lines = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
        "| bound | useful/HLO | MFU % |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(results_dir):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {float(r['compute_s'])*1e3:.1f} | {float(r['memory_s'])*1e3:.1f} "
            f"| {float(r['collective_s'])*1e3:.1f} | {r['bound']} "
            f"| {float(r['useful_flops_ratio']):.2f} "
            f"| {float(r['mfu'])*100:.2f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    print(markdown_table(sys.argv[1] if len(sys.argv) > 1 else RESULTS))
