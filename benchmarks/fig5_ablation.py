"""Fig. 5 reproduction: utilization ablation over 500 random (M,K,N).

Paper artifact: Fig. 5 (Sec. 4.2) — overall-utilization box plots for the
four platform variants plus buffer-depth sweeps.  Paper claims (medians):
CPL 1.4x, +prefetch/buffering(D=2) 2.02x, +SMA 1.18x, all three 2.78x;
deeper buffers keep improving.  (Note the paper's per-mechanism medians
multiply to 3.34x, not 2.78x — box-plot medians don't compose; we report
both views.)

Output rows (CSV via benchmarks/run.py):
  fig5/<arch>          median overall utilization (derived: q1/q3)
  fig5/ratio_<mech>    median ratio vs the previous arch (derived: paper)

Expected runtime: ~30 s (500 shapes x 6 archs, closed-form model).
See EXPERIMENTS.md for the calibration of csr_cycles/bank_conflict_factor.
"""

from __future__ import annotations

import statistics

from repro.core.simulator import (
    OpenGeMMSimulator,
    ablation_architectures,
    random_fig5_shapes,
)

PAPER = {"cpl": 1.4, "buf": 2.02, "sma": 1.18, "overall": 2.78}


def run(count: int = 500, repeats: int = 10, seed: int = 0):
    shapes = random_fig5_shapes(count, seed)
    stats = {}
    for name, cfg in ablation_architectures().items():
        sim = OpenGeMMSimulator(cfg)
        utils = [sim.utilization(s, repeats=repeats) for s in shapes]
        utils.sort()
        n = len(utils)
        stats[name] = {
            "median": statistics.median(utils),
            "q1": utils[n // 4],
            "q3": utils[3 * n // 4],
            "min": utils[0],
            "max": utils[-1],
        }
    m = {k: v["median"] for k, v in stats.items()}
    ratios = {
        "cpl": m["arch2_cpl"] / m["arch1_baseline"],
        "buf": m["arch3_cpl_buf2"] / m["arch2_cpl"],
        "sma": m["arch4_all_buf2"] / m["arch3_cpl_buf2"],
        "overall": m["arch4_all_buf2"] / m["arch1_baseline"],
    }
    return stats, ratios


def rows():
    stats, ratios = run()
    out = []
    for name, s in stats.items():
        out.append({
            "name": f"fig5/{name}", "value": round(s["median"], 4),
            "derived": f"q1={s['q1']:.3f},q3={s['q3']:.3f}",
        })
    for k, v in ratios.items():
        out.append({
            "name": f"fig5/ratio_{k}", "value": round(v, 3),
            "derived": f"paper={PAPER[k]}",
        })
    return out


if __name__ == "__main__":
    stats, ratios = run()
    print("arch                    median   [q1, q3]")
    for name, s in stats.items():
        print(f"{name:22s}  {s['median']:.4f}  [{s['q1']:.3f}, {s['q3']:.3f}]")
    print("\nratio    ours   paper")
    for k, v in ratios.items():
        print(f"{k:8s} {v:.2f}x  {PAPER[k]}x")
