"""Decode-attention benchmark: block-table walking vs the gather baseline.

Paper artifact: Sec 3.3 (programmable strided memory access) applied to the
serving decode path.  The legacy path materializes every slot's cache view
with ``gather_kv`` — a (B, max_blocks * block_size, H, D) gather over the
*table extent* — before a dense softmax; the paged paths (the Pallas kernel
on TPU, the bounded ``while_loop`` fallback elsewhere) walk the block table
and touch only the lived-in blocks.  The gap is therefore widest exactly
where serving lives: long-context tables (large extent) at partial
occupancy (short active lengths).

This benchmark times the jitted decode-attention op itself (the per-tick
hot path; model projections excluded) on one long-context shape with the
active length far below the table extent:

  decode_attn/step_us_gather     µs per decode-attention call, gather path
                                 (derived: table-extent tokens it touches)
  decode_attn/step_us_paged      µs per call, paged path (auto-resolved:
                                 flash on TPU, blocked elsewhere; derived:
                                 the max active tokens it touches)
  decode_attn/speedup_paged      gather / paged ratio (derived = 1.0 — the
                                 bar: walking the table must not lose)
  decode_attn/decode_tok_s_paged tokens/s through the paged op at this
                                 shape (derived: same through gather)
  decode_attn/step_us_paged_int8 µs per call with the int8-resident pool
                                 (in-kernel/in-loop dequant)
  decode_attn/kv_pool_mib_int8   resident pool MiB, int8 (derived: float
                                 pool MiB for the same extent)

Expected runtime: ~20 s on CPU.  REPRO_BENCH_FAST=1 shrinks the extent —
same code paths, smoke-sized problem.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.tuning import env_truthy

FAST = env_truthy(os.environ.get("REPRO_BENCH_FAST"))

SLOTS = 4
HKV, GROUPS, D = 4, 2, 64
BLOCK_SIZE = 16
MAX_BLOCKS = 64 if FAST else 256          # table extent: 1k / 4k tokens
ACTIVE = 96 if FAST else 384              # live tokens per slot (partial)
ITERS = 5 if FAST else 20


def _setup(kv_precision="float"):
    import jax.numpy as jnp

    from repro.serving import kv_cache as kvc

    rng = np.random.default_rng(0)
    num_blocks = 1 + SLOTS * MAX_BLOCKS
    cache = kvc.init_paged_kv(num_blocks, BLOCK_SIZE, HKV, D, jnp.float32,
                              kv_precision=kv_precision)
    alloc = kvc.BlockAllocator(num_blocks, BLOCK_SIZE)
    tables = kvc.BlockTables(SLOTS, MAX_BLOCKS)
    for s in range(SLOTS):
        tables.ensure(s, ACTIVE, alloc)
    bt = tables.array()
    k_new = jnp.asarray(rng.normal(size=(SLOTS, ACTIVE, HKV, D)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(SLOTS, ACTIVE, HKV, D)), jnp.float32)
    cache = kvc.write_kv(cache, bt, k_new, v_new, 0)
    q = jnp.asarray(rng.normal(size=(SLOTS, 1, HKV * GROUPS, D)), jnp.float32)
    idx = jnp.full((SLOTS,), ACTIVE - 1, jnp.int32)
    return q, cache, bt, idx


def _time_backend(backend, setup, iters=ITERS):
    """Best-of-N seconds per jitted decode-attention call."""
    import jax

    from repro.kernels import flash_decode as fd

    q, cache, bt, idx = setup
    fn = jax.jit(lambda q, c, t, i: fd.paged_decode_attention(
        q, c, t, i, backend=backend))
    fn(q, cache, bt, idx).block_until_ready()     # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(q, cache, bt, idx).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    import jax

    from repro.kernels import flash_decode as fd
    from repro.serving import kv_cache as kvc

    setup_f = _setup("float")
    setup_q = _setup("int8")
    paged = fd._resolve_backend("auto")           # flash on TPU, else blocked
    t_gather = _time_backend("gather", setup_f)
    t_paged = _time_backend(paged, setup_f)
    t_paged_q = _time_backend(paged, setup_q)
    pool_f = kvc.pool_bytes(setup_f[1]) / 2**20
    pool_q = kvc.pool_bytes(setup_q[1]) / 2**20
    extent = MAX_BLOCKS * BLOCK_SIZE
    us = 1e6
    return [
        {"name": "decode_attn/step_us_gather",
         "value": round(t_gather * us, 1), "derived": f"{extent} tok extent"},
        {"name": f"decode_attn/step_us_paged[{paged}]",
         "value": round(t_paged * us, 1), "derived": f"{ACTIVE} tok active"},
        {"name": "decode_attn/speedup_paged",
         "value": round(t_gather / t_paged, 2), "derived": 1.0},
        {"name": "decode_attn/decode_tok_s_paged",
         "value": round(SLOTS / t_paged, 1),
         "derived": round(SLOTS / t_gather, 1)},
        {"name": "decode_attn/step_us_paged_int8",
         "value": round(t_paged_q * us, 1), "derived": ""},
        {"name": "decode_attn/kv_pool_mib_int8",
         "value": round(pool_q, 2), "derived": round(pool_f, 2)},
    ]


def rows():
    return run()


if __name__ == "__main__":
    print("name,value,derived")
    for r in rows():
        print(f"{r['name']},{r['value']},{r['derived']}")
