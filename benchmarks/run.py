"""Benchmark aggregator: one section per paper table/figure.

Prints ``name,value,derived`` CSV rows (the harness contract) — for
reproduction benchmarks `value` is the reproduced metric and `derived`
carries the paper's reference value.  Sections: fig5, table2, fig7, table3,
kernel (incl. autotuner deltas), decode_attn (paged decode attention vs the
gather baseline, incl. int8 KV), serving (incl. float-vs-w8a8), spec
(speculative decoding), sched (interactive p95 under batch load, FIFO vs
KV-swap preemption), cluster, obs (tracing overhead; also writes
BENCH_trace.json), plus roofline rows when dry-run results exist.  Expected runtime: ~2 min total on CPU; per-script details in each
module's docstring and EXPERIMENTS.md.

``--fast`` (= `make bench-smoke`, wired into CI) sets REPRO_BENCH_FAST=1
before any section imports: every section still runs its real code paths,
and the wall-clock-heavy ones (serving, spec, table3's host GeMM timing)
consume the flag to shrink their problems — the analytic sections (fig5,
table2, fig7, kernel) are already seconds-fast and run unchanged.
Benchmark rot thus fails CI instead of lurking until the next full
`make bench`.  Fast-mode numbers are smoke signals, not results.

Every section logs ``# begin <name>`` / ``# <name>: <seconds>s`` to stderr
as it runs, so a CI timeout is attributable to a section instead of to
"the benchmark step".  ``--json PATH`` additionally writes the rows as a
machine-readable report (per-section rows + wall-clock + errors); with
``--fast`` it defaults to BENCH_smoke.json, which CI uploads as an artifact
and benchmarks/compare.py diffs across runs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke run: same code paths, shrunken problems "
                         "(exports REPRO_BENCH_FAST=1)")
    ap.add_argument("--only", default=None,
                    help="run a single section (fig5|table2|fig7|table3|"
                         "kernel|decode_attn|serving|spec|sched|cluster|obs)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write a machine-readable report (default "
                         "BENCH_smoke.json with --fast; see "
                         "benchmarks/compare.py)")
    args = ap.parse_args(argv)
    if args.fast:
        os.environ["REPRO_BENCH_FAST"] = "1"
    # Default the report path only for a FULL fast run: `--only X --fast`
    # writing BENCH_smoke.json would silently replace a complete smoke
    # report with a one-section one (and compare.py would then report every
    # other section's rows as removed).
    json_path = args.json or (
        "BENCH_smoke.json" if args.fast and not args.only else None)
    from benchmarks import (
        cluster_bench,
        decode_bench,
        fig5_ablation,
        fig7_gemmini,
        kernel_bench,
        obs_bench,
        sched_bench,
        serving_bench,
        spec_bench,
        table2_dnn,
        table3_efficiency,
    )

    modules = [
        ("fig5", fig5_ablation),
        ("table2", table2_dnn),
        ("fig7", fig7_gemmini),
        ("table3", table3_efficiency),
        ("kernel", kernel_bench),
        ("decode_attn", decode_bench),
        ("serving", serving_bench),
        ("spec", spec_bench),
        ("sched", sched_bench),
        ("cluster", cluster_bench),
        ("obs", obs_bench),
    ]
    if args.only:
        modules = [(n, m) for n, m in modules if n == args.only]
        if not modules:
            raise SystemExit(f"unknown section {args.only!r}")
    print("name,value,derived")
    report = {"fast": bool(args.fast), "sections": {}, "errors": []}
    ok = True
    for name, mod in modules:
        print(f"# begin {name}", file=sys.stderr, flush=True)
        t0 = time.time()
        section_rows = []
        try:
            for row in mod.rows():
                print(f"{row['name']},{row['value']},{row['derived']}")
                section_rows.append({"name": row["name"], "value": row["value"],
                                     "derived": row["derived"]})
        except Exception as e:  # pragma: no cover
            ok = False
            print(f"{name}/ERROR,{e!r},", file=sys.stderr)
            report["errors"].append({"section": name, "error": repr(e)})
        dt = time.time() - t0
        print(f"# {name}: {dt:.1f}s", file=sys.stderr, flush=True)
        report["sections"][name] = {"seconds": round(dt, 2),
                                    "rows": section_rows}

    if not args.only:
        # roofline rows from any dry-run results present on disk
        try:
            from benchmarks import roofline_table
            for row in roofline_table.rows():
                print(f"{row['name']},{row['value']},{row['derived']}")
            opt = os.path.join(os.path.dirname(roofline_table.RESULTS), "dryrun_opt")
            for row in roofline_table.rows(opt):
                print(f"{row['name'].replace('roofline/', 'roofline-opt/')},"
                      f"{row['value']},{row['derived']}")
        except Exception:
            pass
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"# wrote {json_path}", file=sys.stderr)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
