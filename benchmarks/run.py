"""Benchmark aggregator: one section per paper table/figure.

Prints ``name,value,derived`` CSV rows (the harness contract) — for
reproduction benchmarks `value` is the reproduced metric and `derived`
carries the paper's reference value.  Sections: fig5, table2, fig7, table3,
kernel (incl. autotuner deltas), serving (incl. float-vs-w8a8), plus
roofline rows when dry-run results exist.  Expected runtime: ~2 min total
on CPU; per-script details in each module's docstring and EXPERIMENTS.md.

``--fast`` (= `make bench-smoke`, wired into CI) sets REPRO_BENCH_FAST=1
before any section imports: every section still runs its real code paths,
and the wall-clock-heavy ones (serving, table3's host GeMM timing) consume
the flag to shrink their problems — the analytic sections (fig5, table2,
fig7, kernel) are already seconds-fast and run unchanged.  Benchmark rot
thus fails CI instead of lurking until the next full `make bench`.
Fast-mode numbers are smoke signals, not results.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke run: same code paths, shrunken problems "
                         "(exports REPRO_BENCH_FAST=1)")
    ap.add_argument("--only", default=None,
                    help="run a single section (fig5|table2|fig7|table3|"
                         "kernel|serving|cluster)")
    args = ap.parse_args(argv)
    if args.fast:
        os.environ["REPRO_BENCH_FAST"] = "1"
    from benchmarks import (
        cluster_bench,
        fig5_ablation,
        fig7_gemmini,
        kernel_bench,
        serving_bench,
        table2_dnn,
        table3_efficiency,
    )

    modules = [
        ("fig5", fig5_ablation),
        ("table2", table2_dnn),
        ("fig7", fig7_gemmini),
        ("table3", table3_efficiency),
        ("kernel", kernel_bench),
        ("serving", serving_bench),
        ("cluster", cluster_bench),
    ]
    if args.only:
        modules = [(n, m) for n, m in modules if n == args.only]
        if not modules:
            raise SystemExit(f"unknown section {args.only!r}")
    print("name,value,derived")
    ok = True
    for name, mod in modules:
        t0 = time.time()
        try:
            for row in mod.rows():
                print(f"{row['name']},{row['value']},{row['derived']}")
        except Exception as e:  # pragma: no cover
            ok = False
            print(f"{name}/ERROR,{e!r},", file=sys.stderr)
        print(f"# {name}: {time.time()-t0:.1f}s", file=sys.stderr)

    if args.only:     # --only means *only*: no roofline fall-through rows
        if not ok:
            raise SystemExit(1)
        return
    # roofline rows from any dry-run results present on disk
    try:
        from benchmarks import roofline_table
        for row in roofline_table.rows():
            print(f"{row['name']},{row['value']},{row['derived']}")
        opt = os.path.join(os.path.dirname(roofline_table.RESULTS), "dryrun_opt")
        for row in roofline_table.rows(opt):
            print(f"{row['name'].replace('roofline/', 'roofline-opt/')},"
                  f"{row['value']},{row['derived']}")
    except Exception:
        pass
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
