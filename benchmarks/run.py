"""Benchmark aggregator: one section per paper table/figure.

Prints ``name,value,derived`` CSV rows (the harness contract) — for
reproduction benchmarks `value` is the reproduced metric and `derived`
carries the paper's reference value.  Sections: fig5, table2, fig7, table3,
kernel (incl. autotuner deltas), plus roofline rows when dry-run results
exist.  Expected runtime: ~1 min total on CPU; per-script details in each
module's docstring and EXPERIMENTS.md.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        fig5_ablation,
        fig7_gemmini,
        kernel_bench,
        serving_bench,
        table2_dnn,
        table3_efficiency,
    )

    modules = [
        ("fig5", fig5_ablation),
        ("table2", table2_dnn),
        ("fig7", fig7_gemmini),
        ("table3", table3_efficiency),
        ("kernel", kernel_bench),
        ("serving", serving_bench),
    ]
    print("name,value,derived")
    ok = True
    for name, mod in modules:
        t0 = time.time()
        try:
            for row in mod.rows():
                print(f"{row['name']},{row['value']},{row['derived']}")
        except Exception as e:  # pragma: no cover
            ok = False
            print(f"{name}/ERROR,{e!r},", file=sys.stderr)
        print(f"# {name}: {time.time()-t0:.1f}s", file=sys.stderr)

    # roofline rows from any dry-run results present on disk
    try:
        import os
        from benchmarks import roofline_table
        for row in roofline_table.rows():
            print(f"{row['name']},{row['value']},{row['derived']}")
        opt = os.path.join(os.path.dirname(roofline_table.RESULTS), "dryrun_opt")
        for row in roofline_table.rows(opt):
            print(f"{row['name'].replace('roofline/', 'roofline-opt/')},"
                  f"{row['value']},{row['derived']}")
    except Exception:
        pass
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
