"""Observability overhead benchmark (repro.obs).

Paper artifact: none — this guards the PR 8 acceptance bar that tracing is
cheap enough to leave on: the ring-buffer event path must cost < 2% of a
decode tick (ISSUE/EXPERIMENTS.md §Observability).  Rows:

  obs/event_ns            mean cost of one ring event (begin/end pair / 2):
                          a few scalar numpy stores, no allocation, no lock
  obs/decode_tick_us_off  mean decode-tick wall time, tracing off
                          (NULL_TRACER no-op dispatch)
  obs/decode_tick_us_on   same engine/workload with a live Tracer
  obs/decode_overhead_pct on-vs-off decode-tick delta (bar: < 2; can read
                          negative in the noise — both sides are ~µs)
  obs/trace_events        events the traced run exported

The traced run's Chrome-trace JSON is written to BENCH_trace.json at the
repo root — CI uploads it next to BENCH_smoke.json, so every smoke run
leaves an openable Perfetto timeline behind (README §Observability).

Methodology: both engines share one set of jitted steps (one compile for
the whole section) and replay the same seeded workload; each mode's tick
time is the best (min) mean over ITERS interleaved runs, so shared-host
load spikes hit both modes alike.  The per-event cost is measured directly
over a large event count — the analytic bound events-per-tick x event_ns
is what tests/test_obs.py asserts against the 2% bar (robust), while the
A/B wall-clock rows here are the informational measurement.

Expected runtime: ~30 s on CPU; REPRO_BENCH_FAST=1 shrinks the workload.
"""

from __future__ import annotations

import os
import time

FAST = os.environ.get("REPRO_BENCH_FAST", "").lower() not in ("", "0", "false")

N_EVENTS = 20_000 if FAST else 200_000
REQUESTS = 8 if FAST else 16
MAX_NEW = 12 if FAST else 24
SLOTS = 4
ITERS = 2 if FAST else 3

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRACE_PATH = os.path.join(ROOT, "BENCH_trace.json")


def _event_ns() -> float:
    """Direct ring-event cost: one begin/end pair per loop, halved."""
    from repro.obs import Tracer

    tr = Tracer(capacity=1 << 15)
    code = tr.intern("bench")
    # Touch the path once so interning/attribute caches are warm.
    tr.begin(code)
    tr.end(code)
    t0 = time.perf_counter_ns()
    for _ in range(N_EVENTS):
        tr.begin(code)
        tr.end(code)
    dt = time.perf_counter_ns() - t0
    return dt / (2.0 * N_EVENTS)


def _engine_rows():
    import jax
    import numpy as np

    from repro import configs
    from repro.models import model as M
    from repro.obs import write_chrome_trace
    from repro.serving.engine import Engine

    cfg = configs.get_smoke("gemma3-1b")
    max_seq = 64
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(4, 16)))
               for _ in range(REQUESTS)]

    warm = Engine(cfg, params=params, slots=SLOTS, max_seq=max_seq,
                  block_size=8, max_chunk=16)
    warm.warmup()

    def run(trace: bool):
        """One full serve of the workload; returns (mean tick µs, engine)."""
        eng = Engine(cfg, params=params, slots=SLOTS, max_seq=max_seq,
                     block_size=8, max_chunk=16, trace=trace)
        eng.share_steps_from(warm)
        eng.warmup()                    # hits warm's jit caches: no compiles
        for p in prompts:
            eng.submit(p, max_new=MAX_NEW)
        eng.run()
        m = eng.metrics
        tick_us = m.decode_time_s / max(1, m.decode_steps) * 1e6
        return tick_us, eng

    tick_off = tick_on = float("inf")
    traced = None
    for _ in range(ITERS):
        t, _e = run(trace=False)
        tick_off = min(tick_off, t)
        t, e = run(trace=True)
        if t < tick_on:
            tick_on, traced = t, e

    doc = write_chrome_trace(
        TRACE_PATH, [traced.tracer],
        metadata={"arch": cfg.name, "source": "benchmarks/obs_bench.py"})
    overhead_pct = (tick_on - tick_off) / tick_off * 100.0

    return [
        {"name": "obs/decode_tick_us_off",
         "value": round(tick_off, 1), "derived": ""},
        {"name": "obs/decode_tick_us_on",
         "value": round(tick_on, 1), "derived": round(tick_off, 1)},
        {"name": "obs/decode_overhead_pct",
         "value": round(overhead_pct, 2), "derived": "< 2"},
        {"name": "obs/trace_events",
         "value": len(doc["traceEvents"]),
         "derived": f"-> {os.path.basename(TRACE_PATH)}"},
    ]


def rows():
    out = [{"name": "obs/event_ns", "value": round(_event_ns(), 1),
            "derived": ""}]
    out += _engine_rows()
    return out


if __name__ == "__main__":
    print("name,value,derived")
    for r in rows():
        print(f"{r['name']},{r['value']},{r['derived']}")
