"""Observability overhead benchmark (repro.obs).

Paper artifact: none — this guards the acceptance bar that tracing is
cheap enough to leave on: the ring-buffer event path must cost < 2% of a
decode tick (EXPERIMENTS.md §Observability).  Rows:

  obs/event_ns             mean cost of one ring event (begin/end pair /
                           2): a few scalar numpy stores, no alloc, no lock
  obs/decode_tick_us_off   mean decode-tick wall time, tracing off
                           (NULL_TRACER no-op dispatch)
  obs/decode_tick_us_on    same engine/workload with a live Tracer but
                           flow events off (the pre-flow tracing baseline)
  obs/decode_tick_us_flow  live Tracer *with* per-request flow events and
                           instants (Engine default when tracing)
  obs/decode_overhead_pct  on-vs-off decode-tick delta (bar: < 2; can read
                           negative in the noise — both sides are ~µs)
  obs/flow_overhead_pct    flow-vs-on decode-tick delta: what the request-
                           flow arrows add over plain span tracing (same
                           < 2 bar, same noise caveat)
  obs/trace_events         events the flow-traced run exported
  obs/recorder_snapshot_us wall time of one FlightRecorder.trigger() on
                           the traced engine (ring snapshot + metric
                           sources + JSON write)
  obs/incident_bundles     bundles written into BENCH_incidents/

The flow-traced run's Chrome-trace JSON is written to BENCH_trace.json at
the repo root and its incident bundle into BENCH_incidents/ — CI uploads
both next to BENCH_smoke.json, so every smoke run leaves an openable
Perfetto timeline and a sample incident bundle behind (README
§Observability).

Methodology: all engines share one set of jitted steps (one compile for
the whole section) and replay the same seeded workload; each mode's tick
time is the best (min) mean over ITERS interleaved runs, so shared-host
load spikes hit all modes alike.  The per-event cost is measured directly
over a large event count — the analytic bound events-per-tick x event_ns
is what tests/test_obs.py asserts against the 2% bar (robust), while the
A/B wall-clock rows here are the informational measurement.

Expected runtime: ~45 s on CPU; REPRO_BENCH_FAST=1 shrinks the workload.
"""

from __future__ import annotations

import os
import time

FAST = os.environ.get("REPRO_BENCH_FAST", "").lower() not in ("", "0", "false")

N_EVENTS = 20_000 if FAST else 200_000
REQUESTS = 8 if FAST else 16
MAX_NEW = 12 if FAST else 24
SLOTS = 4
ITERS = 2 if FAST else 3

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRACE_PATH = os.path.join(ROOT, "BENCH_trace.json")
INCIDENT_DIR = os.path.join(ROOT, "BENCH_incidents")


def _event_ns() -> float:
    """Direct ring-event cost: one begin/end pair per loop, halved."""
    from repro.obs import Tracer

    tr = Tracer(capacity=1 << 15)
    code = tr.intern("bench")
    # Touch the path once so interning/attribute caches are warm.
    tr.begin(code)
    tr.end(code)
    t0 = time.perf_counter_ns()
    for _ in range(N_EVENTS):
        tr.begin(code)
        tr.end(code)
    dt = time.perf_counter_ns() - t0
    return dt / (2.0 * N_EVENTS)


def _engine_rows():
    import jax
    import numpy as np

    from repro import configs
    from repro.models import model as M
    from repro.obs import FlightRecorder, write_chrome_trace
    from repro.serving.engine import Engine

    cfg = configs.get_smoke("gemma3-1b")
    max_seq = 64
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(4, 16)))
               for _ in range(REQUESTS)]

    warm = Engine(cfg, params=params, slots=SLOTS, max_seq=max_seq,
                  block_size=8, max_chunk=16)
    warm.warmup()

    def run(trace: bool, flow: bool):
        """One full serve of the workload; returns (mean tick µs, engine)."""
        eng = Engine(cfg, params=params, slots=SLOTS, max_seq=max_seq,
                     block_size=8, max_chunk=16, trace=trace,
                     trace_flow=flow)
        eng.share_steps_from(warm)
        eng.warmup()                    # hits warm's jit caches: no compiles
        for p in prompts:
            eng.submit(p, max_new=MAX_NEW)
        eng.run()
        m = eng.metrics
        tick_us = m.decode_time_s / max(1, m.decode_steps) * 1e6
        return tick_us, eng

    tick_off = tick_on = tick_flow = float("inf")
    traced = None
    for _ in range(ITERS):
        t, _e = run(trace=False, flow=False)
        tick_off = min(tick_off, t)
        t, _e = run(trace=True, flow=False)
        tick_on = min(tick_on, t)
        t, e = run(trace=True, flow=True)
        if t < tick_flow:
            tick_flow, traced = t, e

    doc = write_chrome_trace(
        TRACE_PATH, [traced.tracer],
        metadata={"arch": cfg.name, "source": "benchmarks/obs_bench.py"})
    overhead_pct = (tick_on - tick_off) / tick_off * 100.0
    flow_pct = (tick_flow - tick_on) / tick_on * 100.0

    # Flight-recorder snapshot cost on the traced engine: full ring tail +
    # every standard metric source + the JSON write.
    rec = FlightRecorder(INCIDENT_DIR,
                         metadata={"source": "benchmarks/obs_bench.py"})
    rec.attach_engine(traced)
    t0 = time.perf_counter()
    rec.trigger("bench-smoke")
    snapshot_us = (time.perf_counter() - t0) * 1e6

    return [
        {"name": "obs/decode_tick_us_off",
         "value": round(tick_off, 1), "derived": ""},
        {"name": "obs/decode_tick_us_on",
         "value": round(tick_on, 1), "derived": round(tick_off, 1)},
        {"name": "obs/decode_tick_us_flow",
         "value": round(tick_flow, 1), "derived": round(tick_on, 1)},
        {"name": "obs/decode_overhead_pct",
         "value": round(overhead_pct, 2), "derived": "< 2 (informational)"},
        {"name": "obs/flow_overhead_pct",
         "value": round(flow_pct, 2), "derived": "< 2 (informational)"},
        {"name": "obs/trace_events",
         "value": len(doc["traceEvents"]),
         "derived": f"-> {os.path.basename(TRACE_PATH)}"},
        {"name": "obs/recorder_snapshot_us",
         "value": round(snapshot_us, 1), "derived": ""},
        {"name": "obs/incident_bundles",
         "value": len(rec.incidents),
         "derived": f"-> {os.path.basename(INCIDENT_DIR)}/"},
    ]


def rows():
    out = [{"name": "obs/event_ns", "value": round(_event_ns(), 1),
            "derived": ""}]
    out += _engine_rows()
    return out


if __name__ == "__main__":
    print("name,value,derived")
    for r in rows():
        print(f"{r['name']},{r['value']},{r['derived']}")
