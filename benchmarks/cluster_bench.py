"""Cluster serving benchmark: replica-pool throughput scaling and
prefix-cache TTFT savings (repro.cluster).

Paper artifact: none directly — this measures the *system-level* analogues
of the paper's mechanisms (EXPERIMENTS.md §Cluster).  The paper frames its
Gemmini comparison at system throughput, not core throughput; likewise the
headline rows here are cluster-vs-single-engine numbers:

  cluster/decode_tok_s_1r       single-engine throughput on the mixed-
                                traffic trace (generated tokens / wall)
  cluster/decode_tok_s_3r       3-replica pool, same trace, same host
                                (derived = the single-engine row)
  cluster/replica_speedup       pool / single ratio (derived column = 1.5,
                                the acceptance bar)
  cluster/prefix_hit_rate       prefix-cache hit rate on the shared-system-
                                prompt trace (bar: > 0)
  cluster/prefix_ttft_ms        mean TTFT with the prefix cache (derived =
                                mean TTFT without it, same trace)
  cluster/prefix_ttft_reduction 1 - cached/uncached mean TTFT
  cluster/prefix_reused_tokens  prompt tokens whose prefill was skipped

Methodology notes:

* The measurement runs in a **subprocess** with ``XLA_FLAGS`` pinning XLA's
  CPU intra-op pool to one thread.  Replicated serving on CPU wants
  core-per-replica isolation — one engine must not fan its tiny per-step
  ops across every core, or N replicas just fight over the same pool (the
  thread-level mirror of the paper's one-core-per-array design).  The flag
  applies to the single-engine baseline *and* the pool alike, so the
  comparison stays same-host, same-thread-pool — and the subprocess keeps
  the flag from leaking into other benchmark sections.
* Engines share one set of jitted step functions (same config, same
  shapes), so the whole benchmark compiles each step exactly once.
* Both scenarios replay seeded traces (cluster/traffic.py): rerunning the
  benchmark replays token-identical workloads.

Expected runtime: ~2-3 min on CPU (dominated by the one warmup compile).
REPRO_BENCH_FAST=1 (or ``benchmarks/run.py --fast`` / ``make bench-smoke``)
shrinks the model and traces to a smoke run of the same code paths.
"""

from __future__ import annotations

import os
import subprocess
import sys

_CHILD_ENV = "REPRO_CLUSTER_BENCH_CHILD"
# One intra-op thread per replica: see the module docstring.
_XLA_FLAGS = "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1"

FAST = os.environ.get("REPRO_BENCH_FAST", "").lower() not in ("", "0", "false")

# One replica per physical core: replication wins by *overlap* (one
# replica's host-side scheduling under another's device compute), so
# oversubscribing cores past the intra-op pool just thrashes — measured
# 1.57x at 2 replicas on a 2-core host vs 1.14x raw-step scaling at 3
# threads over the same 1-thread intra-op pool.
REPLICAS = max(2, min(4, (os.cpu_count() or 2)))
SLOTS = 4 if FAST else 8
D_MODEL = 128 if FAST else 256
N_MIXED = 16 if FAST else 48
MAX_PROMPT = 24 if FAST else 32
MAX_NEW = (6, 12) if FAST else (12, 24)
N_SHARED = 12 if FAST else 24
PREFIX_LEN = 32
ITERS = 2 if FAST else 3


def _serve_cfg():
    """The mixed-traffic serving config: the smoke arch widened so a decode
    step carries real compute (the d=64 smoke config is dispatch-bound and
    measures the GIL, not the engines)."""
    import dataclasses

    from repro import configs

    cfg0 = configs.get_smoke("gemma3-1b")
    return dataclasses.replace(
        cfg0, name=f"gemma3-serve-d{D_MODEL}", d_model=D_MODEL,
        d_ff=4 * D_MODEL, n_heads=4, n_kv_heads=2, head_dim=D_MODEL // 4)


def _mixed_rows(cfg, params, max_seq):
    """Single engine vs REPLICAS-pool on the same mixed-traffic trace."""
    import time

    from repro import cluster
    from repro.serving.engine import Engine

    trace = cluster.mixed_traffic(
        cfg.vocab, n=N_MIXED, seed=0, max_prompt=MAX_PROMPT, max_new=MAX_NEW)
    gen_total = trace.gen_tokens

    eng = Engine(cfg, params=params, slots=SLOTS, max_seq=max_seq,
                 block_size=16, max_chunk=32)
    eng.warmup()
    pool = cluster.ReplicaPool(cfg, REPLICAS, params=params, slots=SLOTS,
                               max_seq=max_seq, block_size=16, max_chunk=32)
    for r in pool.replicas:
        r.engine.share_steps_from(eng)
    pool.warmup()
    pool.start()

    def single_run():
        cluster.replay(trace, eng.submit)
        t0 = time.perf_counter()
        eng.run()
        return time.perf_counter() - t0

    def pool_run():
        router = cluster.Router(pool, policy="round-robin",
                                async_dispatch=False)
        t0 = time.perf_counter()
        handles, _ = cluster.replay(trace, router.submit)
        router.dispatch_sync()
        pool.drain(handles, timeout=300)
        return time.perf_counter() - t0

    # Interleave the two sides, best-of-ITERS each (the serving_bench
    # convention): shared-host load spikes hit both paths alike.
    t1 = tn = float("inf")
    for _ in range(ITERS):
        t1 = min(t1, single_run())
        tn = min(tn, pool_run())
    pool.stop()
    for e in [eng] + pool.engines:
        e.alloc.check()                      # no leaks across the runs

    ratio = round(t1 / tn, 2)
    cores = os.cpu_count() or 2
    if cores < 2 * REPLICAS:
        # Replica scaling needs ~2 cores per replica (device step + host
        # scheduling overlap); below that the measured ratio is host-
        # scheduler noise, not a regression signal.  Emit a *constant*
        # value so benchmarks/compare.py never flags run-to-run jitter of
        # an unmeetable bar, and park the measurement in `derived`.
        speedup_row = {
            "name": "cluster/replica_speedup", "value": "informational",
            "derived": f"{ratio}x on {cores} cores "
                       f"({2 * REPLICAS}+ needed for the 1.5x bar)"}
    else:
        speedup_row = {"name": "cluster/replica_speedup",
                       "value": ratio, "derived": 1.5}
    return [
        {"name": "cluster/decode_tok_s_1r",
         "value": round(gen_total / t1, 1), "derived": ""},
        {"name": f"cluster/decode_tok_s_{REPLICAS}r",
         "value": round(gen_total / tn, 1),
         "derived": round(gen_total / t1, 1)},
        speedup_row,
    ], eng


def _prefix_rows(cfg, params, max_seq, warm_engine):
    """Shared-system-prompt trace through one engine, cache off vs on."""
    import numpy as np

    from repro import cluster
    from repro.serving.engine import Engine

    trace = cluster.shared_system_prompt(
        cfg.vocab, n=N_SHARED, seed=1, prefix_len=PREFIX_LEN,
        suffix=(2, 8), max_new=(4, 8))

    def run(prefix_cache: bool):
        eng = Engine(cfg, params=params, slots=SLOTS, max_seq=max_seq,
                     block_size=16, max_chunk=32, prefix_cache=prefix_cache)
        eng.share_steps_from(warm_engine)
        eng.warmup()
        cluster.replay(trace, eng.submit)
        eng.run()
        eng.alloc.check()
        if eng.prefix_cache is not None:
            eng.prefix_cache.clear()
            eng.alloc.check()
            assert eng.alloc.in_use == 0    # fork/refcount leak guard
        m = eng.metrics
        ttft = float(np.mean([r.ttft_s for r in m.requests]))
        return ttft, m

    ttft_off, _ = run(prefix_cache=False)
    ttft_on, m_on = run(prefix_cache=True)

    return [
        {"name": "cluster/prefix_hit_rate",
         "value": round(m_on.prefix_hit_rate, 3), "derived": "> 0"},
        {"name": "cluster/prefix_ttft_ms",
         "value": round(ttft_on * 1e3, 1), "derived": round(ttft_off * 1e3, 1)},
        {"name": "cluster/prefix_ttft_reduction",
         "value": round(1.0 - ttft_on / ttft_off, 3) if ttft_off else "",
         "derived": ""},
        {"name": "cluster/prefix_reused_tokens",
         "value": m_on.prefix_hit_tokens,
         "derived": m_on.prefill_tokens},
    ]


def _child_rows():
    import jax

    from repro.models import model as M

    cfg = _serve_cfg()
    max_seq = MAX_PROMPT + MAX_NEW[1] + 1
    max_seq = max(max_seq, PREFIX_LEN + 8 + 8 + 1)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    mixed, warm_engine = _mixed_rows(cfg, params, max_seq)
    return mixed + _prefix_rows(cfg, params, max_seq, warm_engine)


def rows():
    if os.environ.get(_CHILD_ENV):
        return _child_rows()
    env = dict(os.environ)
    env[_CHILD_ENV] = "1"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + _XLA_FLAGS).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__)],
        capture_output=True, text=True, env=env, cwd=root, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"cluster bench child failed:\n{proc.stdout}\n{proc.stderr}")
    out = []
    for line in proc.stdout.splitlines():
        parts = line.rstrip("\n").split(",", 2)
        if len(parts) == 3 and parts[0].startswith("cluster/"):
            out.append({"name": parts[0], "value": parts[1],
                        "derived": parts[2]})
    if not out:
        raise RuntimeError(f"cluster bench child produced no rows:\n"
                           f"{proc.stdout}\n{proc.stderr}")
    return out


if __name__ == "__main__":
    print("name,value,derived")
    for r in rows():
        print(f"{r['name']},{r['value']},{r['derived']}")
