"""Kernel micro-benchmarks + autotuner delta.

Paper artifact: none directly — this is the framework's own hot-path
benchmark (the ROADMAP "hot path measurably faster" contract).  Every row
compares the hard-coded `tpu_kernel_spec` tile against the autotuned tile
for the same problem, so any kernel or tuner PR shows up as a delta here.

Interpret-mode timing is meaningless on CPU, so wall-clock is measured on
the XLA path, while the tile comparison reports the analytic cycle model's
prediction (repro.tuning.model — the same model the autotuner ranks with;
on a TPU host re-run with REPRO_AUTOTUNE=1 and mode="wallclock" for
measured numbers).

Output rows (CSV via benchmarks/run.py):
  kernel/gemm_MxKxN        wall-clock us/call on the XLA path
  kernel/tuned_MxKxN       predicted speedup of tuned vs default tile

Expected runtime: ~10 s on CPU.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.dataflow import GemmShape, arithmetic_intensity
from repro.core.generator import OpenGeMMConfig
from repro.kernels import ops
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW
from repro import tuning


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run():
    out = []
    cfg = OpenGeMMConfig()
    # Memory-only cache: the delta rows must reflect *this* checkout's
    # search, never stale winners from the user's persistent registry.
    tuner = tuning.Autotuner(cache=tuning.TuneCache(persistent=False),
                             persist=False)
    for mkn in [(512, 512, 512), (1024, 4096, 1024), (4096, 4096, 4096)]:
        g = GemmShape(*mkn)
        spec = cfg.tpu_kernel_spec(g)
        a = jnp.zeros((g.M, g.K), jnp.bfloat16)
        b = jnp.zeros((g.K, g.N), jnp.bfloat16)
        f = jax.jit(lambda a, b: ops.gemm(a, b, backend="xla"))
        dt = _time(f, a, b)
        # analytic TPU roofline for this GeMM at the generated tile spec
        t_c = g.flops / PEAK_FLOPS_BF16
        t_m = g.operand_bytes(16, 16, 32) / HBM_BW
        out.append({
            "name": f"kernel/gemm_{mkn[0]}x{mkn[1]}x{mkn[2]}",
            "value": round(dt * 1e6, 1),
            "derived": (
                f"tile=({spec.tm},{spec.tk},{spec.tn}),AI={arithmetic_intensity(g):.0f},"
                f"tpu_roofline_us={max(t_c, t_m)*1e6:.1f}"
            ),
        })
        # autotuner delta: default tile vs searched tile, same cycle model
        res = tuner.tune(g, "bfloat16")
        default_clk = tuning.predict_clocks(spec, g, "bfloat16")
        tuned_clk = tuning.predict_clocks(res.spec, g, "bfloat16")
        out.append({
            "name": f"kernel/tuned_{mkn[0]}x{mkn[1]}x{mkn[2]}",
            "value": round(default_clk / tuned_clk, 3),
            "derived": (
                f"default=({spec.tm},{spec.tk},{spec.tn}),"
                f"tuned=({res.spec.tm},{res.spec.tk},{res.spec.tn}),"
                f"candidates={res.candidates},pred_clk={tuned_clk:.0f}"
            ),
        })
    return out


def rows():
    return run()


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']:28s} {r['value']:>9}  {r['derived']}")
