"""Kernel micro-benchmarks: OpenGeMM Pallas kernel (interpret-mode
correctness timing is meaningless on CPU, so we benchmark the XLA path and
report the kernel's analytic VMEM/roofline characteristics per tile spec).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.dataflow import GemmShape, arithmetic_intensity
from repro.core.generator import OpenGeMMConfig
from repro.kernels import ops
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run():
    out = []
    cfg = OpenGeMMConfig()
    for mkn in [(512, 512, 512), (1024, 4096, 1024), (4096, 4096, 4096)]:
        g = GemmShape(*mkn)
        spec = cfg.tpu_kernel_spec(g)
        a = jnp.zeros((g.M, g.K), jnp.bfloat16)
        b = jnp.zeros((g.K, g.N), jnp.bfloat16)
        f = jax.jit(lambda a, b: ops.gemm(a, b, backend="xla"))
        dt = _time(f, a, b)
        # analytic TPU roofline for this GeMM at the generated tile spec
        t_c = g.flops / PEAK_FLOPS_BF16
        t_m = g.operand_bytes(16, 16, 32) / HBM_BW
        out.append({
            "name": f"kernel/gemm_{mkn[0]}x{mkn[1]}x{mkn[2]}",
            "value": round(dt * 1e6, 1),
            "derived": (
                f"tile=({spec.tm},{spec.tk},{spec.tn}),AI={arithmetic_intensity(g):.0f},"
                f"tpu_roofline_us={max(t_c, t_m)*1e6:.1f}"
            ),
        })
    return out


def rows():
    return run()


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']:28s} {r['value']:>9} us/call  {r['derived']}")
