"""Per-arch smoke tests (deliverable f) + decode/teacher-forcing consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M

ARCHS = configs.list_archs()


def make_batch(cfg, B=2, S=16, seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(k3, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(k3, (B, cfg.prefix_len, M.VISION_DIM))
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_forward_and_grad(name):
    """Reduced same-family config: one forward + train grad on CPU."""
    cfg = configs.get_smoke(name)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits = M.forward(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), name
    loss, grads = jax.value_and_grad(M.loss_fn)(params, cfg, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_decode_shapes(name):
    cfg = configs.get_smoke(name)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    B = 2
    enc = None
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.encoder_seq, cfg.d_model))
        enc = M._run_encoder(frames, params, cfg)
    state = M.init_decode_state(params, cfg, B, 24, encoder_out=enc)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, state = M.decode_step(params, cfg, state, tok)
    assert logits.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(state.index) == 1


@pytest.mark.parametrize("name", ["qwen3-14b", "gemma3-1b", "xlstm-1.3b",
                                  "jamba-1.5-large-398b", "dbrx-132b"])
def test_decode_matches_forward(name):
    """Teacher-forced decode must reproduce the training forward logits."""
    cfg = configs.get_smoke(name)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    batch = make_batch(cfg, B, S)
    ref_logits = np.asarray(M.forward(params, cfg, batch), np.float32)

    state = M.init_decode_state(params, cfg, B, S + 2)
    outs = []
    for t in range(S):
        lg, state = M.decode_step(params, cfg, state, batch["tokens"][:, t:t + 1])
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec_logits = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec_logits, ref_logits, rtol=2e-2, atol=2e-3)


def test_local_window_masks_long_range():
    """gemma3 local layers: token attends only within the window."""
    from repro.models.attention import blockwise_attention

    B, S, H, D = 1, 32, 2, 8
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (B, S, H, D))
    kk = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    out_w = blockwise_attention(q, kk, v, causal=True, window=4, block_kv=8)
    # perturb keys/values far outside the window of the last query
    kk2 = kk.at[:, :8].set(jax.random.normal(jax.random.PRNGKey(3), (B, 8, H, D)))
    v2 = v.at[:, :8].set(0.0)
    out_w2 = blockwise_attention(q, kk2, v2, causal=True, window=4, block_kv=8)
    np.testing.assert_allclose(out_w[:, -1], out_w2[:, -1], rtol=1e-5, atol=1e-6)


def test_blockwise_matches_dense_attention():
    """Online-softmax blockwise attention == dense softmax attention."""
    from repro.models.attention import blockwise_attention

    B, S, Hq, Hkv, D = 2, 24, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = blockwise_attention(q, k, v, causal=True, block_kv=8)

    # dense reference
    kr = jnp.repeat(k, Hq // Hkv, axis=2)
    vr = jnp.repeat(v, Hq // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * D ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    expect = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)


def test_prefix_lm_bidirectional_prefix():
    """VLM prefix tokens attend bidirectionally; suffix stays causal."""
    from repro.models.attention import blockwise_attention

    B, S, H, D = 1, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    out = blockwise_attention(q, k, v, causal=True, prefix_len=6, block_kv=4)
    # query 0 (inside prefix) must see key 5 (also prefix, in its "future"):
    v2 = v.at[:, 5].set(v[:, 5] + 10.0)
    out2 = blockwise_attention(q, k, v2, causal=True, prefix_len=6, block_kv=4)
    assert float(jnp.max(jnp.abs(out2[:, 0] - out[:, 0]))) > 1e-4
    # but a suffix key in the future of a suffix query stays hidden:
    v3 = v.at[:, 15].set(v[:, 15] + 10.0)
    out3 = blockwise_attention(q, k, v3, causal=True, prefix_len=6, block_kv=4)
    np.testing.assert_allclose(out3[:, 10], out[:, 10], rtol=1e-6)


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_constants(name):
    """Full production configs hold the assignment's exact constants."""
    cfg = configs.get(name)
    expected = {
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    if name in expected:
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == expected[name], (name, got)


def test_moe_param_counts_match_published():
    assert configs.get("dbrx-132b").param_count() / 1e9 == pytest.approx(132, rel=0.05)
    assert configs.get("arctic-480b").param_count() / 1e9 == pytest.approx(480, rel=0.05)
    j = configs.get("jamba-1.5-large-398b")
    assert j.param_count() / 1e9 == pytest.approx(398, rel=0.05)
    assert j.active_param_count() / 1e9 == pytest.approx(94, rel=0.1)
