"""GPipe pipeline parallelism: subprocess test on a 4-device fake mesh."""

import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    from repro.parallel.pipeline import gpipe, split_stages

    mesh = jax.make_mesh((4,), ("pod",))
    G, d = 8, 16                     # 8 layer groups -> 4 stages of 2
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (G, d, d)) * (d ** -0.5)

    def group_fn(W, x):              # one "layer group": x -> tanh(x @ W)
        return jnp.tanh(x @ W)

    def stage_fn(stage_params, x):   # stage = its slice of groups, in order
        def body(h, W):
            return group_fn(W, h), None
        h, _ = jax.lax.scan(body, x, stage_params)
        return h

    n_micro = 3
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, 5, d))

    # sequential reference
    def seq_forward(Ws, xb):
        def body(h, W):
            return group_fn(W, h), None
        return jax.lax.scan(body, xb, Ws)[0]
    ref = jax.vmap(lambda xb: seq_forward(Ws, xb))(x)

    piped = gpipe(stage_fn, mesh, axis="pod", n_micro=n_micro)
    stages = split_stages(Ws, 4)
    out = jax.jit(piped)(stages, x)
    err = float(jnp.max(jnp.abs(out - ref)))

    # gradients flow through the pipeline (ppermute transpose)
    def loss(stages, x):
        return jnp.sum(piped(stages, x) ** 2)
    g = jax.grad(loss)(stages, x)
    gnorm = float(sum(jnp.sum(jnp.abs(t)) for t in jax.tree_util.tree_leaves(g)))

    def seq_loss(Ws, x):
        return jnp.sum(jax.vmap(lambda xb: seq_forward(Ws, xb))(x) ** 2)
    g_ref = jax.grad(seq_loss)(Ws, x).reshape(4, 2, d, d)
    gerr = float(jnp.max(jnp.abs(g[0] if isinstance(g, tuple) else g) - 0) )
    import numpy as np
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-4, atol=1e-5)
    print(json.dumps({"err": err, "gnorm": gnorm, "ok": True}))
""")


def test_gpipe_matches_sequential():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["err"] < 1e-5 and res["gnorm"] > 0
