"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.generator import TpuGemmSpec, OpenGeMMConfig
from repro.core.dataflow import GemmShape
from repro.kernels import ops, ref
from repro.kernels.gemm import make_gemm, make_dequant_gemm
from repro.kernels.gemm_pipelined import make_pipelined_gemm
from repro.kernels.quant import quantize_rows

SPEC = TpuGemmSpec(tm=128, tk=128, tn=128)

SHAPES = [(128, 128, 128), (256, 384, 128), (384, 128, 256), (128, 512, 384)]
DTYPES = ["float32", "bfloat16", "int8"]


def _operands(m, k, n, dtype, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    if dtype == "int8":
        a = jax.random.randint(k1, (m, k), -127, 128, jnp.int8)
        b = jax.random.randint(k2, (k, n), -127, 128, jnp.int8)
    else:
        dt = jnp.dtype(dtype)
        a = jax.random.normal(k1, (m, k), jnp.float32).astype(dt)
        b = jax.random.normal(k2, (k, n), jnp.float32).astype(dt)
    return a, b


@pytest.mark.parametrize("mkn", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_gemm_matches_oracle(mkn, dtype):
    a, b = _operands(*mkn, dtype)
    out = make_gemm(SPEC, interpret=True)(a, b)
    expect = ref.gemm_ref(a, b)
    if dtype == "int8":
        np.testing.assert_array_equal(out, expect)
    else:
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            rtol=2e-2 if dtype == "bfloat16" else 1e-5,
            atol=1e-1 if dtype == "bfloat16" else 1e-4,
        )


@pytest.mark.parametrize("depth", [2, 3, 4])
def test_pipelined_gemm_depths(depth):
    """The D_stream knob: every buffer depth computes the same result."""
    spec = TpuGemmSpec(tm=128, tk=128, tn=128, depth=depth)
    a, b = _operands(128, 512, 128, "float32")
    out = make_pipelined_gemm(spec, interpret=True)(a, b)
    np.testing.assert_allclose(out, ref.gemm_ref(a, b), rtol=1e-5, atol=1e-4)


def test_pipelined_gemm_int8():
    a, b = _operands(128, 384, 128, "int8")
    out = make_pipelined_gemm(TpuGemmSpec(tm=128, tk=128, tn=128, depth=3),
                              interpret=True)(a, b)
    np.testing.assert_array_equal(out, ref.gemm_ref(a, b))


def test_dequant_gemm():
    a, b = _operands(128, 256, 128, "int8")
    key = jax.random.PRNGKey(3)
    sa = jnp.abs(jax.random.normal(key, (128, 1))) + 0.01
    sb = jnp.abs(jax.random.normal(key, (1, 128))) + 0.01
    out = make_dequant_gemm(SPEC, interpret=True)(a, b, sa, sb)
    np.testing.assert_allclose(out, ref.gemm_dequant_ref(a, b, sa, sb), rtol=1e-5)


@pytest.mark.parametrize("mkn", [(1, 1, 1), (7, 9, 5), (129, 130, 127), (200, 333, 100)])
def test_ragged_padding(mkn):
    """ops.gemm pads ragged problems to the tile grid (the SU analogue)."""
    a, b = _operands(*mkn, "float32")
    out = ops.gemm(a, b, backend="interpret")
    np.testing.assert_allclose(out, ref.gemm_ref(a, b), rtol=1e-5, atol=1e-4)


def test_quantize_rows_kernel():
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 192))
    q, s = quantize_rows(x, interpret=True)
    qr, sr = ref.quantize_ref(x, axis=-1)
    np.testing.assert_array_equal(q, qr)
    np.testing.assert_allclose(s, sr, rtol=1e-6)


def test_int8_linear_accuracy():
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 17, 96))
    w = jax.random.normal(jax.random.PRNGKey(2), (96, 64)) * 0.05
    y = ops.linear(x, w, quant="int8", backend="interpret")
    yref = x @ w
    rel = float(jnp.max(jnp.abs(y - yref)) / jnp.max(jnp.abs(yref)))
    assert rel < 0.05, rel


def test_generator_spec_fits_vmem():
    """tpu_kernel_spec keeps the double-buffered working set under budget."""
    for mkn in [(4096, 8192, 4096), (128, 128, 128), (524288, 1024, 128)]:
        spec = OpenGeMMConfig().tpu_kernel_spec(GemmShape(*mkn))
        footprint = 2 * (spec.tm * spec.tk + spec.tk * spec.tn) + spec.tm * spec.tn * 4
        assert footprint <= 96 * 1024 * 1024
        assert spec.tn % 128 == 0 and spec.tk % 128 == 0 and spec.tm % 8 == 0


def test_xla_backend_matches():
    a, b = _operands(64, 96, 32, "float32")
    np.testing.assert_allclose(
        ops.gemm(a, b, backend="xla"), ref.gemm_ref(a, b), rtol=1e-6
    )
