"""Speculative decoding tests: greedy token-identity (dense/hybrid/recurrent,
w8a8, prefix-cache-admitted), KV rewind edge cases (reject-all/accept-all,
block boundaries, CoW-forked blocks), drafter/bucket units, and speculative
decode under pool pressure — with the PR 4 allocator ``check()`` invariant
asserted throughout."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.serving import kv_cache as kvc
from repro.serving.engine import Engine
from repro.serving.speculative import (
    NgramDrafter,
    SpecConfig,
    bucket_for,
    coerce_spec,
    verify_buckets,
)

FAMILY_ARCHS = ["gemma3-1b", "jamba-1.5-large-398b", "xlstm-1.3b"]


def _params(cfg):
    return M.init_model(jax.random.PRNGKey(0), cfg)


def _run_pair(cfg, params, prompts_and_gens, *, eos=None, check_every_tick=False,
              **kw):
    """Serve the same workload through a speculative and a plain engine;
    returns (plain results, spec results, spec engine)."""
    outs = []
    for speculative in (False, SpecConfig(k=4)):
        eng = Engine(cfg, params=params, slots=2, max_seq=64, block_size=4,
                     max_chunk=8, speculative=speculative, **kw)
        eng.warmup()
        reqs = [eng.submit(p, max_new=g, eos_token=eos)
                for p, g in prompts_and_gens]
        if check_every_tick:
            while eng.scheduler.has_work:
                eng.tick()
                eng.alloc.check()
            res = eng.results
        else:
            res = eng.run()
        eng.alloc.check()
        if eng.prefix_cache is None:
            assert eng.alloc.in_use == 0
        else:
            # only the cache's own refs remain once every slot drained
            assert eng.alloc.in_use == eng.prefix_cache._count
        assert eng.metrics.cold_compiles == 0
        outs.append(({r.rid: res[r.rid] for r in reqs}, eng, reqs))
    (plain, _, preqs), (spec, seng, sreqs) = outs
    for p, s in zip(preqs, sreqs):
        np.testing.assert_array_equal(plain[p.rid], spec[s.rid])
    return plain, spec, seng


# -- token identity across families ------------------------------------------


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_speculative_token_identity(arch):
    """Speculative-on greedy decoding emits exactly the tokens
    speculative-off emits, for dense, hybrid (SSM+attention), and recurrent
    (xLSTM) stacks — partial accepts restore the recurrent state at the
    accepted position, not just the KV length."""
    cfg = configs.get_smoke(arch)
    params = _params(cfg)
    rng = np.random.default_rng(0)
    pat = rng.integers(0, cfg.vocab, size=3).astype(np.int32)
    work = [
        (np.tile(pat, 4), 10),                                   # repetitive
        (rng.integers(0, cfg.vocab, size=9).astype(np.int32), 7),  # random
        (np.tile(pat, 4), 12),           # repeat of prompt 1: corpus drafts
        (rng.integers(0, cfg.vocab, size=5).astype(np.int32), 6),
    ]
    _, _, seng = _run_pair(cfg, params, work)
    m = seng.metrics
    assert m.spec_ticks > 0                     # the spec path actually ran
    assert m.spec_draft_tokens > 0
    assert 0 < m.spec_accepted_tokens <= m.spec_draft_tokens


def test_speculative_token_identity_with_eos():
    """EOS emitted mid-draft stops the request exactly where non-speculative
    decoding stops — the verify step clamps emission at the first EOS, so
    host and device lengths never diverge."""
    cfg = configs.get_smoke("gemma3-1b")
    params = _params(cfg)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    # discover the greedy stream, then pick a mid-stream token as EOS so the
    # speculative run must clamp inside an accepted draft
    probe = Engine(cfg, params=params, slots=1, max_seq=64, block_size=4,
                   max_chunk=8)
    probe.warmup()
    rid = probe.submit(prompt, max_new=12).rid
    stream = probe.run()[rid]
    eos = int(stream[len(stream) // 2])
    work = [(prompt, 12), (prompt, 12)]      # repeat -> corpus drafts cover EOS
    plain, spec, _ = _run_pair(cfg, params, work, eos=eos)
    for toks in spec.values():
        assert eos in toks.tolist() or len(toks) == 12
        if eos in toks.tolist():
            assert toks.tolist().index(eos) == len(toks) - 1  # stops AT eos


def test_speculative_token_identity_w8a8():
    """Speculative decoding composes with the int8 (w8a8) serving precision:
    the verify step is traced inside the precision context at warmup and the
    committed tokens match the non-speculative w8a8 engine's."""
    cfg = configs.get_smoke("gemma3-1b")
    params = _params(cfg)
    rng = np.random.default_rng(2)
    pat = rng.integers(0, cfg.vocab, size=4).astype(np.int32)
    work = [(np.tile(pat, 3), 8), (np.tile(pat, 3), 8)]
    _, _, seng = _run_pair(cfg, params, work, precision="w8a8")
    assert seng.metrics.precision == "w8a8"
    assert seng.metrics.spec_ticks > 0


def test_speculative_token_identity_with_prefix_cache():
    """Speculative decoding composes with prefix-cache admission: requests
    seeded from shared KV blocks speculate past the shared boundary and
    never rewind into (or mutate) a forked block."""
    cfg = configs.get_smoke("gemma3-1b")
    params = _params(cfg)
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab, size=8).astype(np.int32)  # 2 blocks
    work = [(shared, 8),
            (np.concatenate([shared, rng.integers(0, cfg.vocab, size=3)
                             .astype(np.int32)]), 8),
            (shared, 8)]
    _, _, seng = _run_pair(cfg, params, work, prefix_cache=True,
                           check_every_tick=True)
    assert seng.metrics.prefix_hits > 0          # prefix path exercised
    assert seng.metrics.spec_ticks > 0


def test_speculative_under_pool_pressure_with_eviction():
    """Speculative decode keeps drawing/rolling-back blocks correctly while
    the pool is tight enough that prefix-cache entries must be evicted for
    admission; the allocator invariant holds after every tick."""
    cfg = configs.get_smoke("gemma3-1b")
    params = _params(cfg)
    rng = np.random.default_rng(4)
    shared = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    # pool: 9 usable blocks of 4 tokens; each request needs up to 4 blocks,
    # so two in-flight + cached prefix blocks saturate it and force eviction
    eng = Engine(cfg, params=params, slots=2, max_seq=24, block_size=4,
                 num_blocks=10, max_chunk=8, prefix_cache=True,
                 speculative=SpecConfig(k=4))
    eng.warmup()
    reqs = [eng.submit(shared, max_new=8) for _ in range(4)]
    reqs += [eng.submit(rng.integers(0, cfg.vocab, size=7).astype(np.int32),
                        max_new=8) for _ in range(2)]
    while eng.scheduler.has_work:
        assert eng.tick()
        eng.alloc.check()
    assert sorted(eng.results) == [r.rid for r in reqs]
    assert all(len(t) == 8 for t in eng.results.values())
    assert eng.metrics.spec_ticks > 0
    # identical streams for the identical prompts (speculation + eviction
    # never corrupted a shared or rolled-back block)
    first = eng.results[reqs[0].rid]
    for r in reqs[1:4]:
        np.testing.assert_array_equal(eng.results[r.rid], first)


def test_speculative_exact_max_new_budget():
    """High-acceptance ticks (corpus drafts) never overshoot max_new: the
    verify step's per-slot limit clamps acceptance, so every request ends
    with exactly its token budget."""
    cfg = configs.get_smoke("gemma3-1b")
    params = _params(cfg)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    eng = Engine(cfg, params=params, slots=1, max_seq=64, block_size=4,
                 max_chunk=8, speculative=SpecConfig(k=4))
    eng.warmup()
    # odd budgets force the final tick to clamp mid-draft once the corpus
    # makes acceptance near-total
    reqs = [eng.submit(prompt, max_new=g) for g in (11, 7, 5, 3)]
    res = eng.run()
    for r, g in zip(reqs, (11, 7, 5, 3)):
        assert len(res[r.rid]) == g
    eng.alloc.check()
    assert eng.metrics.spec_accepted_tokens > 0


# -- KV rewind edge cases (host side) -----------------------------------------


def _pool(slots=2, blocks=10, bs=4, max_blocks=6):
    alloc = kvc.BlockAllocator(num_blocks=blocks, block_size=bs)
    tables = kvc.BlockTables(slots, max_blocks)
    return alloc, tables


def test_rewind_reject_all_and_accept_all():
    """Reject-all: every draft block returns to the pool and the request's
    reservation.  Accept-all: nothing to rewind (the engine's guard skips
    the call; a same-length rewind is a no-op)."""
    alloc, tables = _pool()
    assert alloc.reserve(4)
    tables.ensure(0, 9, alloc)                    # 3 blocks: tokens 0..8
    alloc.check()
    # reject-all: roll back to 5 tokens (2 blocks)
    freed, pair = tables.rewind(0, 5, alloc)
    assert (freed, pair) == (1, None)
    assert len(tables.blocks[0]) == 2
    assert alloc._reserved == 2                   # 4 - 3 drawn + 1 rewound
    alloc.check()
    # accept-all: rewind to the exact covered length is a no-op
    freed, pair = tables.rewind(0, 8, alloc)
    assert (freed, pair) == (0, None)
    assert len(tables.blocks[0]) == 2
    # rewinding to more tokens than the table covers is a caller bug
    with pytest.raises(ValueError):
        tables.rewind(0, 20, alloc)
    tables.release(0, alloc, unreserve=alloc._reserved)
    alloc.check()
    assert alloc.in_use == 0


def test_rewind_across_block_boundary():
    """A rewind spanning several blocks frees exactly the uncovered ones and
    the table rows read NULL beyond the new boundary."""
    alloc, tables = _pool()
    tables.ensure(0, 24, alloc)                   # 6 blocks
    held = list(tables.blocks[0])
    freed, pair = tables.rewind(0, 4, alloc, rereserve=False)  # 1 block left
    assert freed == 5 and pair is None            # 4 % 4 == 0: aligned, no CoW
    assert tables.blocks[0] == held[:1]
    assert list(tables.table[0, 1:]) == [kvc.NULL_BLOCK] * 5
    alloc.check()
    assert alloc.in_use == 1
    # freed blocks are immediately reusable
    tables.ensure(1, 20, alloc)
    alloc.check()
    tables.release(0, alloc)
    tables.release(1, alloc)
    assert alloc.in_use == 0


def test_rewind_cow_forked_block_copies_then_rewinds():
    """Rewinding into the middle of a CoW-forked block must diverge it
    (copy-then-rewind): the shared physical block is never mutated, the
    rewound slot gets a private replacement, and the other owner's view is
    untouched."""
    alloc, tables = _pool()
    tables.ensure(0, 12, alloc)                   # slot 0: 3 blocks
    owned = list(tables.blocks[0])
    tables.seed(1, kvc.fork_blocks(alloc, owned))  # slot 1 shares all 3
    assert [alloc.refcount(b) for b in owned] == [2, 2, 2]
    alloc.check()
    # rewind slot 1 to 6 tokens: block 2 dropped (loses one ref), block 1
    # becomes the *partial* tail -> shared -> must diverge
    freed, pair = tables.rewind(1, 6, alloc, rereserve=False)
    assert freed == 1
    assert pair is not None
    src, dst = pair
    assert src == owned[1] and dst == tables.blocks[1][1] and dst != src
    assert tables.blocks[0] == owned              # other owner untouched
    assert alloc.refcount(owned[1]) == 1          # slot 0's ref only
    assert alloc.refcount(dst) == 1               # private to slot 1
    assert alloc.refcount(owned[2]) == 1          # dropped share
    alloc.check()
    # block-ALIGNED rewind of a shared tail needs no divergence: the next
    # write starts a fresh block, so sharing is preserved
    alloc2, tables2 = _pool()
    tables2.ensure(0, 8, alloc2)
    owned2 = list(tables2.blocks[0])
    tables2.seed(1, kvc.fork_blocks(alloc2, owned2))
    freed, pair = tables2.rewind(1, 4, alloc2, rereserve=False)
    assert freed == 1 and pair is None
    assert alloc2.refcount(owned2[0]) == 2        # still shared
    alloc2.check()


def test_free_rereserve_skips_shared_blocks():
    """free(rereserve=True) re-reserves only blocks that actually reached
    the free list — a shared block loses a ref without growing the free
    list, and reserving against it would break the allocator invariant."""
    alloc = kvc.BlockAllocator(num_blocks=6, block_size=4)
    ids = alloc.alloc(2, reserved=False)
    kvc.fork_blocks(alloc, ids[:1])               # ids[0] now refcount 2
    returned = alloc.free(ids, rereserve=True)
    assert returned == 1                          # only ids[1] hit the pool
    assert alloc._reserved == 1
    alloc.check()
    alloc.free(ids[:1])                           # drop the remaining share
    alloc._reserved = 0
    alloc.check()


# -- drafter / bucket units ---------------------------------------------------


def test_spec_config_coercion():
    assert coerce_spec(None) is None and coerce_spec(False) is None
    assert coerce_spec(True) == SpecConfig()
    assert coerce_spec(3).k == 3
    sc = SpecConfig(k=2, ngram_min=1, ngram_max=2)
    assert coerce_spec(sc) is sc
    with pytest.raises(TypeError):
        coerce_spec("yes")
    with pytest.raises(ValueError):
        SpecConfig(k=0)
    with pytest.raises(ValueError):
        SpecConfig(ngram_min=3, ngram_max=2)


def test_verify_buckets_cover_every_draft_length():
    assert verify_buckets(1) == [2]
    assert verify_buckets(4) == [2, 3, 5]
    assert verify_buckets(8) == [2, 3, 5, 9]
    for k in (1, 2, 3, 4, 6, 8):
        for d in range(1, k + 1):
            s = bucket_for(d, k)
            assert s in verify_buckets(k) and s >= d + 1
    with pytest.raises(ValueError):
        bucket_for(5, 4)


def test_ngram_drafter_own_history():
    d = NgramDrafter(SpecConfig(k=3, ngram_min=2, ngram_max=3))
    # history [5,6,7,9, 5,6,7] -> suffix [5,6,7] recurs; proposes [9,5,6]
    ctx = np.array([5, 6, 7, 9, 5, 6, 7], np.int32)
    np.testing.assert_array_equal(d.draft(ctx), [9, 5, 6])
    # no recurrence -> empty (decode normally)
    assert len(d.draft(np.array([1, 2, 3, 4], np.int32))) == 0
    # determinism
    np.testing.assert_array_equal(d.draft(ctx), d.draft(ctx))


def test_ngram_drafter_corpus_and_recency():
    d = NgramDrafter(SpecConfig(k=4, ngram_min=2, ngram_max=3, corpus_size=2))
    d.remember(np.array([1, 2, 3, 40, 41, 42], np.int32))
    # own history has no match; corpus continuation after [2,3] is proposed
    np.testing.assert_array_equal(
        d.draft(np.array([9, 1, 2, 3], np.int32)), [40, 41, 42])
    # a more recent stream with the same n-gram wins
    d.remember(np.array([1, 2, 3, 70, 71], np.int32))
    np.testing.assert_array_equal(
        d.draft(np.array([9, 1, 2, 3], np.int32)), [70, 71])
    # bounded retention: a third stream evicts the oldest
    d.remember(np.array([8, 8, 8], np.int32))
    assert len(d._corpus) == 2
    # own-history match outranks the corpus at equal n-gram length
    own = np.array([2, 3, 50, 2, 3], np.int32)
    np.testing.assert_array_equal(d.draft(own), [50, 2, 3])
