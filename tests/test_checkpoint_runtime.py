"""Checkpointing + fault-tolerant runtime."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data import SyntheticLMData, Prefetcher
from repro.runtime import Supervisor, TrainLoopConfig


@pytest.fixture()
def tmp_ckpt(tmp_path):
    return str(tmp_path / "ckpt")


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 4)),
        "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                   "c": jax.random.normal(k, (3,)).astype(jnp.bfloat16)},
    }


def test_checkpoint_roundtrip_exact(tmp_ckpt):
    tree = _tree()
    save_checkpoint(tmp_ckpt, 7, tree)
    assert latest_step(tmp_ckpt) == 7
    out = restore_checkpoint(tmp_ckpt, 7, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_gc_keeps_last(tmp_ckpt):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_ckpt, s, tree, keep_last=2)
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_ckpt) if d.startswith("step_")
    )
    assert steps == [4, 5]


def test_checkpoint_atomicity_ignores_tmp(tmp_ckpt):
    tree = _tree()
    save_checkpoint(tmp_ckpt, 3, tree)
    # a crashed half-written checkpoint must be invisible
    os.makedirs(os.path.join(tmp_ckpt, "step_9.tmp"))
    os.makedirs(os.path.join(tmp_ckpt, "step_11"))  # no manifest -> incomplete
    assert latest_step(tmp_ckpt) == 3


def test_async_checkpointer(tmp_ckpt):
    tree = _tree()
    ck = AsyncCheckpointer(tmp_ckpt)
    ck.save(1, tree)
    ck.save(2, tree)   # waits for the first
    ck.wait()
    assert latest_step(tmp_ckpt) == 2


def test_data_determinism_and_prefetch():
    data = SyntheticLMData(vocab=100, batch=2, seq=8, seed=3)
    b1, b2 = data.batch_at(5), data.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 8)
    # labels are the next-token shift of the same stream
    it = (data.batch_at(i) for i in range(4))
    pf = Prefetcher(it, depth=2)
    got = [b["tokens"] for b in pf]
    assert len(got) == 4
    np.testing.assert_array_equal(got[0], data.batch_at(0)["tokens"])


def _toy_train_setup(tmp_ckpt, total=30, fail_at=None, ckpt_every=10):
    """Tiny linear-regression 'model' under the real supervisor."""
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = AdamWConfig(weight_decay=0.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    opt = adamw_init(params, cfg)

    data = SyntheticLMData(vocab=17, batch=1, seq=3, seed=0)

    @jax.jit
    def train_step(params, opt_state, batch):
        x = jnp.asarray(batch["tokens"], jnp.float32) / 17.0

        def loss(p):
            return jnp.mean((x @ p["w"] - x @ target) ** 2)

        l, g = jax.value_and_grad(loss)(params)
        new_p, new_s = adamw_update(g, opt_state, params, jnp.asarray(0.05), cfg)
        return new_p, new_s, {"loss": l}

    sup = Supervisor(
        train_step, data.batch_at,
        TrainLoopConfig(total_steps=total, ckpt_every=ckpt_every,
                        ckpt_dir=tmp_ckpt, log_every=1),
        simulate_failure_at=fail_at,
    )
    return sup, params, opt


def test_supervisor_clean_run(tmp_ckpt):
    sup, p, o = _toy_train_setup(tmp_ckpt)
    out = sup.run(p, o)
    assert out["step"] == 30 and out["restarts"] == 0
    assert latest_step(tmp_ckpt) == 30


def test_supervisor_failure_restart_matches_clean(tmp_path):
    d1, d2 = str(tmp_path / "clean"), str(tmp_path / "faulty")
    sup, p, o = _toy_train_setup(d1)
    clean = sup.run(p, o)

    sup2, p2, o2 = _toy_train_setup(d2, fail_at=17)
    faulty = sup2.run(p2, o2)
    assert faulty["restarts"] == 1
    # identical final parameters: restart resumed from step 10 and replayed
    np.testing.assert_allclose(
        np.asarray(clean["params"]["w"]), np.asarray(faulty["params"]["w"]),
        rtol=1e-6,
    )


def test_supervisor_restore_api(tmp_ckpt):
    sup, p, o = _toy_train_setup(tmp_ckpt, total=20, ckpt_every=10)
    sup.run(p, o)
    sup2, p2, o2 = _toy_train_setup(tmp_ckpt, total=20)
    restored = sup2.restore(p2, o2)
    assert restored is not None
    _, _, step = restored
    assert step == 20
