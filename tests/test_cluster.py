"""Cluster subsystem tests: pure routing policies, deterministic traffic,
prefix-cache fork/refcount safety, and cluster-of-1 token-equivalence with
the bare engine (dense, hybrid, recurrent families).

Every test touching the block pool ends with ``alloc.check()`` — the
allocator invariant (free list + refcounted blocks partition the pool,
no double-free, no leak) is the safety net under copy-on-write sharing.
"""

import numpy as np
import pytest

import jax

from repro import configs
from repro.cluster.prefix_cache import PrefixCache
from repro.cluster.replica import ReplicaPool, ReplicaView
from repro.cluster.router import (
    AFFINITY_SLACK,
    POLICIES,
    Router,
    pick_least_loaded,
    pick_prefix_affinity,
    pick_round_robin,
)
from repro.cluster import metrics as cmetrics
from repro.cluster import traffic
from repro.models import model as M
from repro.serving import kv_cache as kvc
from repro.serving.engine import Engine, percentile

FAMILY_ARCHS = ["gemma3-1b", "jamba-1.5-large-398b", "xlstm-1.3b"]


# ---------------------------------------------------------------------------
# routing policies: pure functions of (seed, queue state)
# ---------------------------------------------------------------------------


def _views(depths, free=None):
    free = free or [100] * len(depths)
    return [ReplicaView(idx=i, inbox=d, queued=0, active=0, free_blocks=f)
            for i, (d, f) in enumerate(zip(depths, free))]


def test_policies_are_pure_and_deterministic():
    views = _views([3, 1, 2])
    prompt = np.arange(20, dtype=np.int32)
    for name, pick in POLICIES.items():
        a = [pick(views, prompt, step=s, seed=7) for s in range(6)]
        b = [pick(views, prompt, step=s, seed=7) for s in range(6)]
        assert a == b, f"{name} is not deterministic"
        assert all(0 <= i < 3 for i in a)


def test_round_robin_cycles():
    views = _views([0, 0, 0])
    picks = [pick_round_robin(views, None, step=s) for s in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_least_loaded_prefers_depth_then_free_blocks():
    assert pick_least_loaded(_views([4, 1, 2]), None, step=0) == 1
    # tie on depth -> more free KV blocks wins
    assert pick_least_loaded(_views([2, 2], free=[5, 9]), None, step=0) == 1
    # full tie -> lowest index (stable)
    assert pick_least_loaded(_views([2, 2], free=[5, 5]), None, step=0) == 0


def test_prefix_affinity_sticks_and_sheds_overload():
    views = _views([0, 0, 0, 0])
    p1 = np.arange(24, dtype=np.int32)
    p2 = np.arange(24, dtype=np.int32) + 1000
    home1 = pick_prefix_affinity(views, p1, step=0, seed=0)
    # same prefix, different suffix/lengths -> same home replica
    for extra in (0, 5, 11):
        q = np.concatenate([p1[:16], np.full(extra, 7, np.int32)])
        assert pick_prefix_affinity(views, q, step=3, seed=0) == home1
    # seed perturbs the hash deterministically
    assert (pick_prefix_affinity(views, p1, step=0, seed=1)
            == pick_prefix_affinity(views, p1, step=9, seed=1))
    # overload on the home replica falls back to least-loaded
    depths = [0, 0, 0, 0]
    depths[home1] = AFFINITY_SLACK + 5
    fell_back = pick_prefix_affinity(_views(depths), p1, step=0, seed=0)
    assert fell_back != home1
    _ = pick_prefix_affinity(views, p2, step=0, seed=0)  # just valid


# ---------------------------------------------------------------------------
# traffic: seeded generation + record/replay
# ---------------------------------------------------------------------------


def test_traffic_deterministic_and_mixture_bounded():
    cfg = traffic.TrafficConfig(
        n_requests=40, rate_rps=100.0, vocab=64,
        mixture=((0.5, 2, 6), (0.5, 10, 20)), max_new=(1, 5), seed=3)
    a, b = traffic.generate(cfg), traffic.generate(cfg)
    assert [it.prompt for it in a.items] == [it.prompt for it in b.items]
    assert [it.t for it in a.items] == [it.t for it in b.items]
    assert all(it.t <= nxt.t for it, nxt in zip(a.items, a.items[1:]))
    for it in a.items:
        assert 2 <= len(it.prompt) <= 20
        assert 1 <= it.max_new <= 5
        assert all(0 <= t < 64 for t in it.prompt)
    c = traffic.generate(traffic.TrafficConfig(
        n_requests=40, rate_rps=100.0, vocab=64,
        mixture=((0.5, 2, 6), (0.5, 10, 20)), max_new=(1, 5), seed=4))
    assert [it.prompt for it in c.items] != [it.prompt for it in a.items]


def test_shared_system_prompt_shares_prefix():
    tr = traffic.shared_system_prompt(256, n=10, seed=0, prefix_len=12,
                                      suffix=(2, 4))
    first = tr.items[0].prompt[:12]
    assert all(it.prompt[:12] == first for it in tr.items)
    assert all(14 <= len(it.prompt) <= 16 for it in tr.items)


def test_trace_roundtrip(tmp_path):
    tr = traffic.mixed_traffic(128, n=7, seed=5, rate_rps=50.0)
    path = str(tmp_path / "trace.json")
    tr.save(path)
    back = traffic.Trace.load(path)
    assert back.items == tr.items
    assert back.meta["n_requests"] == 7


def test_replay_counts_shed():
    tr = traffic.mixed_traffic(64, n=6, seed=0)
    seen = []

    def submit(spec):
        seen.append((tuple(int(x) for x in spec.prompt), spec.max_new))
        return None if len(seen) % 2 == 0 else object()

    handles, shed = traffic.replay(tr, submit)
    assert len(seen) == 6 and shed == 3 and len(handles) == 3
    assert [s[0] for s in seen] == [it.prompt for it in tr.items]


# ---------------------------------------------------------------------------
# refcounts / fork / prefix cache (host-side, no jit)
# ---------------------------------------------------------------------------


def test_allocator_refcounts_and_fork():
    alloc = kvc.BlockAllocator(num_blocks=10, block_size=4)
    ids = alloc.alloc(3, reserved=False)
    shared = kvc.fork_blocks(alloc, ids)
    assert shared == ids
    assert all(alloc.refcount(b) == 2 for b in ids)
    alloc.free(ids)                       # first owner lets go
    assert alloc.in_use == 3              # survives: second owner remains
    alloc.check()
    alloc.free(ids)                       # last owner -> back to the pool
    assert alloc.in_use == 0
    alloc.check()
    with pytest.raises(ValueError):
        alloc.free(ids)                   # double free is loud
    with pytest.raises(ValueError):
        alloc.ref([99])                   # can't share what isn't allocated


def test_tables_seed_and_make_writable():
    alloc = kvc.BlockAllocator(num_blocks=12, block_size=4)
    tables = kvc.BlockTables(slots=2, max_blocks=4)
    owned = alloc.alloc(2, reserved=False)
    tables.seed(0, kvc.fork_blocks(alloc, owned))
    assert tables.blocks[0] == owned
    assert tables.table[0, :2].tolist() == owned
    with pytest.raises(RuntimeError):
        tables.seed(0, owned)             # only a fresh slot may be seeded
    # CoW divergence: shared entry gets a private replacement
    src_dst = tables.make_writable(0, 0, alloc)
    assert src_dst is not None
    src, dst = src_dst
    assert src == owned[0] and dst not in owned
    assert tables.blocks[0][0] == dst and alloc.refcount(dst) == 1
    assert alloc.refcount(src) == 1       # only the original owner now
    assert tables.make_writable(0, 0, alloc) is None   # already exclusive
    tables.release(0, alloc)
    alloc.free(owned)
    alloc.check()
    assert alloc.in_use == 0


def test_copy_blocks_device_clone():
    cache = kvc.init_paged_kv(num_blocks=4, block_size=2, n_kv_heads=1,
                              head_dim=3, dtype=np.float32)
    k = cache.k.at[1].set(7.0)
    cache = kvc.PagedKVCache(k=k, v=cache.v.at[1].set(9.0))
    out = kvc.copy_blocks(cache, np.array([1]), np.array([3]))
    np.testing.assert_array_equal(np.asarray(out.k[3]), np.asarray(cache.k[1]))
    np.testing.assert_array_equal(np.asarray(out.v[3]), np.asarray(cache.v[1]))
    np.testing.assert_array_equal(np.asarray(out.k[2]), 0.0)


def test_prefix_cache_radix_lookup_insert_evict():
    alloc = kvc.BlockAllocator(num_blocks=32, block_size=4)
    cache = PrefixCache(alloc)
    toks = list(range(12))                # 3 full blocks
    blocks = alloc.alloc(3, reserved=False)
    assert cache.insert(toks, blocks) == 3
    assert all(alloc.refcount(b) == 2 for b in blocks)

    # full-prompt lookup is capped one token short of the prompt
    got, n = cache.lookup(toks)
    assert got == blocks[:2] and n == 8
    # longer prompt sharing the prefix matches all three
    got, n = cache.lookup(toks + [99, 100])
    assert got == blocks and n == 12
    # diverging second block stops the walk after one
    got, n = cache.lookup(toks[:4] + [55, 55, 55, 55, 8, 9])
    assert got == blocks[:1] and n == 4
    assert cache.hits == 3 and cache.lookups == 3

    # duplicate insert adopts nothing (first writer wins)
    dup = alloc.alloc(3, reserved=False)
    assert cache.insert(toks, dup) == 0
    alloc.free(dup)

    # the original writer releasing its blocks must not free cached ones
    alloc.free(blocks)
    assert alloc.in_use == 3
    alloc.check()

    # eviction is leaves-first and returns blocks to the pool
    assert cache.evict(1) == 1
    assert cache.cached_blocks == 2 and alloc.in_use == 2
    assert cache.lookup(toks + [99])[1] == 8      # prefix still rooted
    assert cache.clear() == 2
    assert alloc.in_use == 0
    alloc.check()


def test_prefix_cache_lru_eviction_order():
    alloc = kvc.BlockAllocator(num_blocks=32, block_size=2)
    cache = PrefixCache(alloc)
    a, b = alloc.alloc(1, reserved=False), alloc.alloc(1, reserved=False)
    cache.insert([1, 2], a)
    cache.insert([3, 4], b)
    cache.lookup([1, 2, 9])               # touch a: b is now the LRU leaf
    cache.evict(1)
    assert cache.lookup([1, 2, 9])[0] == a
    assert cache.lookup([3, 4, 9])[0] == []
    cache.clear()
    alloc.free(a)
    alloc.free(b)      # the test's own (writer) ref, untouched by eviction
    alloc.check()
    assert alloc.in_use == 0


def test_fork_survives_eviction_of_matched_nodes():
    """The engine forks its prefix match *before* evicting under pool
    pressure: an eviction sweep that reaches the matched nodes drops only
    the cache's refs — the forked blocks stay alive under the request's."""
    alloc = kvc.BlockAllocator(num_blocks=6, block_size=2)
    cache = PrefixCache(alloc)
    chain = alloc.alloc(3, reserved=False)
    cache.insert([1, 2, 3, 4, 5, 6], chain)
    alloc.free(chain)                     # writer done: cache is sole owner
    matched, n = cache.lookup([1, 2, 3, 4, 5, 6, 7])
    assert matched == chain and n == 6
    kvc.fork_blocks(alloc, matched)       # the admission fork
    cache.evict(3)                        # pressure wipes the whole tree
    assert cache.cached_blocks == 0
    alloc.check()
    assert alloc.in_use == 3              # forked blocks survived
    alloc.free(matched)                   # request finishes
    alloc.check()
    assert alloc.in_use == 0


def test_prefix_cache_capacity_bound():
    alloc = kvc.BlockAllocator(num_blocks=32, block_size=2)
    cache = PrefixCache(alloc, max_blocks=2)
    ids = alloc.alloc(3, reserved=False)
    cache.insert([1, 2, 3, 4, 5, 6], ids)
    assert cache.cached_blocks == 2       # deepest (stalest leaf) evicted
    cache.clear()
    alloc.free(ids)
    alloc.check()


# ---------------------------------------------------------------------------
# engine + prefix cache (jit; one compile set per config)
# ---------------------------------------------------------------------------


def test_engine_prefix_cache_rejects_recurrent_archs():
    cfg = configs.get_smoke("xlstm-1.3b")
    with pytest.raises(ValueError, match="attention-only"):
        Engine(cfg, slots=1, max_seq=16, prefix_cache=True)


def test_engine_prefix_cache_reuses_and_stays_token_identical():
    """Shared-prefix requests skip prefill for cached blocks, generate the
    same tokens as a cache-less engine, and the allocator survives the whole
    exercise with zero leaked or double-freed blocks."""
    cfg = configs.get_smoke("gemma3-1b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab, size=k).astype(np.int32)])
               for k in (3, 5, 2, 4)]

    ref = Engine(cfg, params=params, slots=2, max_seq=32, block_size=4,
                 max_chunk=4)
    ref.warmup()
    ref_reqs = [ref.submit(p, max_new=3) for p in prompts]
    ref_out = ref.run()

    eng = Engine(cfg, params=params, slots=2, max_seq=32, block_size=4,
                 max_chunk=4, prefix_cache=True)
    eng.share_steps_from(ref)
    eng.warmup()
    reqs = [eng.submit(p, max_new=3) for p in prompts]
    out = eng.run()

    for a, b in zip(ref_reqs, reqs):
        np.testing.assert_array_equal(ref_out[a.rid], out[b.rid])
    assert eng.metrics.prefix_hits >= 1
    assert eng.metrics.prefix_hit_tokens >= 8
    # skipped prefill really skipped: fewer prompt tokens prefilled
    assert eng.metrics.prefill_tokens < ref.metrics.prefill_tokens
    # requests released; only the cache's own refs remain
    eng.alloc.check()
    assert eng.alloc.in_use == eng.prefix_cache.cached_blocks
    eng.prefix_cache.clear()
    eng.alloc.check()
    assert eng.alloc.in_use == 0


def test_engine_prefix_cache_evicts_under_pool_pressure():
    """A pool sized so cached blocks crowd out admissions: the engine must
    evict cache refs rather than wedge, and finish every request."""
    cfg = configs.get_smoke("gemma3-1b")
    eng = Engine(cfg, slots=1, max_seq=16, block_size=4, num_blocks=5,
                 max_chunk=4, prefix_cache=True)
    eng.warmup()
    rng = np.random.default_rng(1)
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=9).astype(np.int32),
                       max_new=2) for _ in range(3)]
    out = eng.run()
    assert sorted(out) == [r.rid for r in reqs]
    assert all(len(v) == 2 for v in out.values())
    eng.alloc.check()
    eng.prefix_cache.clear()
    eng.alloc.check()
    assert eng.alloc.in_use == 0


# ---------------------------------------------------------------------------
# cluster-of-1 equivalence + pool/router behavior
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_cluster_of_one_matches_bare_engine(arch):
    """A 1-replica pool behind the router (prefix cache off) produces
    token-for-token the outputs of a bare Engine.run() on the same
    requests — dense, hybrid, and recurrent families."""
    cfg = configs.get_smoke(arch)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (6, 3, 5, 4)]

    bare = Engine(cfg, params=params, slots=2, max_seq=32, block_size=4,
                  max_chunk=4)
    bare.warmup()
    bare_reqs = [bare.submit(p, max_new=3) for p in prompts]
    want = bare.run()

    pool = ReplicaPool(cfg, 1, params=params, slots=2, max_seq=32,
                       block_size=4, max_chunk=4)
    pool.engines[0].share_steps_from(bare)
    pool.warmup()
    router = Router(pool, policy="round-robin", async_dispatch=False)
    handles = [router.submit(p, max_new=3) for p in prompts]
    router.dispatch_sync()
    pool.run_sync(max_ticks=10_000)

    for br, h in zip(bare_reqs, handles):
        np.testing.assert_array_equal(want[br.rid], h.result(timeout=0))
    assert router.shed == 0
    pool.engines[0].alloc.check()
    m = cmetrics.aggregate(pool, router, elapsed_s=1.0)
    assert m.requests == len(prompts) and m.shed == 0


def test_threaded_pool_serves_all_requests():
    """Threaded replicas + async router dispatch: every request resolves,
    work spreads across replicas, allocators stay clean."""
    cfg = configs.get_smoke("gemma3-1b")
    pool = ReplicaPool(cfg, 2, slots=2, max_seq=32, block_size=4, max_chunk=4)
    pool.warmup()
    pool.start()
    try:
        router = Router(pool, policy="least-loaded")
        trace = traffic.mixed_traffic(cfg.vocab, n=8, seed=0, max_prompt=8,
                                      max_new=(2, 4))
        handles, shed = traffic.replay(trace, router.submit)
        assert shed == 0
        router.drain(timeout=120)
        for h, it in zip(handles, trace.items):
            assert len(h.result(timeout=0)) == it.max_new
            assert h.ttft_s is not None and h.ttft_s >= 0
        m = cmetrics.aggregate(pool, router, elapsed_s=1.0)
        assert m.requests == 8
        assert sum(m.per_replica_requests) == 8
        for e in pool.engines:
            e.alloc.check()
    finally:
        router.close()


def test_router_backpressure_sheds():
    """max_pending bounds in-flight requests; overflow is shed (counted,
    returns None), never queued invisibly."""
    cfg = configs.get_smoke("gemma3-1b")
    pool = ReplicaPool(cfg, 1, slots=1, max_seq=16, block_size=4, max_chunk=4)
    # replicas never started: everything stays in flight
    router = Router(pool, policy="round-robin", max_pending=3,
                    async_dispatch=False)
    prompt = np.arange(4, dtype=np.int32)
    accepted = [router.submit(prompt, 1) for _ in range(5)]
    assert sum(h is not None for h in accepted) == 3
    assert router.shed == 2 and router.offered == 5
    assert router.shed_rate == pytest.approx(0.4)
    pool.stop()


def test_router_rejects_unknown_policy():
    cfg = configs.get_smoke("gemma3-1b")
    pool = ReplicaPool(cfg, 1, slots=1, max_seq=16, block_size=4, max_chunk=4)
    with pytest.raises(ValueError, match="unknown policy"):
        Router(pool, policy="fastest-first", async_dispatch=False)
    pool.stop()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    assert percentile([], 50) == 0.0
    assert percentile([5.0], 95) == 5.0
    vals = list(range(1, 101))
    assert percentile(vals, 50) == 50
    assert percentile(vals, 95) == 95
    assert percentile([3.0, 1.0, 2.0], 100) == 3.0


def test_engine_metrics_percentiles_in_summary():
    from repro.serving.engine import EngineMetrics, RequestMetrics

    m = EngineMetrics()
    for i, (ttft, lat, toks) in enumerate(
            [(0.010, 0.110, 11), (0.020, 0.120, 11), (0.200, 0.500, 31)]):
        m.requests.append(RequestMetrics(
            rid=i, prompt_len=4, new_tokens=toks, ttft_s=ttft,
            latency_s=lat, queue_steps=0))
    assert m.ttft_percentile(50) == pytest.approx(0.020)
    assert m.ttft_percentile(95) == pytest.approx(0.200)
    assert m.requests[0].decode_tok_s == pytest.approx(100.0)
    s = m.summary()
    assert "p50=" in s and "p95=" in s and "req_tok_s_p50=" in s


def test_cluster_metrics_aggregate_folds_replicas():
    from repro.serving.engine import EngineMetrics, RequestMetrics

    class _Pool:
        class _E:
            def __init__(self, ttfts):
                self.metrics = EngineMetrics()
                for i, t in enumerate(ttfts):
                    self.metrics.requests.append(RequestMetrics(
                        rid=i, prompt_len=2, new_tokens=3, ttft_s=t,
                        latency_s=t + 0.1, queue_steps=0))
                self.metrics.decode_tokens = 2 * len(ttfts)
                self.metrics.occupancy_sum = 0.5
                self.metrics.occupancy_samples = 1

        engines = None

    pool = _Pool()
    pool.engines = [_Pool._E([0.01, 0.02]), _Pool._E([0.03])]
    m = cmetrics.aggregate(pool, elapsed_s=2.0)
    assert m.requests == 3 and m.replicas == 2
    # 6 decode-step tokens + 3 first-tokens out of final prefill chunks
    assert m.decode_tokens == 9
    assert m.throughput_tok_s == pytest.approx(4.5)
    assert m.ttft_p50_s == pytest.approx(0.02)
    assert m.per_replica_requests == [2, 1]
    assert "replicas=2" in m.summary()


def test_cluster_metrics_aggregate_empty_replicas():
    """Replicas that finished nothing must aggregate to clean zeros (the
    serve loop calls aggregate() on fresh pools before traffic arrives)."""
    from repro.serving.engine import EngineMetrics

    class _Pool:
        class _E:
            def __init__(self):
                self.metrics = EngineMetrics()

        engines = None

    pool = _Pool()
    pool.engines = [_Pool._E(), _Pool._E(), _Pool._E()]
    m = cmetrics.aggregate(pool, elapsed_s=1.0)
    assert m.requests == 0 and m.replicas == 3
    assert m.ttft_p50_s == 0.0 and m.ttft_p95_s == 0.0
    assert m.req_tok_s_p50 == 0.0
    assert m.throughput_tok_s == 0.0
    assert m.per_replica_requests == [0, 0, 0]
    assert m.shed_rate == 0.0            # zero offered -> 0.0, not a div/0
    assert "replicas=3" in m.summary()


def test_cluster_metrics_shed_rate_zero_offered():
    m = cmetrics.ClusterMetrics()
    assert m.shed_rate == 0.0
    m.shed, m.offered = 5, 10
    assert m.shed_rate == pytest.approx(0.5)


def test_cluster_aggregate_merged_hist_matches_raw_percentiles():
    """When a replica's raw request log was capped, aggregate() falls back
    to merged histograms — their percentiles must track the exact nearest-
    rank values within the histogram's resolution."""
    from repro.serving.engine import EngineMetrics, RequestMetrics

    rng = np.random.default_rng(7)
    ttfts = [float(t) for t in rng.lognormal(-3.5, 0.8, size=60)]

    def _engine(sub, capped):
        class _E:
            pass
        e = _E()
        e.metrics = EngineMetrics()
        for i, t in enumerate(sub):
            # log_limit=1 forces the dropped path; None keeps the raw log
            e.metrics.note_request(RequestMetrics(
                rid=i, prompt_len=2, new_tokens=4, ttft_s=t,
                latency_s=t + 0.05, queue_steps=0), 1 if capped else None)
        return e

    class _Pool:
        engines = None

    for capped in (False, True):
        pool = _Pool()
        pool.engines = [_engine(ttfts[:40], capped),
                        _engine(ttfts[40:], capped)]
        m = cmetrics.aggregate(pool, elapsed_s=1.0)
        assert m.requests == len(ttfts)
        rel = m.ttft_hist.rel_error if capped else 1e-9
        for q, got in ((50, m.ttft_p50_s), (95, m.ttft_p95_s)):
            assert got == pytest.approx(percentile(ttfts, q), rel=rel), \
                (capped, q)
