"""Property-based tests of the dataflow/tiling math.

Optional module: requires `hypothesis` (requirements-dev.txt).  The
deterministic equivalents in test_dataflow.py always run.
"""

import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.dataflow import (
    Dataflow,
    GemmShape,
    SpatialUnrolling,
    TemporalUnrolling,
    OUTPUT_STATIONARY,
    arithmetic_intensity,
    choose_loop_order,
    roofline_time_s,
)

dims = st.integers(min_value=1, max_value=512)
arr = st.sampled_from([1, 2, 4, 8, 16])


@given(M=dims, K=dims, N=dims, Mu=arr, Ku=arr, Nu=arr)
@settings(max_examples=200, deadline=None)
def test_spatial_utilization_bounds(M, K, N, Mu, Ku, Nu):
    df = Dataflow(spatial=SpatialUnrolling(Mu, Ku, Nu))
    g = GemmShape(M, K, N)
    su = df.spatial_utilization(g)
    assert 0 < su <= 1
    # SU == 1 iff every dim is a multiple of its unrolling
    if M % Mu == 0 and K % Ku == 0 and N % Nu == 0:
        assert su == 1.0
    else:
        assert su < 1.0


@given(M=dims, K=dims, N=dims)
@settings(max_examples=100, deadline=None)
def test_padded_shape_consistency(M, K, N):
    sp = SpatialUnrolling()
    g = GemmShape(M, K, N)
    p = sp.padded_shape(g)
    assert p.M % sp.Mu == 0 and p.K % sp.Ku == 0 and p.N % sp.Nu == 0
    assert p.M - g.M < sp.Mu and p.K - g.K < sp.Ku and p.N - g.N < sp.Nu
    m, k, n = sp.tile_counts(g)
    assert (m * sp.Mu, k * sp.Ku, n * sp.Nu) == (p.M, p.K, p.N)


@given(m=st.integers(1, 6), k=st.integers(1, 6), n=st.integers(1, 6),
       order=st.permutations(["m1", "k1", "n1"]))
@settings(max_examples=50, deadline=None)
def test_temporal_iterate_covers_all_tiles(m, k, n, order):
    t = TemporalUnrolling(tuple(order))
    seen = list(t.iterate((m, k, n)))
    assert len(seen) == m * k * n
    assert len(set(seen)) == m * k * n
    assert all(0 <= a < m and 0 <= b < k and 0 <= c < n for a, b, c in seen)


def test_output_stationary_innermost_k():
    t = TemporalUnrolling(OUTPUT_STATIONARY)
    assert t.is_output_stationary and not t.is_weight_stationary
    # consecutive iterations differ only in k1 until a boundary
    it = list(t.iterate((2, 3, 2)))
    assert it[0][:1] + it[0][2:] == it[1][:1] + it[1][2:]


@given(M=dims, K=dims, N=dims)
@settings(max_examples=100, deadline=None)
def test_choose_loop_order_prefers_output_stationary(M, K, N):
    # Paper Sec 2.3: partial-sum width (32b) > operand width (8b) => OS.
    t = choose_loop_order(GemmShape(M, K, N), SpatialUnrolling())
    assert t.order == OUTPUT_STATIONARY


@given(M=dims, K=dims, N=dims)
@settings(max_examples=100, deadline=None)
def test_roofline_terms_positive_and_scaling(M, K, N):
    g = GemmShape(M, K, N)
    c, m = roofline_time_s(g, peak_flops=1e12, mem_bw=1e11)
    assert c > 0 and m > 0
    c2, m2 = roofline_time_s(g, peak_flops=2e12, mem_bw=2e11)
    assert math.isclose(c / c2, 2.0) and math.isclose(m / m2, 2.0)
    ai = arithmetic_intensity(g)
    assert math.isclose(ai, (c * 1e12) / (m * 1e11) * (1e11 / 1e12) * (1e12 / 1e11), rel_tol=1)
    assert ai > 0


@given(M=dims, K=dims, N=dims)
@settings(max_examples=100, deadline=None)
def test_overall_equals_spatial_times_temporal(M, K, N):
    df = Dataflow()
    g = GemmShape(M, K, N)
    compute = df.compute_cycles(g)
    total = compute + 137  # arbitrary stall cycles
    su = df.spatial_utilization(g)
    tu = df.temporal_utilization(compute, total)
    ou = df.overall_utilization(g, total)
    assert math.isclose(ou, su * tu, rel_tol=1e-12)
