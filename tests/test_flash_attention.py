"""Flash-attention Pallas kernel vs dense oracle + blockwise JAX path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import blockwise_attention


def dense_ref(q, k, v, causal=True, window=None):
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    kr = jnp.repeat(k, Hq // Hkv, axis=2)
    vr = jnp.repeat(v, Hq // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) * D ** -0.5
    qpos, kpos = jnp.arange(S)[:, None], jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((S, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr).astype(q.dtype)


def _qkv(B, S, Hq, Hkv, D, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, Hq, D), dtype),
            jax.random.normal(ks[1], (B, S, Hkv, D), dtype),
            jax.random.normal(ks[2], (B, S, Hkv, D), dtype))


@pytest.mark.parametrize("shape", [
    (1, 128, 2, 2, 64),    # MHA
    (2, 256, 4, 2, 64),    # GQA
    (1, 192, 4, 1, 128),   # MQA, ragged seq vs block
])
def test_flash_matches_dense(shape):
    B, S, Hq, Hkv, D = shape
    q, k, v = _qkv(B, S, Hq, Hkv, D)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_kv=128,
                          interpret=True)
    np.testing.assert_allclose(out, dense_ref(q, k, v), rtol=1e-4, atol=1e-5)


def test_flash_non_causal():
    q, k, v = _qkv(1, 128, 2, 2, 64, seed=1)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_kv=64,
                          interpret=True)
    np.testing.assert_allclose(out, dense_ref(q, k, v, causal=False),
                               rtol=1e-4, atol=1e-5)


def test_flash_sliding_window():
    q, k, v = _qkv(1, 256, 2, 1, 64, seed=2)
    out = flash_attention(q, k, v, causal=True, window=64,
                          block_q=64, block_kv=64, interpret=True)
    np.testing.assert_allclose(out, dense_ref(q, k, v, window=64),
                               rtol=1e-4, atol=1e-5)


def test_flash_matches_blockwise_jax_path():
    """Kernel and the XLA blockwise path agree (same math, two substrates)."""
    q, k, v = _qkv(2, 128, 4, 2, 64, seed=3)
    a = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                        interpret=True)
    b = blockwise_attention(q, k, v, causal=True, block_kv=64)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_flash_bf16():
    q, k, v = _qkv(1, 128, 2, 2, 64, seed=4, dtype=jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                          interpret=True)
    ref = dense_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               rtol=5e-2, atol=5e-2)
