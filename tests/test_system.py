"""End-to-end system tests: training convergence, serving, int8 mode,
HLO cost analyzer, and data/training determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_training_loss_decreases(tmp_path):
    """A tiny LM trained through the full launcher improves its loss."""
    from repro.launch import train as T

    out = T.main([
        "--arch", "gemma3-1b", "--preset", "smoke",
        "--steps", "40", "--batch", "4", "--seq", "32",
        "--ckpt-every", "1000", "--ckpt-dir", str(tmp_path / "ck"),
        "--lr", "3e-3",
    ])
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0] - 0.2, losses


def test_serving_generates(tmp_path):
    from repro.launch import serve

    gen = serve.main(["--arch", "qwen3-14b", "--requests", "2",
                      "--prompt-len", "6", "--gen-len", "4"])
    assert gen.shape == (2, 4)
    assert gen.dtype.kind == "i"


def test_int8_linear_close_to_f32():
    from repro.kernels import ops

    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32)) * 0.1
    y8 = ops.linear(x, w, quant="int8", backend="xla")
    rel = float(jnp.linalg.norm(y8 - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.03


def test_hlo_cost_matches_xla_on_loop_free():
    from repro.launch import hlo_cost

    def f(a, b):
        return jnp.tanh(a @ b).sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 32), jnp.float32),
    ).compile()
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    mine = hlo_cost.analyze(comp.as_text())
    assert mine.flops == pytest.approx(float(ca["flops"]), rel=0.05)


def test_hlo_cost_scales_scan_trip_count():
    from repro.launch import hlo_cost

    def g(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, None, length=7)[0]

    comp = jax.jit(g).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
    ).compile()
    mine = hlo_cost.analyze(comp.as_text())
    assert mine.flops == pytest.approx(7 * 2 * 32 ** 3, rel=0.01)
    assert 7 in mine.trip_counts.values()


def test_hlo_cost_counts_collectives():
    from repro.launch import hlo_cost

    # single-device: no collectives expected
    comp = jax.jit(lambda a: a * 2).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    mine = hlo_cost.analyze(comp.as_text())
    assert mine.collective_bytes == 0


def test_train_determinism(tmp_path):
    from repro.launch import train as T

    outs = []
    for i in range(2):
        out = T.main([
            "--arch", "bert-base", "--preset", "smoke",
            "--steps", "10", "--batch", "2", "--seq", "16",
            "--ckpt-every", "1000", "--ckpt-dir", str(tmp_path / f"d{i}"),
        ])
        outs.append([m["loss"] for m in out["metrics"]])
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6)
