"""Optimizer: convergence, compressed states, clipping, schedule."""

import jax
import jax.numpy as jnp
import pytest

from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine, global_norm
from repro.optim.adamw import _bq_encode, _bq_decode


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges_quadratic(state_dtype):
    cfg = AdamWConfig(weight_decay=0.0, state_dtype=state_dtype)
    target = jnp.asarray([[1.5, -2.0], [0.5, 3.0]])
    params = {"w": jnp.zeros((2, 2))}
    state = adamw_init(params, cfg)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw_update(g, state, params, jnp.asarray(0.05), cfg)

    for _ in range(300):
        params, state = step(params, state)
    tol = {"float32": 1e-2, "bfloat16": 5e-2, "int8": 1e-1}[state_dtype]
    assert float(jnp.max(jnp.abs(params["w"] - target))) < tol


def test_blockq_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (300,)) * 10
    bq = _bq_encode(x)
    assert bq.q.dtype == jnp.int8
    y = _bq_decode(bq, x.shape)
    # int8 with 128-block scales: ~1% of block absmax
    err = float(jnp.max(jnp.abs(y - x)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_grad_clipping_caps_update():
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    new_params, _ = adamw_update(huge, state, params, jnp.asarray(0.1), cfg)
    # clipped: the Adam update magnitude stays ~lr
    assert float(jnp.max(jnp.abs(new_params["w"]))) < 1.0


def test_warmup_cosine_shape():
    s = warmup_cosine(1e-3, warmup=100, total=1000)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(100))) == pytest.approx(1e-3, rel=1e-3)
    assert float(s(jnp.asarray(50))) == pytest.approx(5e-4, rel=1e-3)
    assert float(s(jnp.asarray(1000))) == pytest.approx(1e-4, rel=1e-2)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_optimizer_state_memory_sizes():
    """bf16/int8 states halve/quarter the moment footprint (the reason the
    477B configs fit a pod — see DESIGN.md)."""
    params = {"w": jnp.zeros((1024, 128), jnp.bfloat16)}

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))

    f32 = nbytes(adamw_init(params, AdamWConfig(state_dtype="float32"))["m"])
    b16 = nbytes(adamw_init(params, AdamWConfig(state_dtype="bfloat16"))["m"])
    i8 = nbytes(adamw_init(params, AdamWConfig(state_dtype="int8"))["m"])
    assert b16 == f32 // 2
    assert i8 < f32 // 3  # int8 + per-128 block f32 scales
