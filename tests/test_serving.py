"""Serving engine tests: paged-vs-dense decode equivalence, chunked-prefill
logits equivalence, scheduler slot refill under unequal generation lengths,
block-table reuse, and prefill work proportional to real prompt tokens."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.serving import kv_cache as kvc
from repro.serving.engine import Engine
from repro.serving.prefill import chunk_buckets, plan_chunks
from repro.serving.scheduler import Scheduler

# One arch per serving family (all float32 smoke configs -> tight tolerances).
FAMILY_ARCHS = ["gemma3-1b", "jamba-1.5-large-398b", "xlstm-1.3b"]
TOL = dict(rtol=3e-4, atol=3e-4)


def _paged_state_with_tables(cfg, slots, block_size, max_blocks, need_tokens):
    num_blocks = 1 + slots * max_blocks
    state = M.init_paged_decode_state(
        cfg, slots, num_blocks=num_blocks, block_size=block_size,
        max_blocks_per_slot=max_blocks)
    alloc = kvc.BlockAllocator(num_blocks, block_size)
    tables = kvc.BlockTables(slots, max_blocks)
    for s in range(slots):
        tables.ensure(s, need_tokens, alloc)
    return state._replace(block_tables=tables.array())


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_and_chunked_match_dense_decode(arch):
    """Chunked prefill through the paged cache produces the same logits as
    token-by-token dense decode, and paged decode tracks dense decode
    step-for-step — for the dense, hybrid, and recurrent families."""
    cfg = configs.get_smoke(arch)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    slots, prompt_len, gen = 2, 6, 3
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(slots, prompt_len)).astype(np.int32)

    # dense reference: lock-step token-by-token decode
    dstate = M.init_decode_state(params, cfg, slots, 32)
    last = None
    for t in range(prompt_len):
        last, dstate = M.decode_step(
            params, cfg, dstate, jnp.asarray(prompts[:, t:t + 1]))
    dense = [np.asarray(last)]
    tok = jnp.argmax(last[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(gen):
        last, dstate = M.decode_step(params, cfg, dstate, tok)
        dense.append(np.asarray(last))
        tok = jnp.argmax(last[:, -1], -1)[:, None].astype(jnp.int32)

    # paged: chunked prefill per slot (6 tokens = chunks 4 + 2), then decode
    pstate = _paged_state_with_tables(cfg, slots, 4, 8, prompt_len + gen + 1)
    for s in range(slots):
        pos, lp = 0, None
        for c in plan_chunks(prompt_len, max_chunk=4):
            lp, pstate = M.prefill_chunk(
                params, cfg, pstate,
                jnp.asarray(prompts[s:s + 1, pos:pos + c]), jnp.int32(s))
            pos += c
        np.testing.assert_allclose(np.asarray(lp)[0], dense[0][s], **TOL)

    tok = jnp.asarray(np.argmax(dense[0][:, -1], -1)[:, None].astype(np.int32))
    for ref in dense[1:]:
        lp, pstate = M.paged_decode_step(params, cfg, pstate, tok)
        np.testing.assert_allclose(np.asarray(lp), ref, **TOL)
        tok = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
    assert int(pstate.lengths[0]) == prompt_len + gen


def test_paged_decode_per_slot_lengths():
    """Slots at *different* positions decode correctly: a slot refilled later
    matches the same prompt served alone (state isolation across slots)."""
    cfg = configs.get_smoke("gemma3-1b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    p0 = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab, size=3).astype(np.int32)

    # Serve p1 alone (slot 0 of a 1-slot state) as the reference.
    ref, ref_state = None, _paged_state_with_tables(cfg, 1, 4, 8, 16)
    for c, pos in ((2, 0), (1, 2)):
        ref, ref_state = M.prefill_chunk(
            params, cfg, ref_state, jnp.asarray(p1[None, pos:pos + c]),
            jnp.int32(0))

    # Two-slot state: slot 0 holds 6 tokens of p0, then slot 1 prefills p1.
    st = _paged_state_with_tables(cfg, 2, 4, 8, 16)
    for c, pos in ((4, 0), (2, 4)):
        _, st = M.prefill_chunk(
            params, cfg, st, jnp.asarray(p0[None, pos:pos + c]), jnp.int32(0))
    out = None
    for c, pos in ((2, 0), (1, 2)):
        out, st = M.prefill_chunk(
            params, cfg, st, jnp.asarray(p1[None, pos:pos + c]), jnp.int32(1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    assert st.lengths.tolist() == [6, 3]


def test_engine_slot_refill_unequal_lengths():
    """Continuous batching: more requests than slots, unequal max_new; every
    request completes with its own token budget, prefill work is proportional
    to real prompt tokens, and all blocks return to the pool."""
    cfg = configs.get_smoke("gemma3-1b")
    eng = Engine(cfg, slots=2, max_seq=32, block_size=4, max_chunk=4, seed=0)
    eng.warmup()
    rng = np.random.default_rng(2)
    lens, gens = [5, 3, 7, 4], [2, 5, 1, 3]
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32) for n in lens]
    reqs = [eng.submit(p, max_new=g) for p, g in zip(prompts, gens)]
    results = eng.run()

    assert sorted(results) == [r.rid for r in reqs]
    for r, g in zip(reqs, gens):
        assert len(results[r.rid]) == g, (r.rid, results[r.rid])
    # prefill proportional to real tokens (regression for the old padded
    # token-by-token loop, which burned slots * max(len) dead steps)
    assert eng.metrics.prefill_tokens == sum(lens)
    assert eng.metrics.decode_tokens == sum(gens) - len(gens)  # first tokens
    # come from the final prefill chunk, not from a decode step
    assert eng.alloc.in_use == 0 and eng.alloc.available == eng.num_blocks - 1
    assert eng.metrics.cold_compiles == 0  # warmup covered every step shape


def test_engine_matches_isolated_run():
    """A request served through a busy 2-slot engine generates the same
    tokens as the same request served alone."""
    cfg = configs.get_smoke("jamba-1.5-large-398b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (6, 4, 5)]

    busy = Engine(cfg, params=params, slots=2, max_seq=32, block_size=4,
                  max_chunk=4)
    busy.warmup()
    reqs = [busy.submit(p, max_new=3) for p in prompts]
    got = busy.run()

    for p, r in zip(prompts, reqs):
        solo = Engine(cfg, params=params, slots=1, max_seq=32, block_size=4,
                      max_chunk=4)
        solo.warmup()
        sr = solo.submit(p, max_new=3)
        want = solo.run()[sr.rid]
        np.testing.assert_array_equal(got[r.rid], want)


def test_block_table_reuse_after_completion():
    """Freed blocks are handed to the next request: a pool far smaller than
    total demand still serves everything, and the same physical block ids
    get reused across requests."""
    cfg = configs.get_smoke("gemma3-1b")
    # usable pool: 4 blocks of 4 tokens; each request needs 2 blocks
    eng = Engine(cfg, slots=2, max_seq=16, block_size=4, num_blocks=5,
                 max_chunk=4)
    eng.warmup()
    rng = np.random.default_rng(4)
    n_req = 4
    for _ in range(n_req):
        eng.submit(rng.integers(0, cfg.vocab, size=5).astype(np.int32),
                   max_new=2)
    seen_blocks = set()
    while eng.scheduler.has_work:
        assert eng.tick()
        for slot_blocks in eng.tables.blocks:
            seen_blocks.update(slot_blocks)
    results = eng.results
    assert len(results) == n_req and all(len(t) == 2 for t in results.values())
    # 4 requests x 2 blocks = 8 block-uses served by <= 4 physical blocks
    assert len(seen_blocks) <= 4
    assert kvc.NULL_BLOCK not in seen_blocks
    assert eng.metrics.peak_blocks_in_use <= 4
    assert eng.alloc.in_use == 0


def test_engine_admission_queue_backpressure():
    """max_queue bounds the admission queue; overflow submissions are
    rejected, not crashed."""
    cfg = configs.get_smoke("gemma3-1b")
    eng = Engine(cfg, slots=1, max_seq=16, block_size=4, max_chunk=4,
                 max_queue=2)
    prompts = np.arange(4, dtype=np.int32)
    assert eng.submit(prompts, max_new=1) is not None
    assert eng.submit(prompts, max_new=1) is not None
    assert eng.submit(prompts, max_new=1) is None
    assert eng.scheduler.rejected == 1
    with pytest.raises(ValueError):
        eng.submit(np.zeros((0,), np.int32), max_new=1)  # nothing to prefill
    with pytest.raises(ValueError):
        eng.submit(np.zeros((20,), np.int32), max_new=1)  # exceeds max_seq


# -- host-side units (no jit, fast) ------------------------------------------


def test_plan_chunks_exact_and_bucketed():
    buckets = set(chunk_buckets(8))
    assert buckets == {8, 4, 2, 1}
    for L in range(0, 40):
        plan = plan_chunks(L, max_chunk=8)
        assert sum(plan) == L
        assert all(c in buckets for c in plan)
        # largest-first greedy: at most log2(C) trailing sub-max chunks
        assert plan == sorted(plan, reverse=True)


def test_scheduler_interleaves_prefill_and_decode():
    sched = Scheduler(slots=2, max_chunk=4)
    sched.submit(np.arange(8, dtype=np.int32), max_new=4)
    sched.submit(np.arange(6, dtype=np.int32), max_new=4)
    sched.admit(lambda req: True)
    kinds = []
    for _ in range(4):
        act = sched.next_action()
        kinds.append(act[0])
        if act[0] == "prefill":
            _, req, chunk = act
            sched.on_prefill(req, chunk, 0)
        else:
            for r in act[1]:
                sched.on_token(r, 1, 0)
    # nothing decodes until the first prompt completes; then phases mix
    assert kinds == ["prefill", "prefill", "decode", "prefill"]

    # with one request decoding and one prefilling, actions alternate
    sched2 = Scheduler(slots=2, max_chunk=4)
    a = sched2.submit(np.arange(4, dtype=np.int32), max_new=8)
    sched2.submit(np.arange(8, dtype=np.int32), max_new=8)
    sched2.admit(lambda req: True)
    act = sched2.next_action()           # a's only chunk
    sched2.on_prefill(a, act[2], 0)
    seq = []
    for _ in range(4):
        act = sched2.next_action()
        seq.append(act[0])
        if act[0] == "prefill":
            sched2.on_prefill(act[1], act[2], 0)
        else:
            for r in act[1]:
                sched2.on_token(r, 1, 0)
    assert seq == ["decode", "prefill", "decode", "prefill"]


def test_scheduler_fifo_admission_blocks_behind_head():
    sched = Scheduler(slots=3, max_chunk=4)
    big = sched.submit(np.arange(8, dtype=np.int32), max_new=4)
    small = sched.submit(np.arange(2, dtype=np.int32), max_new=1)
    admitted = sched.admit(lambda req: req is small)  # big can't fit
    assert admitted == []                 # FIFO: small must wait behind big
    assert sched.queue[0] is big and len(sched.queue) == 2


def test_block_allocator_reservations():
    alloc = kvc.BlockAllocator(num_blocks=8, block_size=4)
    assert alloc.available == 7
    assert alloc.reserve(5)
    assert alloc.available == 2 and not alloc.can_reserve(3)
    ids = alloc.alloc(5)
    assert len(set(ids)) == 5 and kvc.NULL_BLOCK not in ids
    assert alloc.in_use == 5 and alloc.available == 2
    alloc.free(ids)
    assert alloc.in_use == 0 and alloc.available == 7
    with pytest.raises(ValueError):
        alloc.free([kvc.NULL_BLOCK])
