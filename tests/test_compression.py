"""Error-feedback gradient compression: unbiasedness + convergence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import (
    compress_with_feedback,
    compressed_bytes,
    decompress,
    init_error_feedback,
)


def test_compression_wire_size():
    g = {"w": jnp.ones((1024, 256), jnp.float32)}
    q, _ = compress_with_feedback(g, init_error_feedback(g))
    f32_bytes = 1024 * 256 * 4
    assert compressed_bytes(q) < f32_bytes / 3.5   # ~int8 + scale overhead


def test_error_feedback_accumulates_residual():
    """With a constant gradient, compressed updates converge to the true sum
    (residuals are re-injected, never lost)."""
    g = {"w": jnp.full((256,), 1e-3) + jnp.arange(256) * 1e-6}
    ef = init_error_feedback(g)
    total = jnp.zeros((256,))
    for _ in range(50):
        q, ef = compress_with_feedback(g, ef)
        total = total + decompress(q, g)["w"]
    np.testing.assert_allclose(total, 50 * g["w"], rtol=0.02)


def test_training_converges_with_compression():
    cfg = AdamWConfig(weight_decay=0.0)
    target = jnp.asarray([1.0, -2.0, 0.5, 3.0])
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params, cfg)
    ef = init_error_feedback(params)

    @jax.jit
    def step(params, state, ef):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        q, ef = compress_with_feedback(g, ef)
        g_hat = decompress(q, g)          # (= after the int8 all-reduce)
        p, s = adamw_update(g_hat, state, params, jnp.asarray(0.05), cfg)
        return p, s, ef

    for _ in range(300):
        params, state, ef = step(params, state, ef)
    np.testing.assert_allclose(params["w"], target, atol=0.05)
