"""SLO telemetry tests (repro.obs.slo / repro.obs.recorder): multi-window
burn-rate math over synthetic feeds, escalation/hysteresis state machine,
--slo spec parsing, cluster-merged evaluation, flight-recorder incident
bundles and built-in trigger policies, the benchmark compare gate, and an
end-to-end cluster acceptance run (trace-id flow chains across router and
replica lanes, forced shed, incident capture)."""

import importlib.util
import json
import os
import types

import numpy as np
import pytest

from repro import configs
from repro.obs import (
    BREACH,
    OK,
    WARN,
    FlightRecorder,
    Histogram,
    NULL_TRACER,
    SloMonitor,
    SloTarget,
    Tracer,
    parse_slo_spec,
)

ARCH = "gemma3-1b"


# ---------------------------------------------------------------------------
# burn-rate math + state machine (synthetic feeds, no engine)
# ---------------------------------------------------------------------------


def _ttft_target(threshold=0.1, budget=0.05):
    return SloTarget(name="ttft_p95", kind="histogram", source="ttft",
                     threshold=threshold, budget=budget)


def _observe(mon, h, n_good=0, n_bad=0):
    """Extend the cumulative histogram feed, then evaluate one step."""
    for _ in range(n_good):
        h.add(0.01)
    for _ in range(n_bad):
        h.add(1.0)
    return mon.observe({"ttft": h})


def test_burn_crossing_warn_breach_and_hysteresis():
    """The canonical trajectory: clean -> bad burst -> clean again.  Burn
    rates are exact (windows are observe() counts, feeds are synthetic), so
    every state on the way up and down is asserted."""
    mon = SloMonitor([_ttft_target()])        # short=1 long=4, clear_after=2
    h = Histogram()

    r = _observe(mon, h, n_good=100)
    t = r.targets[0]
    assert (t.state, t.burn_short, t.burn_long) == (OK, 0.0, 0.0)
    assert r.state == OK and not r.transitions

    # burst: 20% bad in the step -> short burn 4.0; 10% bad overall -> long
    # burn 2.0.  Both windows at breach_burn: immediate escalation.
    r = _observe(mon, h, n_good=80, n_bad=20)
    t = r.targets[0]
    assert t.state == BREACH and t.transitioned and t.prev_state == OK
    assert t.burn_short == pytest.approx(4.0)
    assert t.burn_long == pytest.approx(2.0)
    assert r.breaches and r.state == BREACH
    assert t.bad_total == 20 and t.total == 200
    assert "breach" in r.summary()

    # clean step: level drops to WARN (long window still burns 1.33) but
    # hysteresis holds BREACH for clear_after=2 evaluations
    r = _observe(mon, h, n_good=100)
    t = r.targets[0]
    assert t.state == BREACH and not t.transitioned
    assert t.burn_short == 0.0
    assert t.burn_long == pytest.approx(20 / 300 / 0.05)

    # second calm evaluation: clears — to WARN, since the long window still
    # spends budget exactly at rate 1.0
    r = _observe(mon, h, n_good=100)
    t = r.targets[0]
    assert t.state == WARN and t.transitioned and t.prev_state == BREACH
    assert t.burn_long == pytest.approx(1.0)

    _observe(mon, h, n_good=100)              # long window 1.0: WARN holds
    r = _observe(mon, h, n_good=100)          # bad burst slides out: calm 1
    assert r.targets[0].state == WARN
    r = _observe(mon, h, n_good=100)          # calm 2: clears to OK
    t = r.targets[0]
    assert t.state == OK and t.transitioned and t.prev_state == WARN
    assert mon.state == OK


def test_breach_requires_both_windows():
    """A short-window spike over a calm long window must not page: that is
    the whole point of multi-window burn."""
    mon = SloMonitor([_ttft_target()])
    h = Histogram()
    _observe(mon, h, n_good=400)
    # 20% bad in this step (short burn 4.0) but only ~1% bad overall
    r = _observe(mon, h, n_good=16, n_bad=4)
    t = r.targets[0]
    assert t.burn_short == pytest.approx(4.0)
    assert t.burn_long < 1.0
    assert t.state == OK


def test_ratio_target_and_idle_window():
    mon = SloMonitor([SloTarget(name="shed_rate", kind="ratio",
                                source="shed/offered", threshold=0.05,
                                budget=0.05)])
    r = mon.observe({"shed": 0, "offered": 100})
    assert r.targets[0].state == OK
    r = mon.observe({"shed": 20, "offered": 200})
    t = r.targets[0]
    assert t.burn_short == pytest.approx(0.2 / 0.05)
    assert t.burn_long == pytest.approx(0.1 / 0.05)
    assert t.state == BREACH
    # idle window (counters unchanged) spends no budget: level drops, the
    # hysteresis holds the state
    r = mon.observe({"shed": 20, "offered": 200})
    t = r.targets[0]
    assert t.burn_short == 0.0 and t.state == BREACH and not t.transitioned


def test_floor_target_gauge_mean_and_startup_grace():
    mon = SloMonitor([SloTarget(name="mfu_floor", kind="floor",
                                source="mfu_decode", threshold=0.5)])
    r = mon.observe({"mfu_decode": 1.0})
    assert r.targets[0].state == OK
    assert r.targets[0].burn_short == pytest.approx(0.5)
    # gauge collapses: short burn jumps at once, long mean degrades slowly
    r = mon.observe({"mfu_decode": 0.1})
    t = r.targets[0]
    assert t.burn_short == pytest.approx(5.0)
    assert t.burn_long == pytest.approx(0.5 / 0.55)
    assert t.state == OK                       # long window still healthy
    mon.observe({"mfu_decode": 0.1})
    r = mon.observe({"mfu_decode": 0.1})
    assert r.targets[0].state == WARN          # long mean now 0.325
    r = mon.observe({"mfu_decode": 0.1})       # window all-collapsed
    assert r.targets[0].state == BREACH
    # zero gauge = no signal yet, never an alarm (serve-loop startup)
    calm = SloMonitor([SloTarget(name="mfu_floor", kind="floor",
                                 source="mfu_decode", threshold=0.5)])
    r = calm.observe({"mfu_decode": 0.0})
    assert r.targets[0].state == OK and r.targets[0].burn_short == 0.0


def test_missing_or_empty_sources_burn_nothing():
    mon = SloMonitor([_ttft_target(),
                      SloTarget(name="shed_rate", kind="ratio",
                                source="shed/offered", threshold=0.05,
                                budget=0.05)])
    r = mon.observe({})                        # nothing wired yet
    assert r.state == OK
    r = mon.observe({"ttft": Histogram(), "shed": 0, "offered": 0})
    assert r.state == OK


def test_report_worst_of_and_dict_shape():
    mon = SloMonitor([_ttft_target(),
                      SloTarget(name="mfu_floor", kind="floor",
                                source="mfu_decode", threshold=1e-9)])
    h = Histogram()
    for _ in range(10):
        h.add(1.0)                             # 100% bad
    r = mon.observe({"ttft": h, "mfu_decode": 1.0})
    assert [t.state for t in r.targets] == [BREACH, OK]
    assert r.state == BREACH                   # worst-of
    d = json.loads(json.dumps(r.as_dict()))
    assert d["state"] == BREACH
    assert d["targets"][0]["transitioned"] is True
    assert SloMonitor([]).observe({}).state == OK


def test_target_and_monitor_validation():
    with pytest.raises(ValueError, match="unknown SLO kind"):
        SloTarget(name="x", kind="gauge", source="y", threshold=1.0)
    with pytest.raises(ValueError, match="budget"):
        SloTarget(name="x", kind="histogram", source="y", threshold=1.0,
                  budget=0.0)
    with pytest.raises(ValueError, match="num/den"):
        SloTarget(name="x", kind="ratio", source="shed", threshold=0.05)
    targets = [_ttft_target()]
    with pytest.raises(ValueError):
        SloMonitor(targets, short_window=0)
    with pytest.raises(ValueError):
        SloMonitor(targets, short_window=4, long_window=2)
    with pytest.raises(ValueError):
        SloMonitor(targets, clear_after=0)


def test_parse_slo_spec():
    by = {t.name: t for t in parse_slo_spec(
        "ttft_p95=0.25, latency_p99=1.0, shed_rate=0.05, mfu_floor=1e-6")}
    t = by["ttft_p95"]
    assert (t.kind, t.source, t.threshold) == ("histogram", "ttft", 0.25)
    assert t.budget == pytest.approx(0.05)
    assert by["latency_p99"].budget == pytest.approx(0.01)
    # budgets parse to clean decimals so burn==breach_burn compares exact
    assert by["ttft_p95"].budget == 0.05
    s = by["shed_rate"]
    assert (s.kind, s.source, s.budget) == ("ratio", "shed/offered", 0.05)
    f = by["mfu_floor"]
    assert (f.kind, f.source, f.threshold) == ("floor", "mfu_decode", 1e-6)
    for bad in ("", "   ", "nope=1", "ttft_p95", "ttft_p95=fast",
                "ttft_pxx=1", "ttft_p0=1", "ttft_p100=1", "queue_p95=1"):
        with pytest.raises(ValueError):
            parse_slo_spec(bad)


def test_cluster_merged_histogram_burns_like_concatenated_feed():
    """The cluster path merges per-replica histograms losslessly, so the
    merged monitor must report exactly the burn of one monitor fed the
    concatenated stream."""
    rng = np.random.default_rng(0)
    a_vals = list(rng.lognormal(-3, 1, 120)) + [1.0] * 9
    b_vals = list(rng.lognormal(-3, 1, 80)) + [1.0] * 13
    a, b, one = Histogram(), Histogram(), Histogram()
    for v in a_vals:
        a.add(float(v))
        one.add(float(v))
    for v in b_vals:
        b.add(float(v))
        one.add(float(v))
    a.merge(b)
    m_merged = SloMonitor([_ttft_target(threshold=0.5)])
    m_single = SloMonitor([_ttft_target(threshold=0.5)])
    rm = m_merged.observe({"ttft": a}).targets[0]
    rs = m_single.observe({"ttft": one}).targets[0]
    assert rm.state == rs.state
    assert rm.burn_short == rs.burn_short and rm.burn_long == rs.burn_long
    assert (rm.bad_total, rm.total) == (rs.bad_total, rs.total)


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


def test_recorder_bundle_contents(tmp_path):
    tr = Tracer(capacity=64, name="unit", pid=7)
    c = tr.intern("work")
    tr.begin(c)
    tr.flow_start(tr.intern("req"), 3)
    tr.end(c)
    rec = FlightRecorder(str(tmp_path), tracers=[tr, NULL_TRACER],
                         metadata={"arch": "unit"})
    rec.add_source("counts", lambda: {"x": 1})
    rec.add_source("boom", lambda: 1 / 0)
    path = rec.trigger("unit test: weird/reason!", extra={"k": "v"})
    assert os.path.basename(path) == "incident-001-unit-test-weird-reason.json"
    with open(path) as f:
        b = json.load(f)                       # self-contained valid JSON
    assert b["trigger"]["reason"] == "unit test: weird/reason!"
    assert b["trigger"]["seq"] == 1 and b["trigger"]["context"] == {"k": "v"}
    assert b["metadata"] == {"arch": "unit"}
    [lane] = b["tracers"]                      # NULL_TRACER never registers
    assert (lane["name"], lane["pid"], lane["live_read"]) == ("unit", 7, True)
    assert [e["ph"] for e in lane["events"]] == ["B", "s", "E"]
    assert lane["recorded"] == 3 and lane["dropped"] == 0
    assert b["sources"]["counts"] == {"x": 1}
    assert "ZeroDivisionError" in b["sources"]["boom"]["error"]
    assert rec.incidents == [path]


def test_recorder_caps_events_to_newest(tmp_path):
    tr = Tracer(capacity=256, name="t")
    c = tr.intern("v")
    for i in range(100):
        tr.counter(c, float(i))
    rec = FlightRecorder(str(tmp_path), tracers=[tr], max_events=10)
    with open(rec.trigger("cap")) as f:
        evs = json.load(f)["tracers"][0]["events"]
    assert [e["value"] for e in evs] == [float(i) for i in range(90, 100)]


def test_recorder_rate_limits_per_reason(tmp_path):
    rec = FlightRecorder(str(tmp_path), min_interval_s=60.0)
    assert rec.trigger("shed") is not None
    assert rec.trigger("shed") is None         # same reason, inside window
    assert rec.suppressed == 1
    other = rec.trigger("allocator-pressure")  # different reason passes
    assert other is not None and "incident-002" in other
    assert len(rec.incidents) == 2


def test_record_breaches_only_on_transition(tmp_path):
    mon = SloMonitor([_ttft_target()])
    h = Histogram()
    _observe(mon, h, n_good=100)
    report = _observe(mon, h, n_bad=100)       # transition into breach
    assert FlightRecorder.is_breach(report)
    rec = FlightRecorder(str(tmp_path))
    paths = rec.record_breaches(report)
    assert len(paths) == 1
    with open(paths[0]) as f:
        b = json.load(f)
    assert b["trigger"]["reason"] == "slo-breach-ttft_p95"
    ctx = b["trigger"]["context"]
    assert ctx["prev_state"] == OK and ctx["burn_short"] >= 2.0
    assert ctx["report"]["state"] == BREACH
    # still breaching, but no transition: no new bundle
    report = _observe(mon, h, n_bad=100)
    assert report.state == BREACH and rec.record_breaches(report) == []


def _fake_engine(free, in_use, drafted=0, accepted=0,
                 preemptions=0, admitted=0):
    alloc = types.SimpleNamespace(stats=lambda: {
        "in_use": in_use, "reserved": 0, "free": free})
    metrics = types.SimpleNamespace(
        spec_draft_tokens=drafted, spec_accepted_tokens=accepted,
        acceptance_rate=accepted / max(1, drafted),
        preemptions=preemptions)
    sched = types.SimpleNamespace(admitted_total=admitted)
    return types.SimpleNamespace(alloc=alloc, metrics=metrics,
                                 scheduler=sched)


def test_check_engine_pressure_triggers(tmp_path):
    rec = FlightRecorder(str(tmp_path))
    assert rec.check_engine(_fake_engine(free=50, in_use=50)) == []
    paths = rec.check_engine(_fake_engine(free=2, in_use=98))
    assert len(paths) == 1 and "allocator-pressure" in paths[0]
    with open(paths[0]) as f:
        assert json.load(f)["trigger"]["context"]["free"] == 2
    paths = rec.check_engine(
        _fake_engine(free=50, in_use=50, drafted=100, accepted=5))
    assert len(paths) == 1 and "spec-acceptance-collapse" in paths[0]
    # below min_drafted: too little evidence to call a collapse
    assert rec.check_engine(
        _fake_engine(free=50, in_use=50, drafted=10, accepted=0)) == []
    # preemption pressure: victims swapped for over half of admissions
    paths = rec.check_engine(
        _fake_engine(free=50, in_use=50, preemptions=6, admitted=8))
    assert len(paths) == 1 and "preemption-pressure" in paths[0]
    with open(paths[0]) as f:
        ctx = json.load(f)["trigger"]["context"]
    assert ctx["preemptions"] == 6 and ctx["admitted_total"] == 8
    # same ratio under the threshold: no bundle
    assert rec.check_engine(
        _fake_engine(free=50, in_use=50, preemptions=2, admitted=8)) == []


# ---------------------------------------------------------------------------
# benchmarks/compare.py: the CI regression gate
# ---------------------------------------------------------------------------

_COMPARE_PATH = os.path.join(os.path.dirname(__file__), "..",
                             "benchmarks", "compare.py")
_spec = importlib.util.spec_from_file_location("bench_compare", _COMPARE_PATH)
bench_compare = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_compare)


def _report(tmp_path, fname, rows, errors=None):
    doc = {"sections": {"s": {"rows": rows, "seconds": 1.0}}}
    if errors:
        doc["errors"] = errors
    p = tmp_path / fname
    p.write_text(json.dumps(doc))
    return str(p)


def _gate(tmp_path, base_rows, head_rows, errors=None):
    base = _report(tmp_path, "base.json", base_rows)
    head = _report(tmp_path, "head.json", head_rows, errors=errors)
    return bench_compare.main([base, head, "--fail-on-change"])


def test_compare_gate_fails_on_regression(tmp_path):
    rows = [{"name": "s/count", "value": 100, "derived": ""}]
    assert _gate(tmp_path, rows, rows) == 0
    worse = [{"name": "s/count", "value": 200, "derived": ""}]
    assert _gate(tmp_path, rows, worse) == 1


def test_compare_gate_exempts_informational_rows(tmp_path):
    base = [{"name": "obs/decode_overhead_pct", "value": 0.5, "derived": ""},
            {"name": "x/flaky", "value": 1.0,
             "derived": "< 2 (informational)"},
            {"name": "c/bar", "value": "informational", "derived": ""}]
    head = [{"name": "obs/decode_overhead_pct", "value": -3.0, "derived": ""},
            {"name": "x/flaky", "value": 9.0,
             "derived": "< 2 (informational)"},
            {"name": "c/bar", "value": "informational", "derived": ""}]
    assert _gate(tmp_path, base, head) == 0


def test_compare_gate_wide_tolerance_for_wall_clock_rows(tmp_path):
    base = [{"name": "s/tick_us", "value": 10.0, "derived": ""}]
    assert _gate(tmp_path, base,
                 [{"name": "s/tick_us", "value": 25.0, "derived": ""}]) == 0
    assert _gate(tmp_path, base,
                 [{"name": "s/tick_us", "value": 50.0, "derived": ""}]) == 1


def test_compare_gate_removed_gates_added_does_not(tmp_path):
    rows = [{"name": "s/count", "value": 100, "derived": ""}]
    grown = rows + [{"name": "s/new_row", "value": 1, "derived": ""}]
    assert _gate(tmp_path, rows, grown) == 0   # new coverage never gates
    assert _gate(tmp_path, grown, rows) == 1   # vanished row always gates


def test_compare_gate_fails_on_head_section_errors(tmp_path):
    rows = [{"name": "s/count", "value": 100, "derived": ""}]
    assert _gate(tmp_path, rows, rows, errors={"s": "boom"}) == 1


# ---------------------------------------------------------------------------
# end-to-end: cluster trace reconstruction + forced shed + incident capture
# ---------------------------------------------------------------------------


def test_cluster_trace_slo_and_incidents_end_to_end(tmp_path):
    """The ISSUE acceptance run: 2 traced replicas with prefix cache and
    speculation on, a traced router with a tight in-flight window.  Every
    finished request must be reconstructable by trace id via connected
    flow events (s on the router lane, f on a replica lane); the forced
    shed must leave instants, an SLO breach, and an incident bundle."""
    from repro import cluster
    from repro.cluster import metrics as cmetrics

    cfg = configs.get_smoke(ARCH)
    pool = cluster.ReplicaPool(cfg, 2, slots=2, max_seq=48, block_size=4,
                               max_chunk=8, trace=True, prefix_cache=True,
                               speculative=True)
    pool.warmup()
    router_tracer = Tracer(name="router", pid=len(pool))
    rec = FlightRecorder(str(tmp_path / "incidents"),
                         tracers=[router_tracer],
                         metadata={"arch": cfg.name})
    for i, e in enumerate(pool.engines):
        rec.attach_engine(e, name=f"replica{i}")
    router = cluster.Router(pool, policy="round-robin", max_pending=3,
                            async_dispatch=False, tracer=router_tracer,
                            recorder=rec)

    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    handles = []
    for k in range(8):
        tail = rng.integers(0, cfg.vocab, size=int(rng.integers(2, 6)))
        h = router.submit(np.concatenate([prefix, tail]).astype(np.int32),
                          max_new=4)
        if h is not None:
            handles.append(h)
        router.dispatch_sync()
        if k == 3:
            pool.run_sync(max_ticks=5000)     # drain the first wave
    router.dispatch_sync()
    pool.run_sync(max_ticks=5000)

    # the tight window shed some of the burst, the rest finished
    assert router.shed >= 1 and len(handles) == 8 - router.shed
    for h in handles:
        assert len(h.result(timeout=0)) == 4
        assert h.trace_id == h.crid           # router-minted, cluster-unique

    doc = pool.export_trace(str(tmp_path / "trace.json"),
                            extra_tracers=[router_tracer])
    evs = doc["traceEvents"]
    assert {e["pid"] for e in evs} >= {0, 1, len(pool)}

    # every finished request: one connected flow chain starting on the
    # router lane and finishing on the replica lane that served it
    flows_by_id = {}
    for e in evs:
        if e.get("cat") == "flow":
            flows_by_id.setdefault(e["id"], []).append(e)
    assert set(flows_by_id) == {h.trace_id for h in handles}
    finish_pids = set()
    for h in handles:
        # the export concatenates lanes; wall-clock order reconstructs the
        # cross-lane chain (all tracers share one perf_counter_ns clock)
        chain = sorted(flows_by_id[h.trace_id], key=lambda e: e["ts"])
        assert chain[0]["ph"] == "s" and chain[0]["pid"] == len(pool)
        assert chain[1]["ph"] == "t" and chain[1]["pid"] == len(pool)  # route
        assert chain[-1]["ph"] == "f" and chain[-1]["pid"] in (0, 1)
        assert {e["ph"] for e in chain[1:-1]} == {"t"}
        finish_pids.add(chain[-1]["pid"])
    assert finish_pids == {0, 1}              # round-robin used both lanes

    # shed decisions left instants on the router lane, one per shed
    sheds = [e for e in evs if e["ph"] == "i" and e["name"] == "shed"
             and e["pid"] == len(pool)]
    assert len(sheds) == router.shed

    # shared prefix across the waves: at least one replica served from cache
    assert sum(e.metrics.prefix_hits for e in pool.engines) >= 1

    # incident bundles: the router shed trigger fired with full evidence
    assert rec.incidents
    with open(rec.incidents[0]) as f:
        b = json.load(f)
    assert b["trigger"]["reason"] == "shed"
    assert b["trigger"]["context"]["max_pending"] == 3
    lanes = {t["name"] for t in b["tracers"]}
    assert "router" in lanes and len(lanes) == 3
    assert "replica0.metrics" in b["sources"]
    assert "replica1.scheduler" in b["sources"]
    assert "in_use" in b["sources"]["replica0.allocator"]

    # cluster-aggregated SLO: shed rate breaches a tight objective and the
    # recorder captures the breach transition
    m = cmetrics.aggregate(pool, router, elapsed_s=1.0)
    snap = cluster.slo_snapshot(m)
    mon = SloMonitor(parse_slo_spec(
        "ttft_p95=60.0, latency_p95=60.0, shed_rate=0.01, mfu_floor=1e-12"))
    report = mon.observe(snap)
    by = {t.name: t for t in report.targets}
    assert by["ttft_p95"].state == OK and by["mfu_floor"].state == OK
    assert by["shed_rate"].state == BREACH
    paths = rec.record_breaches(report)
    assert len(paths) == 1 and "slo-breach-shed_rate" in paths[0]
    pool.stop()
