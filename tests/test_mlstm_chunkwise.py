"""Chunkwise-parallel mLSTM == sequential recurrence (and decode)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import ssm
from repro.models.ssm import (
    MLSTMState,
    _chunked_scan,
    _mlstm_chunkwise,
    init_mlstm,
    mlstm_block,
)


def _cfg():
    return dataclasses.replace(
        configs.get_smoke("xlstm-1.3b"), d_model=32, n_heads=2, n_kv_heads=2,
    )


def _inputs(B, S, H, hd, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd)) * hd ** -0.5
    v = jax.random.normal(ks[2], (B, S, H, hd))
    i_pre = jax.random.normal(ks[3], (B, S, H))
    f_pre = jax.random.normal(ks[4], (B, S, H)) + 3.0
    return q, k, v, i_pre, f_pre


def _sequential(q, k, v, i_pre, f_pre, st):
    def step(s, t):
        qt, kt, vt, it, ft = t
        log_f = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(log_f + s.m, it)
        f_sc = jnp.exp(log_f + s.m - m_new)[..., None]
        i_sc = jnp.exp(it - m_new)[..., None]
        C = f_sc[..., None] * s.C + (i_sc * vt)[..., None] * kt[..., None, :]
        n = f_sc * s.n + i_sc * kt
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt))[..., None], 1.0)
        h = jnp.einsum("bhij,bhj->bhi", C, qt) / denom
        return MLSTMState(C, n, m_new), h

    S = q.shape[1]
    return _chunked_scan(step, st, (q, k, v, i_pre, f_pre), S)


def test_chunkwise_matches_sequential():
    B, S, H, hd = 2, 64, 2, 8
    q, k, v, i_pre, f_pre = _inputs(B, S, H, hd)
    st = MLSTMState(
        C=jnp.zeros((B, H, hd, hd)), n=jnp.zeros((B, H, hd)),
        m=jnp.full((B, H), -1e30),
    )
    seq_state, seq_h = _sequential(q, k, v, i_pre, f_pre, st)
    chk_h, chk_state = _mlstm_chunkwise(q, k, v, i_pre, f_pre, st, chunk=16)
    np.testing.assert_allclose(
        chk_h, seq_h.reshape(B, S, H * hd), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(chk_state.C, seq_state.C, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(chk_state.n, seq_state.n, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(chk_state.m, seq_state.m, rtol=1e-4, atol=1e-4)


def test_chunkwise_with_nonzero_initial_state():
    B, S, H, hd = 1, 32, 2, 8
    q, k, v, i_pre, f_pre = _inputs(B, S, H, hd, seed=7)
    st = MLSTMState(
        C=jax.random.normal(jax.random.PRNGKey(9), (B, H, hd, hd)),
        n=jnp.abs(jax.random.normal(jax.random.PRNGKey(10), (B, H, hd))),
        m=jnp.zeros((B, H)),
    )
    _, seq_h = _sequential(q, k, v, i_pre, f_pre, st)
    chk_h, _ = _mlstm_chunkwise(q, k, v, i_pre, f_pre, st, chunk=8)
    np.testing.assert_allclose(
        chk_h, seq_h.reshape(B, S, H * hd), rtol=1e-4, atol=1e-5)


def test_mlstm_block_decode_consistency_still_holds():
    """mlstm_block training path (now chunkwise) vs token-by-token decode."""
    cfg = _cfg()
    p = init_mlstm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    full, _ = mlstm_block(x, p, cfg)
    st = ssm.init_mlstm_state(cfg, B)
    outs = []
    for t in range(S):
        o, st = mlstm_block(x[:, t:t + 1], p, cfg, state=st)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)
