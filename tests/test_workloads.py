"""Workload extraction sanity: MAC counts vs published model costs."""

import pytest

from repro.core.workloads import (
    bert_base,
    mobilenet_v2,
    resnet18,
    total_macs,
    vit_b_16,
)


def test_resnet18_macs():
    # torchvision ResNet18 @224: ~1.82 GMACs per image
    per_img = total_macs(resnet18(batch=1)) / 1e9
    assert per_img == pytest.approx(1.82, rel=0.05), per_img


def test_mobilenet_v2_macs():
    # ~0.30-0.32 GMACs per image
    per_img = total_macs(mobilenet_v2(batch=1)) / 1e9
    assert per_img == pytest.approx(0.31, rel=0.15), per_img


def test_vit_b16_macs():
    # ViT-B/16 @224: ~17.6 GMACs per image
    per_img = total_macs(vit_b_16(batch=1)) / 1e9
    assert per_img == pytest.approx(17.6, rel=0.05), per_img


def test_bert_base_macs():
    # BERT-base @ seq 512: ~48 GMACs per sequence (incl. attention matmuls)
    per_seq = total_macs(bert_base(batch=1)) / 1e9
    assert per_seq == pytest.approx(48.3, rel=0.07), per_seq


def test_depthwise_grouping_preserves_macs():
    from repro.core.workloads import depthwise_gemm

    g, count = depthwise_gemm(batch=4, hw=56, c=96, k=3, s=1, group=8)
    # useful MACs = B * OH*OW * k*k * C regardless of grouping
    assert g.macs * count == 4 * 56 * 56 * 9 * 96
