"""Property-based simulator invariants.

Optional module: requires `hypothesis` (requirements-dev.txt).  The
deterministic invariants and reproduction-band checks live in
test_simulator.py and always run.
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.dataflow import GemmShape
from repro.core.simulator import OpenGeMMSimulator, ablation_architectures

dim8 = st.integers(1, 32).map(lambda i: 8 * i)


@given(M=dim8, K=dim8, N=dim8)
@settings(max_examples=60, deadline=None)
def test_utilization_bounded(M, K, N):
    sim = OpenGeMMSimulator()
    u = sim.utilization(GemmShape(M, K, N), repeats=10)
    assert 0 < u <= 1


@given(M=dim8, K=dim8, N=dim8)
@settings(max_examples=40, deadline=None)
def test_mechanisms_monotone(M, K, N):
    """Enabling each mechanism never hurts utilization materially.

    (Exactly at degenerate single-K-tile workloads, pre-fetch adds a few fill
    cycles with nothing to hide — the paper's Fig. 5 whiskers show the same
    overlap at the bottom — so the property holds to 2%.)
    """
    g = GemmShape(M, K, N)
    archs = ablation_architectures()
    u = {k: OpenGeMMSimulator(c).utilization(g, repeats=10) for k, c in archs.items()}
    tol = lambda x: x * 1.02 + 1e-9
    assert u["arch1_baseline"] <= tol(u["arch2_cpl"])
    assert u["arch2_cpl"] <= tol(u["arch3_cpl_buf2"])
    assert u["arch3_cpl_buf2"] <= tol(u["arch4_all_buf2"])
    assert u["arch4_all_buf2"] <= tol(u["arch4_all_buf3"])
    assert u["arch4_all_buf3"] <= tol(u["arch4_all_buf4"])


@given(M=dim8, K=dim8, N=dim8, reps=st.integers(1, 12))
@settings(max_examples=40, deadline=None)
def test_timing_decomposition(M, K, N, reps):
    sim = OpenGeMMSimulator()
    ts = sim.simulate_sequence([GemmShape(M, K, N)] * reps)
    for t in ts:
        assert t.total_cycles == (
            t.config_cycles + t.fill_cycles + t.compute_cycles
            + t.input_stall_cycles + t.output_stall_cycles
        )
        assert t.compute_cycles >= 1
    # CPL: later calls pay less config than the first
    if reps > 1:
        assert ts[1].config_cycles <= ts[0].config_cycles
