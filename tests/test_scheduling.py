"""Multi-tenant SLO scheduling tests: the unified RequestSpec API across
all three submit surfaces, priority-class admission, KV-swap preemption
round trips, on-device sampling (seeded reproducibility + distribution
equivalence), and router-level tenant fairness / class-aware shedding."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.serving import kv_cache as kvc
from repro.serving.engine import Engine
from repro.serving.request import (
    GREEDY,
    PRIORITIES,
    RequestSpec,
    SamplingParams,
    as_spec,
    priority_rank,
)
from repro.serving.scheduler import Phase, Scheduler

ARCH = "gemma3-1b"


@pytest.fixture(scope="module")
def warm():
    """One warmed engine per module: later engines share its jitted steps
    so the file pays each compile once."""
    cfg = configs.get_smoke(ARCH)
    eng = Engine(cfg, slots=2, max_seq=64, block_size=4, seed=0)
    eng.warmup()
    return cfg, eng


def _engine(cfg, warm_eng, **kw):
    eng = Engine(cfg, **kw)
    eng.share_steps_from(warm_eng)
    eng.warmup()
    return eng


# ---------------------------------------------------------------------------
# RequestSpec / as_spec (host-only)
# ---------------------------------------------------------------------------


def test_request_spec_validation():
    p = np.arange(4, dtype=np.int32)
    spec = RequestSpec(prompt=[1, 2, 3], max_new=2)
    assert spec.prompt.dtype == np.int32 and not spec.prompt.flags.writeable
    assert spec.sampling is GREEDY and spec.sampling.is_greedy
    with pytest.raises(ValueError):
        RequestSpec(prompt=[], max_new=1)
    with pytest.raises(ValueError):
        RequestSpec(prompt=p, max_new=0)
    with pytest.raises(ValueError):
        RequestSpec(prompt=p, max_new=1, priority="urgent")
    with pytest.raises(TypeError):
        RequestSpec(prompt=p, max_new=1, sampling="hot")
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(Exception):       # frozen dataclass
        spec.max_new = 9
    assert priority_rank("interactive") < priority_rank("batch")
    with pytest.raises(ValueError):
        priority_rank("gold")


def test_as_spec_shim_single_warning_path():
    p = np.arange(3, dtype=np.int32)
    with pytest.warns(DeprecationWarning, match="RequestSpec"):
        spec = as_spec(p, 4, eos_token=7)
    assert spec.max_new == 4 and spec.eos_token == 7
    # spec passthrough: no warning, and conflicting kwargs are an error
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert as_spec(spec) is spec
    with pytest.raises(TypeError):
        as_spec(spec, 9)
    with pytest.raises(TypeError):
        as_spec(p)                        # legacy form requires max_new


def test_spec_accepted_by_scheduler_and_priority_admission():
    sched = Scheduler(slots=1)
    b = sched.submit(RequestSpec(prompt=[1, 2], max_new=2, priority="batch",
                                 tenant="t1"))
    i = sched.submit(RequestSpec(prompt=[3, 4], max_new=2,
                                 priority="interactive",
                                 sampling=SamplingParams(seed=99)))
    assert [r.rid for r in sched.queue] == [i.rid, b.rid]  # class rank first
    assert i.sample_seed == 99 and b.sample_seed == b.rid  # seed resolution
    assert b.tenant == "t1"
    admitted = sched.admit(lambda r: True)
    assert [r.rid for _, r in admitted] == [i.rid]         # interactive first
    # preempt returns the victim to the *front* of its class queue
    i.phase = Phase.DECODE
    i.out_tokens.append(5)
    slot = sched.preempt(i)
    assert slot == 0 and i.swapped and i.preemptions == 1
    assert sched.queue[0] is i and sched.preemptions == 1
    readmit = sched.admit(lambda r: True)
    assert readmit[0][1] is i and i.phase is Phase.DECODE  # no re-prefill


# ---------------------------------------------------------------------------
# sampling head (model-level, no engine)
# ---------------------------------------------------------------------------


def test_sample_tokens_greedy_rows_exact_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    seeds = jnp.arange(4, dtype=jnp.int32)
    gen_idx = jnp.zeros(4, jnp.int32)
    toks = M.sample_tokens(logits, seeds, gen_idx,
                           jnp.zeros(4), jnp.zeros(4, jnp.int32), jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.argmax(np.asarray(logits), -1))
    # top_k=1 at any temperature is also argmax
    toks1 = M.sample_tokens(logits, seeds, gen_idx,
                            jnp.full(4, 2.0), jnp.ones(4, jnp.int32),
                            jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(toks1),
                                  np.argmax(np.asarray(logits), -1))


def test_sample_tokens_topk_topp_mask_and_distribution():
    """Truncation: tokens outside top-k/top-p never appear.  Distribution:
    the empirical histogram over many (seed, idx) streams tracks softmax
    within a small total-variation distance."""
    rng = np.random.default_rng(1)
    V, N = 8, 4000
    logits_row = rng.normal(size=V).astype(np.float32)
    logits = jnp.asarray(np.tile(logits_row, (N, 1)))
    seeds = jnp.arange(N, dtype=jnp.int32)
    gen_idx = jnp.zeros(N, jnp.int32)

    k = 3
    toks = np.asarray(M.sample_tokens(
        logits, seeds, gen_idx, jnp.ones(N), jnp.full(N, k, jnp.int32),
        jnp.ones(N)))
    topk = set(np.argsort(logits_row)[-k:].tolist())
    assert set(toks.tolist()) <= topk

    p = 0.6
    toks_p = np.asarray(M.sample_tokens(
        logits, seeds, gen_idx, jnp.ones(N), jnp.zeros(N, jnp.int32),
        jnp.full(N, p)))
    probs = np.exp(logits_row - logits_row.max())
    probs /= probs.sum()
    order = np.argsort(-probs)
    keep, mass = set(), 0.0
    for t in order:                      # exclusive-cumsum nucleus
        keep.add(int(t))
        mass += probs[t]
        if mass >= p:
            break
    assert set(toks_p.tolist()) <= keep

    # full distribution (no truncation): TV distance to softmax
    toks_f = np.asarray(M.sample_tokens(
        logits, seeds, gen_idx, jnp.ones(N), jnp.zeros(N, jnp.int32),
        jnp.ones(N)))
    emp = np.bincount(toks_f, minlength=V) / N
    assert 0.5 * np.abs(emp - probs).sum() < 0.05


def test_fold_keys_batch_composition_independent():
    """The PRNG stream is a pure function of (seed, generation index) —
    a request's draws do not depend on who else is in the batch."""
    one = M._fold_keys(jnp.asarray([7], jnp.int32), jnp.asarray([3], jnp.int32))
    many = M._fold_keys(jnp.asarray([1, 7, 9], jnp.int32),
                        jnp.asarray([0, 3, 5], jnp.int32))
    np.testing.assert_array_equal(np.asarray(one)[0], np.asarray(many)[1])


# ---------------------------------------------------------------------------
# engine: greedy identity, sampling reproducibility
# ---------------------------------------------------------------------------


def test_greedy_spec_token_identical_to_legacy(warm):
    cfg, weng = warm
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 3, 7)]
    e1 = _engine(cfg, weng, slots=2, max_seq=32, block_size=4, seed=0)
    for p in prompts:
        e1.submit(RequestSpec(prompt=p, max_new=4))
    r1 = e1.run()
    e2 = _engine(cfg, weng, slots=2, max_seq=32, block_size=4, seed=0)
    with pytest.warns(DeprecationWarning):
        for p in prompts:
            e2.submit(p, max_new=4)
    r2 = e2.run()
    assert sorted(r1) == sorted(r2)
    for rid in r1:
        np.testing.assert_array_equal(r1[rid], r2[rid])
    assert e1.metrics.sampled_tokens == 0    # greedy batches never sample


def test_sampling_seeded_reproducible_and_divergent(warm):
    cfg, weng = warm
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, size=6).astype(np.int32)
               for _ in range(2)]

    def run(seed):
        eng = _engine(cfg, weng, slots=2, max_seq=32, block_size=4,
                      sampling=True, seed=0)
        sp = SamplingParams(temperature=0.9, top_k=24, top_p=0.95, seed=seed)
        reqs = [eng.submit(RequestSpec(prompt=p, max_new=5, sampling=sp))
                for p in prompts]
        out = eng.run()
        eng.alloc.check()
        assert eng.metrics.sampled_tokens == sum(len(v) for v in out.values())
        return [out[r.rid] for r in reqs]

    a, b, c = run(11), run(11), run(12)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)     # bitwise-reproducible streams
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_mixed_batch_keeps_greedy_rows_identical(warm):
    """A sampling request in the batch reroutes the whole batch through the
    sampling step — the greedy rows must still match their solo greedy run
    token for token."""
    cfg, weng = warm
    rng = np.random.default_rng(5)
    gp = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    sp_prompt = rng.integers(0, cfg.vocab, size=4).astype(np.int32)

    solo = _engine(cfg, weng, slots=2, max_seq=32, block_size=4, seed=0)
    g = solo.submit(RequestSpec(prompt=gp, max_new=5))
    ref = solo.run()[g.rid]

    mixed = _engine(cfg, weng, slots=2, max_seq=32, block_size=4,
                    sampling=True, seed=0)
    g2 = mixed.submit(RequestSpec(prompt=gp, max_new=5))
    mixed.submit(RequestSpec(
        prompt=sp_prompt, max_new=5,
        sampling=SamplingParams(temperature=1.0, seed=2)))
    out = mixed.run()
    np.testing.assert_array_equal(out[g2.rid], ref)
    assert mixed.metrics.sampled_tokens > 0


# ---------------------------------------------------------------------------
# KV-swap preemption
# ---------------------------------------------------------------------------


def test_swap_blocks_roundtrip_unit():
    """swap_out -> zero the pool blocks -> swap_in restores bytes exactly,
    for float pools and int8+scales pools (grouped 5-D layout)."""
    rng = np.random.default_rng(6)
    nb, bs, H, D, G = 5, 4, 2, 8, 3
    fl = kvc.PagedKVCache(
        k=jnp.asarray(rng.normal(size=(nb, bs, H, D)).astype(np.float32)),
        v=jnp.asarray(rng.normal(size=(nb, bs, H, D)).astype(np.float32)))
    q = kvc.PagedKVCache(
        k=jnp.asarray(rng.integers(-127, 128, size=(G, nb, bs, H, D))
                      .astype(np.int8)),
        v=jnp.asarray(rng.integers(-127, 128, size=(G, nb, bs, H, D))
                      .astype(np.int8)),
        k_scale=jnp.asarray(rng.uniform(0.1, 1.0, size=(G, nb, bs, H))
                            .astype(np.float32)),
        v_scale=jnp.asarray(rng.uniform(0.1, 1.0, size=(G, nb, bs, H))
                            .astype(np.float32)))
    ids = [3, 1]
    saved = kvc.swap_out_blocks((fl, q), ids)
    assert saved[1]["k"].dtype == np.int8          # payload keeps pool dtype
    ix = np.asarray(ids)
    zero = (
        kvc.PagedKVCache(k=fl.k.at[ix].set(0), v=fl.v.at[ix].set(0)),
        kvc.PagedKVCache(k=q.k.at[:, ix].set(0), v=q.v.at[:, ix].set(0),
                         k_scale=q.k_scale.at[:, ix].set(0),
                         v_scale=q.v_scale.at[:, ix].set(0)),
    )
    back = kvc.swap_in_blocks(zero, ids, saved)
    np.testing.assert_array_equal(np.asarray(back[0].k), np.asarray(fl.k))
    np.testing.assert_array_equal(np.asarray(back[0].v), np.asarray(fl.v))
    np.testing.assert_array_equal(np.asarray(back[1].k), np.asarray(q.k))
    np.testing.assert_array_equal(np.asarray(back[1].k_scale),
                                  np.asarray(q.k_scale))
    with pytest.raises(TypeError):
        kvc.swap_out_blocks((object(),), ids)


def test_preemption_swap_restore_round_trip(warm):
    """An interactive arrival preempts the decoding batch request; the
    victim's stream after restore is token-identical to an undisturbed run,
    and the allocator invariant holds after every tick."""
    cfg, weng = warm
    rng = np.random.default_rng(7)
    batch_p = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    inter_p = rng.integers(0, cfg.vocab, size=4).astype(np.int32)

    eng = _engine(cfg, weng, slots=1, max_seq=64, block_size=4,
                  num_blocks=12, preempt=True, seed=0)
    b = eng.submit(RequestSpec(prompt=batch_p, max_new=10, priority="batch"))
    for _ in range(6):                    # let the batch request decode a bit
        eng.tick()
        eng.alloc.check()
    i = eng.submit(RequestSpec(prompt=inter_p, max_new=3,
                               priority="interactive"))
    while eng.tick():
        eng.alloc.check()
    out = eng.results
    eng.alloc.check()
    assert eng.metrics.preemptions >= 1
    assert eng.metrics.swap_out_blocks == eng.metrics.swap_in_blocks > 0
    assert eng.scheduler.preemptions >= 1
    assert len(out[i.rid]) == 3
    for m in eng.metrics.requests:
        if m.rid == b.rid:
            assert m.preemptions >= 1 and m.priority == "batch"

    base = _engine(cfg, weng, slots=1, max_seq=64, block_size=4,
                   num_blocks=12, seed=0)
    bb = base.submit(RequestSpec(prompt=batch_p, max_new=10, priority="batch"))
    ref = base.run()
    np.testing.assert_array_equal(out[b.rid], ref[bb.rid])


def test_preempt_refused_on_recurrent_stack():
    cfg = configs.get_smoke("xlstm-1.3b")
    with pytest.raises(ValueError, match="attention-only"):
        Engine(cfg, slots=1, max_seq=32, preempt=True)


# ---------------------------------------------------------------------------
# router: class-aware shedding, tenant fairness, eos through the cluster
# ---------------------------------------------------------------------------


class _StubPool:
    """Router target for admission-only tests (nothing is dispatched)."""

    def views(self):
        return []

    def submit_to(self, idx, h):
        raise AssertionError("admission tests must not dispatch")

    def stop(self):
        pass


def _spec(rng, vocab, **kw):
    return RequestSpec(prompt=rng.integers(0, vocab, size=4).astype(np.int32),
                       max_new=2, **kw)


def test_router_class_aware_shed_and_tenant_fairness():
    from repro.cluster.router import Router

    rng = np.random.default_rng(9)
    # batch window shrinks to 2 of 4; tenant share caps any tenant at 2
    r = Router(_StubPool(), max_pending=4, batch_pending_frac=0.5,
               tenant_share=0.5, async_dispatch=False)
    assert r.submit(_spec(rng, 64, priority="batch", tenant="a")) is not None
    assert r.submit(_spec(rng, 64, priority="batch", tenant="b")) is not None
    # batch window (2) is full -> batch sheds, interactive still admits
    assert r.submit(_spec(rng, 64, priority="batch", tenant="c")) is None
    assert r.shed_by_class["batch"] == 1
    assert r.submit(_spec(rng, 64, priority="interactive",
                          tenant="c")) is not None
    # tenant "a" hits its share cap (2) before the global window (4)
    assert r.submit(_spec(rng, 64, priority="interactive",
                          tenant="a")) is not None
    assert r.submit(_spec(rng, 64, priority="interactive", tenant="a")) is None
    stats = r.tenant_stats()
    assert stats["a"] == {"offered": 3, "admitted": 2, "shed": 1,
                          "in_flight": 2}
    assert r.shed_by_class["interactive"] == 1 and r.shed == 2
    # dispatch order: interactive queue drains before batch
    order = []
    while True:
        h = r._next_locked()
        if h is None:
            break
        order.append(h.spec.priority)
    assert order == sorted(order, key=priority_rank)


@pytest.fixture(scope="module")
def pool(warm):
    cfg, weng = warm
    from repro import cluster

    p = cluster.ReplicaPool(cfg, 1, slots=2, max_seq=32, block_size=4)
    p.replicas[0].engine.share_steps_from(weng)
    p.warmup()
    yield cfg, p
    p.stop()


def test_eos_token_reaches_replicas(pool):
    """ClusterRequest carries the full spec, so eos_token now survives the
    router -> replica hop (it could not before this API)."""
    cfg, p = pool
    from repro import cluster

    router = cluster.Router(p, async_dispatch=False)
    rng = np.random.default_rng(10)
    prompt = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    h = router.submit(RequestSpec(prompt=prompt, max_new=4))
    router.dispatch_sync()
    p.run_sync()
    first = int(h.result(timeout=30)[0])
    h2 = router.submit(RequestSpec(prompt=prompt, max_new=4, eos_token=first))
    router.dispatch_sync()
    p.run_sync()
    toks = h2.result(timeout=30)
    assert toks.tolist() == [first]
    assert h2.spec.eos_token == first and h2.max_new == 4


def test_replay_builds_specs_with_labels():
    from repro import cluster

    tr = cluster.mixed_traffic(64, n=6, seed=2,
                               class_mix=(("interactive", 0.5),
                                          ("batch", 0.5)),
                               tenants=2)
    plain = cluster.mixed_traffic(64, n=6, seed=2)
    # labelling draws from its own stream: prompts/budgets are untouched
    assert [i.prompt for i in tr.items] == [i.prompt for i in plain.items]
    assert [i.max_new for i in tr.items] == [i.max_new for i in plain.items]
    assert {i.tenant for i in tr.items} <= {"t0", "t1"}
    seen = []
    sp = SamplingParams(temperature=0.7, seed=1)
    cluster.replay(tr, seen.append, sampling=sp)
    assert all(isinstance(s, RequestSpec) for s in seen)
    assert [s.priority for s in seen] == [i.priority for i in tr.items]
    assert [s.tenant for s in seen] == [i.tenant for i in tr.items]
    assert all(s.sampling == sp for s in seen)


def test_trace_roundtrip_preserves_labels(tmp_path):
    from repro import cluster

    tr = cluster.mixed_traffic(64, n=4, seed=3,
                               class_mix=(("batch", 1.0),), tenants=3)
    path = str(tmp_path / "trace.json")
    tr.save(path)
    back = cluster.Trace.load(path)
    assert back.items == tr.items
    assert all(i.priority == "batch" for i in back.items)


def test_preempt_never_evicts_same_or_higher_class(warm):
    """A batch arrival must not preempt a decoding interactive request (nor
    another batch request — preemption is strictly cross-class)."""
    cfg, weng = warm
    rng = np.random.default_rng(8)
    eng = _engine(cfg, weng, slots=1, max_seq=64, block_size=4,
                  num_blocks=12, preempt=True, seed=0)
    a = eng.submit(RequestSpec(
        prompt=rng.integers(0, cfg.vocab, size=4).astype(np.int32),
        max_new=6, priority="interactive"))
    for _ in range(4):
        eng.tick()
    eng.submit(RequestSpec(
        prompt=rng.integers(0, cfg.vocab, size=4).astype(np.int32),
        max_new=2, priority="batch"))
    eng.run()
    eng.alloc.check()
    assert eng.metrics.preemptions == 0
    assert a.preemptions == 0
