"""Simulator invariants + reproduction-band checks against the paper.

Deterministic module — always runs (no hypothesis).  Randomized-input
versions of the invariants live in test_simulator_properties.py.
"""

import pytest

from repro.core.dataflow import GemmShape
from repro.core.generator import OpenGeMMConfig
from repro.core.simulator import (
    OpenGeMMSimulator,
    ablation_architectures,
    fig5_median_utilizations,
    random_fig5_shapes,
)
from repro.core.workloads import TABLE2_MODELS, TABLE2_PAPER
from repro.core.gemmini_model import GemminiModel

GRID = [(8, 8, 8), (8, 256, 16), (64, 64, 64), (120, 48, 200), (256, 8, 256)]


@pytest.mark.parametrize("mkn", GRID)
def test_utilization_bounded(mkn):
    sim = OpenGeMMSimulator()
    u = sim.utilization(GemmShape(*mkn), repeats=10)
    assert 0 < u <= 1


@pytest.mark.parametrize("mkn", GRID)
def test_mechanisms_monotone(mkn):
    """Enabling each mechanism never hurts utilization materially (Fig. 5)."""
    g = GemmShape(*mkn)
    archs = ablation_architectures()
    u = {k: OpenGeMMSimulator(c).utilization(g, repeats=10) for k, c in archs.items()}
    tol = lambda x: x * 1.02 + 1e-9
    assert u["arch1_baseline"] <= tol(u["arch2_cpl"])
    assert u["arch2_cpl"] <= tol(u["arch3_cpl_buf2"])
    assert u["arch3_cpl_buf2"] <= tol(u["arch4_all_buf2"])
    assert u["arch4_all_buf2"] <= tol(u["arch4_all_buf3"])
    assert u["arch4_all_buf3"] <= tol(u["arch4_all_buf4"])


@pytest.mark.parametrize("mkn", GRID)
@pytest.mark.parametrize("reps", [1, 3])
def test_timing_decomposition(mkn, reps):
    sim = OpenGeMMSimulator()
    ts = sim.simulate_sequence([GemmShape(*mkn)] * reps)
    for t in ts:
        assert t.total_cycles == (
            t.config_cycles + t.fill_cycles + t.compute_cycles
            + t.input_stall_cycles + t.output_stall_cycles
        )
        assert t.compute_cycles >= 1
    # CPL: later calls pay less config than the first
    if reps > 1:
        assert ts[1].config_cycles <= ts[0].config_cycles


def test_grouped_matches_sequence():
    sim = OpenGeMMSimulator()
    shapes = [GemmShape(64, 128, 64)] * 7 + [GemmShape(128, 64, 256)] * 3
    seq_total = sum(t.total_cycles for t in sim.simulate_sequence(shapes))
    grp = sim.report_grouped([(GemmShape(64, 128, 64), 7), (GemmShape(128, 64, 256), 3)])
    assert abs(grp.total_cycles - seq_total) / seq_total < 0.01


def test_peak_gops_matches_paper():
    assert OpenGeMMConfig().peak_gops() == pytest.approx(204.8)


def test_fig5_reproduction_band():
    """Median-utilization ratios land near the paper's Fig. 5 claims."""
    meds = fig5_median_utilizations(random_fig5_shapes(200, seed=1))
    cpl = meds["arch2_cpl"] / meds["arch1_baseline"]
    buf = meds["arch3_cpl_buf2"] / meds["arch2_cpl"]
    sma = meds["arch4_all_buf2"] / meds["arch3_cpl_buf2"]
    # paper: 1.4x / 2.02x / 1.18x — accept a generous band
    assert 1.15 < cpl < 1.7, cpl
    assert 1.6 < buf < 2.4, buf
    assert 1.05 < sma < 1.35, sma
    # depth sweep keeps improving (paper: Buf.Depth 3, 4)
    assert meds["arch4_all_buf3"] >= meds["arch4_all_buf2"]
    assert meds["arch4_all_buf4"] >= meds["arch4_all_buf3"]


@pytest.mark.parametrize("name", list(TABLE2_MODELS))
def test_table2_reproduction(name):
    """SU/TU/OU within a few points of the paper's Table 2."""
    sim = OpenGeMMSimulator()
    rep = sim.report_grouped(TABLE2_MODELS[name]())
    su_p, tu_p, ou_p, cc_p = TABLE2_PAPER[name]
    assert abs(rep.su * 100 - su_p) < 4.0, (rep.su * 100, su_p)
    assert abs(rep.tu * 100 - tu_p) < 4.0, (rep.tu * 100, tu_p)
    assert abs(rep.ou * 100 - ou_p) < 5.0, (rep.ou * 100, ou_p)
    # cycle count within 2.5x (batch size back-derived, not stated in paper)
    assert 0.4 < rep.total_cycles / cc_p < 2.5


def test_gemmini_utilization_regime():
    """The Fig. 7 baseline sits in the measured ~6% average-TU regime [32]."""
    gm = GemminiModel()
    sizes = [GemmShape(s, s, s) for s in (8, 16, 32, 64, 128)]
    tus = [gm.temporal_utilization(g) for g in sizes]
    avg = sum(tus) / len(tus)
    assert 0.01 < avg < 0.15, tus


@pytest.mark.parametrize("mkn", GRID + [(7, 9, 13), (100, 100, 100)])
def test_call_timing_spatial_utilization(mkn):
    """CallTiming.spatial_utilization is the real padded-MAC ratio (not the
    old 1.0 placeholder) and agrees with the dataflow definition and with
    the MAC-weighted aggregate."""
    sim = OpenGeMMSimulator()
    g = GemmShape(*mkn)
    t = sim.simulate_call(g)
    su = t.spatial_utilization
    assert 0 < su <= 1
    assert su == pytest.approx(sim.df.spatial_utilization(g), abs=1e-12)
    aligned = all(d % 8 == 0 for d in mkn)
    assert (su == 1.0) == aligned
    assert t.overall_utilization == pytest.approx(
        su * t.temporal_utilization, abs=1e-12)


def test_per_call_su_aggregates_to_workload_su():
    """MAC-weighted per-call SU equals aggregate_utilization's SU for a
    mixed-shape workload (also asserted inside OpenGeMMSimulator.report)."""
    sim = OpenGeMMSimulator()
    shapes = [GemmShape(7, 9, 13), GemmShape(64, 64, 64), GemmShape(120, 48, 200)]
    timings = sim.simulate_sequence(shapes)
    weighted = (sum(t.shape.macs for t in timings)
                / sum(t.padded_shape.macs for t in timings))
    rep = sim.report(shapes)
    assert weighted == pytest.approx(rep.su, abs=1e-12)
