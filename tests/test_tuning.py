"""Autotuner + kernel registry: enumeration legality, ranking determinism,
cache round-trips, and tuned_gemm correctness/performance."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataflow import GemmShape
from repro.core.generator import (
    CASE_STUDY,
    MXU_LANES,
    MXU_SUBLANES,
    TpuGemmSpec,
    VMEM_BUDGET_BYTES,
)
from repro.core.workloads import bert_base, resnet18, vit_b_16
from repro.kernels import ops, ref
from repro.kernels.registry import make_kernel, register_kernel, registered_kernels
from repro import tuning


def _tuner(tmp_path, **kw):
    cache = tuning.TuneCache(path=str(tmp_path / "tunecache.json"))
    return tuning.Autotuner(cache=cache, **kw)


# Three real workload shapes (core/workloads.py): the largest-MAC GeMM of
# ViT-B-16 (FFN up), BERT-base (FFN up at seq 512) and ResNet18 (a mid conv).
WORKLOAD_SHAPES = [
    GemmShape(197, 768, 3072),
    GemmShape(512, 768, 3072),
    GemmShape(784, 1152, 128),
]


def test_workload_shapes_come_from_extraction():
    """The shapes above really occur in the im2col extraction lists."""
    extracted = {g for fn in (vit_b_16, bert_base, resnet18) for g, _ in fn()}
    for g in WORKLOAD_SHAPES:
        assert g in extracted, g


# -- candidate enumeration ---------------------------------------------------


@pytest.mark.parametrize("mkn", [(197, 768, 3072), (64, 64, 64), (4096, 4096, 4096)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_candidates_legal(mkn, dtype):
    g = GemmShape(*mkn)
    cands = tuning.enumerate_tiles(g, dtype)
    assert cands, "candidate set must be non-empty"
    bits = tuning.dtype_bits(dtype)
    for s in cands:
        assert s.tm % MXU_SUBLANES == 0
        assert s.tk % MXU_LANES == 0 and s.tn % MXU_LANES == 0
        assert s.vmem_bytes(bits) <= VMEM_BUDGET_BYTES
        assert s.int8 == (dtype == "int8")
    # no duplicates
    keys = [(s.tm, s.tk, s.tn) for s in cands]
    assert len(keys) == len(set(keys))


def test_candidates_include_default_and_respect_cap():
    g = GemmShape(197, 768, 3072)
    default = CASE_STUDY.tpu_kernel_spec(g)
    for cap in (None, 4):
        cands = tuning.enumerate_tiles(g, "int8", max_candidates=cap)
        assert (default.tm, default.tk, default.tn) in {
            (s.tm, s.tk, s.tn) for s in cands
        }
        if cap is not None:
            assert len(cands) <= cap


def test_candidates_never_exceed_padded_problem():
    g = GemmShape(8, 128, 128)
    for s in tuning.enumerate_tiles(g, "float32"):
        assert s.tm <= 8 and s.tk <= 128 and s.tn <= 128


# -- analytic model + ranking ------------------------------------------------


def test_predict_is_positive_and_padding_aware():
    g = GemmShape(197, 768, 768)
    small = TpuGemmSpec(tm=200, tk=128, tn=128)
    oversized = TpuGemmSpec(tm=512, tk=128, tn=128)  # pads M 197 -> 512
    p_small = tuning.predict(small, g, "bfloat16")
    p_big = tuning.predict(oversized, g, "bfloat16")
    assert p_small.clocks > 0 and 0 < p_small.utilization <= 1
    assert p_big.clocks > p_small.clocks  # padded passes cost real clocks


def test_analytic_ranking_deterministic(tmp_path):
    g = GemmShape(512, 768, 3072)
    results = [
        _tuner(tmp_path / str(i), persist=False).tune(g, "bfloat16")
        for i in range(3)
    ]
    assert len({r.spec for r in results}) == 1
    assert len({r.score for r in results}) == 1


@pytest.mark.parametrize("shape", WORKLOAD_SHAPES)
def test_tuned_beats_or_matches_default(shape, tmp_path):
    """Acceptance: model-predicted throughput of the tuned tile >= default's."""
    tuner = _tuner(tmp_path, persist=False)
    for dtype in ("int8", "bfloat16"):
        res = tuner.tune(shape, dtype)
        default = CASE_STUDY.tpu_kernel_spec(shape)
        tuned_clk = tuning.predict_clocks(res.spec, shape, dtype)
        default_clk = tuning.predict_clocks(default, shape, dtype)
        assert tuned_clk <= default_clk, (res.spec, default)


# -- cache -------------------------------------------------------------------


def test_cache_json_roundtrip(tmp_path):
    path = str(tmp_path / "tc.json")
    cache = tuning.TuneCache(path=path)
    spec = TpuGemmSpec(tm=256, tk=128, tn=512, depth=3, int8=False)
    key = tuning.cache_key(GemmShape(512, 768, 3072), "bfloat16", "pallas")
    cache.put(key, tuning.CacheEntry(spec=spec, score=123.5, source="analytic"))

    raw = json.load(open(path))  # human-readable on disk (EXPERIMENTS.md dumps)
    assert raw[key]["tm"] == 256 and raw[key]["source"] == "analytic"

    fresh = tuning.TuneCache(path=path)
    hit = fresh.get(key)
    assert hit is not None and hit.spec == spec and hit.score == 123.5


def test_cache_hit_path(tmp_path):
    """Second tune of the same problem resolves from cache, not a re-search."""
    tuner = _tuner(tmp_path)
    g = WORKLOAD_SHAPES[0]
    first = tuner.tune(g, "int8")
    assert not first.from_cache
    again = tuner.tune(g, "int8")
    assert again.from_cache and again.spec == first.spec
    assert tuner.cache.hits >= 1

    # ...including across processes (a fresh cache object on the same file)
    tuner2 = tuning.Autotuner(cache=tuning.TuneCache(path=tuner.cache.path))
    cold = tuner2.tune(g, "int8")
    assert cold.from_cache and cold.spec == first.spec


def test_cache_lru_eviction_keeps_disk(tmp_path):
    cache = tuning.TuneCache(path=str(tmp_path / "tc.json"), lru_size=2)
    spec = TpuGemmSpec(tm=128, tk=128, tn=128)
    keys = [f"k{i}" for i in range(4)]
    for k in keys:
        cache.put(k, tuning.CacheEntry(spec=spec, score=1.0, source="analytic"))
    assert len(cache._lru) == 2          # LRU bounded
    assert len(cache) == 4               # disk registry keeps everything
    assert cache.get(keys[0]) is not None  # evicted entries refill from disk


def test_wallclock_mode_does_not_reuse_analytic_winners(tmp_path):
    """Mode is part of the cache key: --tune-mode wallclock after an
    analytic run must re-search, not resolve the analytic entry."""
    path = str(tmp_path / "tc.json")
    g = GemmShape(64, 128, 128)
    analytic = tuning.Autotuner(cache=tuning.TuneCache(path=path))
    assert not analytic.tune(g, "float32").from_cache
    wallclock = tuning.Autotuner(
        cache=tuning.TuneCache(path=path), mode="wallclock",
        max_candidates=2, wallclock_iters=1,
    )
    res = wallclock.tune(g, "float32", backend="interpret")
    assert not res.from_cache
    # ...and each mode hits its own entry on the second query
    assert analytic.tune(g, "float32").from_cache
    assert wallclock.tune(g, "float32", backend="interpret").from_cache


def test_wallclock_does_not_trust_analytic_fallback(tmp_path):
    """An analytic *fallback* stored under the wallclock key (host couldn't
    measure) must not satisfy a later wallclock tune on a capable host."""
    path = str(tmp_path / "tc.json")
    g = GemmShape(64, 128, 128)
    kw = dict(mode="wallclock", max_candidates=2, wallclock_iters=1)
    # "pallas" is unmeasurable on a CPU host -> analytic fallback persisted
    fallback = tuning.Autotuner(cache=tuning.TuneCache(path=path), **kw)
    first = fallback.tune(g, "float32", backend="pallas")
    assert first.source == "analytic"
    # "interpret" shares the pallas tuning key but IS measurable -> re-search
    capable = tuning.Autotuner(cache=tuning.TuneCache(path=path), **kw)
    second = capable.tune(g, "float32", backend="interpret")
    assert not second.from_cache and second.source == "wallclock"
    # measured winner now satisfies the next query
    assert capable.tune(g, "float32", backend="interpret").from_cache


def test_search_space_params_separate_cache_keys(tmp_path):
    """Explicit depth sweeps / candidate caps don't alias the default key."""
    tuner = _tuner(tmp_path)
    g = GemmShape(64, 128, 128)
    tuner.tune(g, "float32", backend="pipelined")               # default sweep
    res = tuner.tune(g, "float32", backend="pipelined", depth=8)
    assert not res.from_cache and res.spec.depth == 8
    capped = tuning.Autotuner(cache=tuner.cache, max_candidates=2)
    assert not capped.tune(g, "float32").from_cache


def test_env_truthy_disables_on_zero():
    from repro.tuning.autotuner import env_truthy

    assert not env_truthy("0") and not env_truthy("false") and not env_truthy("")
    assert not env_truthy(None) and not env_truthy("off")
    assert env_truthy("1") and env_truthy("true") and env_truthy("yes")


def test_memory_only_cache_never_touches_disk(tmp_path):
    path = tmp_path / "never-created.json"
    cache = tuning.TuneCache(path=str(path), persistent=False)
    spec = TpuGemmSpec(tm=128, tk=128, tn=128)
    cache.put("k", tuning.CacheEntry(spec=spec, score=1.0, source="analytic"))
    cache.save()
    assert not path.exists()
    assert cache.get("k") is not None  # still served from memory


def test_corrupt_cache_file_is_ignored(tmp_path):
    path = tmp_path / "tc.json"
    path.write_text("{not json")
    cache = tuning.TuneCache(path=str(path))
    assert len(cache) == 0 and cache.get("anything") is None


# -- tuned_gemm end to end ---------------------------------------------------


@pytest.mark.parametrize("mkn", [(64, 128, 128), (100, 200, 150), (129, 256, 130)])
@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_tuned_gemm_matches_oracle(mkn, dtype, tmp_path):
    tuner = _tuner(tmp_path)
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    m, k, n = mkn
    if dtype == "int8":
        a = jax.random.randint(k1, (m, k), -127, 128, jnp.int8)
        b = jax.random.randint(k2, (k, n), -127, 128, jnp.int8)
    else:
        a = jax.random.normal(k1, (m, k), jnp.float32)
        b = jax.random.normal(k2, (k, n), jnp.float32)
    out = tuning.tuned_gemm(a, b, backend="interpret", tuner=tuner)
    expect = ref.gemm_ref(a, b)
    if dtype == "int8":
        np.testing.assert_array_equal(out, expect)
    else:
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-4)


def test_wallclock_mode_interpret(tmp_path):
    """Empirical ranking path: times real kernels (interpret on CPU)."""
    tuner = _tuner(tmp_path, mode="wallclock", max_candidates=2,
                   wallclock_iters=1, persist=False)
    res = tuner.tune(GemmShape(64, 128, 128), "float32", backend="interpret")
    assert res.source in ("wallclock", "analytic")  # analytic = no cand ran
    a = jnp.ones((64, 128), jnp.float32)
    b = jnp.ones((128, 128), jnp.float32)
    out = ops.gemm(a, b, spec=res.spec, backend="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gemm_ref(a, b)),
                               rtol=1e-6)


def test_ops_dispatch_through_enabled_tuner(tmp_path):
    """tuning.enable() routes spec-less ops.gemm calls through the tuner."""
    tuner = _tuner(tmp_path)
    old = tuning.get_tuner()
    tuning.set_tuner(tuner)
    tuning.enable()
    try:
        a = jnp.ones((64, 128), jnp.float32)
        b = jnp.ones((128, 128), jnp.float32)
        out = ops.gemm(a, b, backend="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gemm_ref(a, b)),
                                   rtol=1e-6)
        assert len(tuner.cache) >= 1  # the dispatch populated this cache

        # An explicitly passed non-default config is designer intent: it
        # bypasses the tuner and uses its own tpu_kernel_spec mapping.
        import dataclasses

        before = len(tuner.cache)
        custom = dataclasses.replace(CASE_STUDY, D_stream=4)
        a2 = jnp.ones((8, 128), jnp.float32)
        out2 = ops.gemm(a2, b, config=custom, backend="interpret")
        np.testing.assert_allclose(np.asarray(out2), np.asarray(ref.gemm_ref(a2, b)),
                                   rtol=1e-6)
        assert len(tuner.cache) == before
    finally:
        tuning.disable()
        tuning.set_tuner(old)


# -- kernel registry ---------------------------------------------------------


def test_registry_builtins():
    assert {"pallas", "pipelined", "dequant"} <= set(registered_kernels())


def test_registry_rejects_duplicates_and_unknown():
    with pytest.raises(ValueError):
        register_kernel("pallas", lambda spec, interpret=False: None)
    with pytest.raises(KeyError):
        make_kernel("no-such-kernel", TpuGemmSpec(tm=128, tk=128, tn=128))


def test_registry_memoizes_specializations():
    spec = TpuGemmSpec(tm=128, tk=128, tn=128)
    assert make_kernel("pallas", spec, interpret=True) is make_kernel(
        "pallas", spec, interpret=True
    )


def test_registered_kernel_is_dispatchable(tmp_path):
    """A newly registered variant is reachable by name, like the built-ins."""
    calls = []

    def factory(spec, *, interpret=False):
        def fn(a, b):
            calls.append(spec)
            return ref.gemm_ref(a, b)

        return fn

    register_kernel("test-variant", factory)
    try:
        fn = make_kernel("test-variant", TpuGemmSpec(tm=128, tk=128, tn=128))
        a = jnp.ones((128, 128), jnp.float32)
        fn(a, a)
        assert calls
    finally:
        from repro.kernels import registry as _registry

        _registry._REGISTRY.pop("test-variant", None)
        _registry._make_cached.cache_clear()
