import os
import sys

# Tests run on the single real CPU device (the dry-run's 512 fake devices are
# set only inside repro.launch.dryrun / subprocess integration tests).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
