"""Sharding rules + a subprocess mini dry-run (8 fake devices)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.parallel.logical import resolve_spec
from jax.sharding import PartitionSpec as P


def test_resolve_spec_basic():
    rules = {"batch": ("pod", "data"), "heads": "model", "embed": None}
    assert resolve_spec(["batch", None, "heads"], rules) == P(("pod", "data"), None, "model")
    assert resolve_spec(["embed"], rules) == P(None)


def test_resolve_spec_no_duplicate_axes():
    rules = {"batch": "data", "seq": "data"}
    # second use of an already-consumed mesh axis falls back to replication
    assert resolve_spec(["batch", "seq"], rules) == P("data", None)


def test_resolve_spec_tuple_dedup():
    rules = {"batch": ("data", "model"), "heads": "model"}
    spec = resolve_spec(["batch", "heads"], rules)
    assert spec == P(("data", "model"), None)


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, jax
    from repro import configs
    from repro.launch import steps as steps_lib, roofline as rl, hlo_cost
    from repro.parallel import sharding as shard_lib
    from repro.parallel.logical import use_rules
    from jax.sharding import NamedSharding, PartitionSpec as P

    arch = os.environ["ARCH"]
    cfg = configs.get_smoke(arch)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    plan = shard_lib.make_plan(mesh, cfg.param_count(),
                               force_mode=os.environ.get("MODE", "dp"))
    p_struct = steps_lib.params_struct(cfg)
    p_shard = shard_lib.param_sharding(p_struct, mesh, plan)
    opt_cfg = steps_lib.optimizer_config(cfg)
    o_struct = steps_lib.opt_state_struct(cfg, p_struct, opt_cfg)
    o_shard = {"m": shard_lib.param_sharding(o_struct["m"], mesh, plan),
               "v": shard_lib.param_sharding(o_struct["v"], mesh, plan),
               "count": NamedSharding(mesh, P())}
    shape = dict(kind="train", seq_len=32, global_batch=8)
    specs = steps_lib.input_specs(cfg, shape)
    b_shard = shard_lib.batch_sharding(specs["batch"], mesh, plan)
    step = steps_lib.make_train_step(cfg, opt_cfg)
    with use_rules(mesh, plan.activation_rules()), mesh:
        lowered = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard)).lower(
            p_struct, o_struct, specs["batch"])
        compiled = lowered.compile()
    lac = hlo_cost.analyze(compiled.as_text())
    print(json.dumps({"flops": lac.flops, "collective_bytes": lac.collective_bytes,
                      "ok": True}))
""")


@pytest.mark.parametrize("arch,mode", [
    ("qwen3-14b", "tp"), ("gemma3-1b", "dp"), ("dbrx-132b", "tp"),
    ("jamba-1.5-large-398b", "tp"),
])
def test_mini_dryrun_compiles(arch, mode, tmp_path):
    """The full dry-run machinery on an 8-device mesh with smoke configs:
    sharding rules + jit lowering + compile + loop-aware cost analysis."""
    env = dict(os.environ, ARCH=arch, MODE=mode,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", MINI_DRYRUN], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["flops"] > 0
