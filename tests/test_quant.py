"""repro.quant tests: kernels, mode hygiene, calibration determinism,
int8-resident equivalence, and w8a8 serving fidelity (dense + hybrid)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, quant
from repro.kernels import ops, ref
from repro.kernels.quant import quantize_rows
from repro.kernels.registry import make_kernel, registered_kernels
from repro.core.generator import TpuGemmSpec
from repro.models import model as M
from repro.quant import modes
from repro.quant.calibrate import (
    AbsmaxObserver,
    MovingAverageObserver,
    PercentileObserver,
)
from repro.serving.engine import Engine


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [4, 10, 300])
def test_quantize_rows_ragged(m):
    """Ragged M pads to the block grid and slices back; every row matches
    the per-row reference exactly."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=(m, 32)), jnp.float32)
    q, s = quantize_rows(x, block_m=8, interpret=True)
    qr, sr = ref.quantize_ref(x, axis=-1)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    assert q.shape == (m, 32) and s.shape == (m, 1)


def test_w8a8_kernel_registered_and_matches_ref():
    """The registry's "w8a8" variant (row quant + fused dequant) matches the
    composed jnp oracles."""
    assert "w8a8" in registered_kernels()
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(16, 128)), jnp.float32)
    wf = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    wq, sw = ref.quantize_ref(wf, axis=0)
    spec = TpuGemmSpec(tm=8, tk=128, tn=128)
    out = make_kernel("w8a8", spec, interpret=True)(a, wq, sw.reshape(1, -1))
    aq, sa = ref.quantize_ref(a, axis=-1)
    want = ref.gemm_dequant_ref(aq, wq, sa, sw.reshape(1, -1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def test_int8_resident_matches_on_the_fly():
    """linear() on a QuantTensor == linear(quant="int8") on the float weight:
    pre-quantizing weights changes *when* quantization happens, not what."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(3, 5, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    resident = ops.linear(x, quant.quantize_leaf(w))
    on_the_fly = ops.linear(x, w, quant="int8")
    np.testing.assert_allclose(
        np.asarray(resident), np.asarray(on_the_fly), rtol=1e-5, atol=1e-5)


def test_gemm_w8a8_static_act_scale():
    """Calibrated path: a static activation scale replaces per-row absmax."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    wq, sw = ref.quantize_ref(w, axis=0)
    s = float(jnp.max(jnp.abs(x))) / 127.0
    got = ops.gemm_w8a8(x, wq, sw, act_scale=s, backend="xla")
    xq = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    want = ref.gemm_dequant_ref(xq, wq, jnp.full((8, 1), s), sw.reshape(1, -1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# precision modes
# ---------------------------------------------------------------------------

def test_mode_save_restore_hygiene():
    assert modes.get_mode() == "float"
    with quant.precision("w8a8"):
        assert modes.get_mode() == "w8a8"
        with quant.precision("w8a8-calibrated"):       # nesting
            assert modes.get_mode() == "w8a8-calibrated"
        assert modes.get_mode() == "w8a8"
        with pytest.raises(RuntimeError):              # exception inside
            with quant.precision("float"):
                assert modes.get_mode() == "float"
                raise RuntimeError("boom")
        assert modes.get_mode() == "w8a8"              # restored past raise
    assert modes.get_mode() == "float"
    with pytest.raises(ValueError):
        modes.set_mode("w4a4")                         # unknown mode
    assert modes.get_mode() == "float"


def test_mode_drives_linear_and_none_opts_out():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(6, 48)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(48, 24)), jnp.float32)
    int8_y = ops.linear(x, w, quant="int8")
    float_y = ops.linear(x, w)
    with quant.precision("w8a8"):
        np.testing.assert_allclose(
            np.asarray(ops.linear(x, w)), np.asarray(int8_y), rtol=1e-6)
        # explicit opt-out beats the mode (SSM gate projections rely on this)
        np.testing.assert_allclose(
            np.asarray(ops.linear(x, w, quant="none")), np.asarray(float_y),
            rtol=1e-6)


# ---------------------------------------------------------------------------
# observers + calibration
# ---------------------------------------------------------------------------

def test_observers():
    rng = np.random.default_rng(5)
    a1, a2 = np.abs(rng.normal(size=(32, 8))), np.abs(rng.normal(size=(32, 8)))

    absmax = AbsmaxObserver()
    pct = PercentileObserver(percentile=90.0)
    ema = MovingAverageObserver(momentum=0.5)
    for obs in (absmax, pct, ema):
        for a in (a1, a2):
            obs.observe(a)
            obs.end_batch()

    assert float(absmax.stat()) == pytest.approx(max(a1.max(), a2.max()))
    # percentile clips the tail: strictly inside the absmax
    assert float(pct.stat()) < float(absmax.stat())
    # EMA of the two per-batch absmaxes at momentum 0.5
    want = 0.5 * a1.max(axis=0) + 0.5 * a2.max(axis=0)
    np.testing.assert_allclose(ema.stat(per_channel=True), want)
    # per-channel stats cover every channel and scales are positive
    assert absmax.stat(per_channel=True).shape == (8,)
    assert (absmax.scale(per_channel=True) > 0).all()


@pytest.mark.parametrize("observer", ["absmax", "moving_average", "percentile"])
def test_calibration_deterministic(observer):
    cfg = configs.get_smoke("gemma3-1b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    batches = quant.synthetic_batches(cfg, n=2, batch=2, seq=8)
    t1 = quant.collect_scales(params, cfg, batches, observer=observer)
    t2 = quant.collect_scales(params, cfg, batches, observer=observer)
    assert len(t1) > 0
    assert t1.scales == t2.scales
    for k, v in t1.channel_scales.items():
        np.testing.assert_array_equal(v, t2.channel_scales[k])
    # every attention/FFN projection of every group got a site
    for g in range(cfg.n_groups):
        assert f"blocks.{g}.sub0.mixer.wq" in t1.scales
    assert "head" in t1.scales
    assert modes.get_mode() == "float"   # capture context fully unwound


def test_quantize_params_structure_and_memory():
    cfg = configs.get_smoke("gemma3-1b")          # tied embeddings
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    table = quant.collect_scales(
        params, cfg, quant.synthetic_batches(cfg, n=1, batch=1, seq=8))
    qp = quant.quantize_params(params, cfg=cfg, scales=table)
    wq = qp["blocks"]["sub0"]["mixer"]["wq"]
    assert isinstance(wq, quant.QuantTensor)
    G = cfg.n_groups
    assert wq.q.dtype == jnp.int8 and wq.q.shape[0] == G
    assert wq.scale.shape == (G, 1, wq.q.shape[-1])
    assert wq.act_scale is not None and wq.act_scale.shape == (G, 1, 1)
    assert "head_q" in qp                          # tied-head int8 copy
    assert not isinstance(qp["embed"], quant.QuantTensor)  # gathered, not matmul'd
    assert quant.weight_bytes(qp) < 0.5 * quant.weight_bytes(params)
    # dequantized round trip stays close to the float weights
    deq = quant.dequantize_params(qp)
    w = np.asarray(params["blocks"]["sub0"]["mixer"]["wq"], np.float32)
    d = np.asarray(deq["blocks"]["sub0"]["mixer"]["wq"], np.float32)
    assert np.linalg.norm(d - w) / np.linalg.norm(w) < 0.01
    # error report covers every quantized leaf
    rows = quant.layer_error_rows(params, qp)
    assert len(rows) == quant.quantized_leaf_count(qp)
    assert all(r["rel_err"] < 0.02 for r in rows)


# ---------------------------------------------------------------------------
# serving fidelity + engine end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "arch", ["gemma3-1b", "jamba-1.5-large-398b", "xlstm-1.3b"])
def test_w8a8_paged_decode_matches_float(arch):
    """Paged chunked-prefill + decode under w8a8 tracks the float path within
    quantization tolerance for the dense, hybrid, and recurrent families."""
    cfg = configs.get_smoke(arch)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    qparams = quant.quantize_params(params, cfg=cfg)
    slots, prompt_len, gen = 2, 6, 3
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(slots, prompt_len)).astype(np.int32)

    def serve(p, mode):
        num_blocks, bs, mb = 1 + slots * 8, 4, 8
        state = M.init_paged_decode_state(
            cfg, slots, num_blocks=num_blocks, block_size=bs,
            max_blocks_per_slot=mb)
        from repro.serving import kv_cache as kvc
        alloc = kvc.BlockAllocator(num_blocks, bs)
        tables = kvc.BlockTables(slots, mb)
        for s in range(slots):
            tables.ensure(s, prompt_len + gen + 1, alloc)
        state = state._replace(block_tables=tables.array())
        outs = []
        with quant.precision(mode):
            for s in range(slots):
                _, state = M.prefill_chunk(
                    p, cfg, state, jnp.asarray(prompts[s:s + 1]), jnp.int32(s))
            tok = jnp.zeros((slots, 1), jnp.int32)
            for _ in range(gen):
                logits, state = M.paged_decode_step(p, cfg, state, tok)
                outs.append(np.asarray(logits, np.float32))
        return outs

    ref_logits = serve(params, "float")
    q_logits = serve(qparams, "w8a8")
    for lf, lq in zip(ref_logits, q_logits):
        rel = np.linalg.norm(lq - lf) / max(np.linalg.norm(lf), 1e-9)
        assert rel < 0.15, rel


@pytest.mark.parametrize("precision", ["w8a8", "w8a8-calibrated"])
def test_engine_w8a8_end_to_end(precision):
    """Engine(precision=...) serves the dense smoke arch with zero cold
    compiles after warmup, reports the memory saving, and leaves the global
    precision mode untouched."""
    cfg = configs.get_smoke("gemma3-1b")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=10).astype(np.int32)
               for _ in range(3)]

    eng = Engine(cfg, slots=2, max_seq=32, max_chunk=8, precision=precision)
    eng.warmup()
    assert modes.get_mode() == "float"       # warmup restored the mode
    for p in prompts:
        eng.submit(p, max_new=4)
    results = eng.run()
    assert len(results) == len(prompts)
    assert all(len(v) == 4 for v in results.values())
    assert eng.metrics.cold_compiles == 0
    assert eng.metrics.weight_bytes < eng.metrics.weight_bytes_float
    s = eng.metrics.summary()
    assert f"precision={precision}" in s and "smaller" in s
    if precision == "w8a8-calibrated":
        assert eng.metrics.calib_sites > 0
    # params really are int8-resident (not re-quantized per step)
    assert quant.quantized_leaf_count(eng.params) > 0


def test_engine_w8a8_tracks_float_tokens():
    """Same prompts through a float and a w8a8 engine: generations have the
    same shape and the engines stay isolated (separate jit traces)."""
    cfg = configs.get_smoke("gemma3-1b")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=8).astype(np.int32)
               for _ in range(2)]

    outs = {}
    for prec in ("float", "w8a8"):
        eng = Engine(cfg, slots=2, max_seq=24, max_chunk=8, precision=prec)
        eng.warmup()
        for p in prompts:
            eng.submit(p, max_new=4)
        outs[prec] = eng.run()
    for rid in outs["float"]:
        assert outs["float"][rid].shape == outs["w8a8"][rid].shape
