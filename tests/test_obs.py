"""Observability tests (repro.obs): ring-buffer tracer semantics, Chrome-
trace export validity from a real traced serving run, histogram percentile
parity with the engine's nearest-rank definition, per-phase MFU accounting,
and the tracing-overhead bound the subsystem is allowed to cost."""

import json
import time

import numpy as np
import pytest

from repro import configs
from repro.obs import (
    Histogram,
    MfuMeter,
    NULL_TRACER,
    Tracer,
    chrome_trace_events,
    nearest_rank_index,
    trace_document,
    write_chrome_trace,
)
from repro import obs
from repro.serving.engine import Engine, percentile

ARCH = "gemma3-1b"


# ---------------------------------------------------------------------------
# tracer ring
# ---------------------------------------------------------------------------


def test_tracer_records_and_decodes():
    tr = Tracer(capacity=64, name="t")
    a, g = tr.intern("phase"), tr.intern("gauge")
    assert tr.intern("phase") == a          # idempotent interning
    tr.begin(a)
    tr.counter(g, 7.5)
    tr.end(a)
    tr.async_begin(tr.intern("req"), 42)
    tr.async_end(tr.intern("req"), 42)
    evs = tr.events()
    assert [e["ph"] for e in evs] == ["B", "C", "E", "b", "e"]
    assert evs[1]["value"] == 7.5
    assert evs[3]["id"] == 42
    assert evs[0]["ts_ns"] <= evs[-1]["ts_ns"]
    assert tr.dropped == 0 and tr.recorded == 5 and len(tr) == 5


def test_tracer_ring_wraps_and_counts_dropped():
    tr = Tracer(capacity=8)
    c = tr.intern("x")
    for i in range(20):
        tr.counter(c, float(i))
    assert len(tr) == 8
    assert tr.recorded == 20 and tr.dropped == 12
    # ring holds the most recent events, oldest first
    assert [e["value"] for e in tr.events()] == [float(i) for i in range(12, 20)]
    tr.clear()
    assert len(tr) == 0 and tr.events() == []


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    NULL_TRACER.begin(NULL_TRACER.intern("x"))
    NULL_TRACER.counter(0, 1.0)
    with NULL_TRACER.span("y"):
        pass
    assert len(NULL_TRACER) == 0 and NULL_TRACER.events() == []


def test_span_contextmanager_balances_on_exception():
    tr = Tracer(capacity=16)
    with pytest.raises(RuntimeError):
        with tr.span("work"):
            raise RuntimeError("boom")
    assert [e["ph"] for e in tr.events()] == ["B", "E"]


# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------


def test_percentile_helper_is_the_shared_definition():
    """One nearest-rank definition across the repo: the engine and the
    serving package both re-export repro.obs.percentile (the PR-9 dedupe),
    and its rank math matches the index helper the histogram uses."""
    from repro import serving
    from repro.serving import engine as engine_mod

    assert engine_mod.percentile is obs.percentile
    assert serving.percentile is obs.percentile      # lazy re-export
    vals = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert obs.percentile(vals, 50) == 3.0           # nearest rank, not interp
    assert obs.percentile(vals, 100) == 5.0
    assert obs.percentile(vals, 0) == 1.0
    assert obs.percentile([], 95) == 0.0
    assert obs.percentile(iter(vals), 95) == 5.0     # any iterable
    assert nearest_rank_index(50, 5) == 2
    assert nearest_rank_index(0, 5) == 0             # clamped low
    assert nearest_rank_index(100, 5) == 4
    assert nearest_rank_index(99, 1) == 0


def test_histogram_count_above():
    h = Histogram()
    assert h.count_above(1.0) == 0
    for v in (0.5, 0.5, 2.0, 3.0, 100.0):
        h.add(v)
    # bucket representatives keep small-vs-large separable at rel_error
    assert h.count_above(1.0) == 3
    assert h.count_above(0.01) == 5
    assert h.count_above(1e9) == 0
    # underflow bucket represents as h.min (never above a real threshold)
    h2 = Histogram()
    h2.add(0.0)
    h2.add(5.0)
    assert h2.count_above(1.0) == 1


def test_histogram_empty_and_single_value():
    h = Histogram()
    assert h.percentile(50) == 0.0 and h.mean == 0.0
    h.add(3.25)
    # single observation: clamped to [min, max] -> exact
    assert h.percentile(50) == pytest.approx(3.25)
    assert h.percentile(99) == pytest.approx(3.25)
    assert h.count == 1 and h.mean == pytest.approx(3.25)


def test_histogram_matches_nearest_rank_within_rel_error():
    rng = np.random.default_rng(0)
    vals = np.concatenate([
        rng.lognormal(-3.0, 1.0, size=400),       # latency-like spread
        rng.uniform(1e-4, 1e-1, size=100),
    ])
    h = Histogram()
    for v in vals:
        h.add(float(v))
    for q in (5, 25, 50, 90, 95, 99, 100):
        exact = percentile(vals, q)
        approx = h.percentile(q)
        assert approx == pytest.approx(exact, rel=h.rel_error), q


def test_histogram_merge_equals_single_feed():
    rng = np.random.default_rng(1)
    a_vals, b_vals = rng.lognormal(0, 1, 200), rng.lognormal(0.5, 0.7, 150)
    one = Histogram()
    for v in np.concatenate([a_vals, b_vals]):
        one.add(float(v))
    a, b = Histogram(), Histogram()
    for v in a_vals:
        a.add(float(v))
    for v in b_vals:
        b.add(float(v))
    a.merge(b)
    assert a.count == one.count and a.total == pytest.approx(one.total)
    assert a.counts == one.counts
    for q in (50, 95, 99):
        assert a.percentile(q) == one.percentile(q)


def test_histogram_merge_rejects_mismatched_bucketing():
    with pytest.raises(ValueError, match="bucketing"):
        Histogram().merge(Histogram(growth=2.0))


def test_histogram_dict_roundtrip():
    h = Histogram()
    for v in (0.0, 1e-12, 0.5, 2.0, 2.0, 1e6):   # incl. underflow bucket
        h.add(v)
    h2 = Histogram.from_dict(json.loads(json.dumps(h.to_dict())))
    assert h2.count == h.count and h2.counts == h.counts
    assert h2.percentile(50) == h.percentile(50)
    assert h2.min == h.min and h2.max == h.max


# ---------------------------------------------------------------------------
# traced serving run: export validity + instrumentation coverage
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def traced_run():
    cfg = configs.get_smoke(ARCH)
    eng = Engine(cfg, slots=2, max_seq=64, block_size=4, max_chunk=8,
                 trace=True, speculative=True)
    eng.warmup()
    rng = np.random.default_rng(0)
    for _ in range(5):
        p = rng.integers(0, cfg.vocab, size=int(rng.integers(4, 12)))
        eng.submit(p, max_new=int(rng.integers(2, 8)))
    eng.run()
    return eng


def test_trace_export_is_valid_chrome_trace(traced_run, tmp_path):
    path = tmp_path / "trace.json"
    write_chrome_trace(str(path), [traced_run.tracer],
                       metadata={"arch": traced_run.cfg.name})
    doc = json.loads(path.read_text())          # valid JSON on disk
    evs = doc["traceEvents"]
    assert doc["metadata"]["arch"] == traced_run.cfg.name
    assert evs, "traced run exported no events"
    # B/E spans nest properly per (pid, tid)
    stacks = {}
    for e in evs:
        key = (e["pid"], e["tid"])
        if e["ph"] == "B":
            stacks.setdefault(key, []).append(e["name"])
        elif e["ph"] == "E":
            assert stacks[key], f"E without B for {e['name']}"
            assert stacks[key].pop() == e["name"]
    assert all(not s for s in stacks.values()), stacks
    # async request spans balance per (name, id) and carry the request cat
    open_spans = {}
    for e in evs:
        if e["ph"] in ("b", "e"):
            assert e["cat"] == "request"
            k = (e["name"], e["id"])
            open_spans[k] = open_spans.get(k, 0) + (1 if e["ph"] == "b" else -1)
            assert open_spans[k] in (0, 1), k
    assert all(v == 0 for v in open_spans.values()), open_spans
    # timestamps are non-negative microseconds from the common origin
    assert min(e["ts"] for e in evs if "ts" in e) >= 0.0


def test_trace_covers_lifecycle_and_phases(traced_run):
    names = {e["name"] for e in chrome_trace_events([traced_run.tracer])}
    # per-tick phase spans
    assert {"tick", "sched", "prefill", "decode", "warmup"} <= names
    # per-request lifecycle async spans
    assert {"queued", "req_prefill", "req_decode"} <= names
    # counters
    assert {"kv_blocks_in_use", "kv_blocks_reserved", "queue_depth"} <= names


def test_trace_document_counts_dropped():
    tr = Tracer(capacity=4)
    c = tr.intern("x")
    for i in range(10):
        tr.counter(c, i)
    doc = trace_document([tr])
    assert doc["metadata"]["dropped_events"] == 6


def test_untraced_engine_records_nothing(traced_run):
    cfg = configs.get_smoke(ARCH)
    eng = Engine(cfg, slots=2, max_seq=32, block_size=4, max_chunk=8)
    eng.share_steps_from(traced_run)
    eng.warmup()
    eng.submit([1, 2, 3, 4], max_new=3)
    eng.run()
    assert eng.tracer is NULL_TRACER
    assert chrome_trace_events([eng.tracer]) == []


def test_flow_events_connect_each_request(traced_run):
    """Tentpole acceptance: every finished request is reconstructable by
    trace id — one connected flow chain (``s`` -> ``t``... -> ``f``) named
    "req" with ``cat="flow"``, ids namespaced ``(pid << 24) + rid``."""
    evs = chrome_trace_events([traced_run.tracer])
    flows = [e for e in evs if e.get("cat") == "flow"]
    assert flows, "flow-traced run exported no flow events"
    assert all(e["name"] == "req" for e in flows)
    want = {(traced_run.tracer.pid << 24) + r.rid
            for r in traced_run.metrics.requests}
    by_id = {}
    for e in flows:
        by_id.setdefault(e["id"], []).append(e)
    assert set(by_id) == want
    for fid, chain in by_id.items():
        phs = [e["ph"] for e in chain]
        assert phs[0] == "s" and phs[-1] == "f", (fid, phs)
        assert set(phs[1:-1]) <= {"t"}, (fid, phs)
        assert chain[-1]["bp"] == "e"           # bind f to preceding slice
        ts = [e["ts"] for e in chain]
        assert ts == sorted(ts)


def test_flow_events_bind_to_open_slices(traced_run):
    """Perfetto draws a flow arrow only when the s/t/f event lands inside a
    duration slice open on that thread at that ts; replay the stream and
    require nonzero B/E depth at every flow event."""
    depth = {}
    for e in chrome_trace_events([traced_run.tracer]):
        key = (e.get("pid"), e.get("tid"))
        if e["ph"] == "B":
            depth[key] = depth.get(key, 0) + 1
        elif e["ph"] == "E":
            depth[key] = depth.get(key, 0) - 1
        elif e["ph"] in ("s", "t", "f"):
            assert depth.get(key, 0) > 0, e


def test_flow_events_gated_by_trace_flow(traced_run):
    cfg = configs.get_smoke(ARCH)
    eng = Engine(cfg, slots=2, max_seq=32, block_size=4, max_chunk=8,
                 trace=True, trace_flow=False)
    eng.share_steps_from(traced_run)
    eng.warmup()
    eng.submit([1, 2, 3, 4], max_new=3)
    eng.run()
    evs = chrome_trace_events([eng.tracer])
    assert evs                                   # still span-traced
    assert not [e for e in evs if e["ph"] in ("s", "t", "f", "i")]


def test_shed_and_prefix_hit_instants():
    """Shed decisions and prefix-cache hits surface as annotated instant
    events ("i", thread-scoped) in the trace."""
    cfg = configs.get_smoke(ARCH)
    eng = Engine(cfg, slots=2, max_seq=32, block_size=4, max_chunk=8,
                 trace=True, prefix_cache=True, max_queue=1)
    eng.warmup()
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    p1 = np.concatenate([prefix, rng.integers(0, cfg.vocab, size=3)]).astype(np.int32)
    p2 = np.concatenate([prefix, rng.integers(0, cfg.vocab, size=4)]).astype(np.int32)
    assert eng.submit(p1, max_new=3) is not None
    # queue cap 1: a second pre-tick submit must shed (-> "shed" instant)
    assert eng.submit(p2, max_new=3) is None
    eng.run()
    # p1's full blocks are cached at finish; resubmitting p2 hits the prefix
    assert eng.submit(p2, max_new=3) is not None
    eng.run()
    inst = [e for e in chrome_trace_events([eng.tracer]) if e["ph"] == "i"]
    names = {e["name"] for e in inst}
    assert {"shed", "prefix_hit"} <= names
    assert all(e["s"] == "t" for e in inst)
    hit = [e for e in inst if e["name"] == "prefix_hit"]
    assert hit[0]["args"]["value"] >= 4          # tokens served from cache


def test_cache_evict_instant_under_pool_pressure():
    cfg = configs.get_smoke(ARCH)
    eng = Engine(cfg, slots=1, max_seq=16, block_size=4, num_blocks=5,
                 max_chunk=4, prefix_cache=True, trace=True)
    eng.warmup()
    rng = np.random.default_rng(1)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab, size=9).astype(np.int32),
                   max_new=3)
        eng.run()
    evs = chrome_trace_events([eng.tracer])
    evict = [e for e in evs if e["ph"] == "i" and e["name"] == "cache_evict"]
    assert evict, "pool pressure produced no cache_evict instant"
    assert evict[0]["args"]["value"] > 0         # blocks short at admission


def test_tracing_overhead_under_two_percent(traced_run):
    """The acceptance bar: per-tick tracing cost < 2% of a decode tick.

    Asserted analytically — measured per-event ring cost x the events a
    decode tick records, against the engine's own measured mean tick — so
    the test is robust to host-load noise that an A/B wall-clock diff
    (benchmarks/obs_bench.py keeps that measurement) would flake on."""
    tr = Tracer(capacity=1 << 14)
    code = tr.intern("bench")
    n = 5000
    best_ns = float("inf")
    for _ in range(3):                     # best-of-3: dodge load spikes
        t0 = time.perf_counter_ns()
        for _ in range(n):
            tr.begin(code)
            tr.end(code)
        best_ns = min(best_ns, (time.perf_counter_ns() - t0) / (2 * n))
    m = traced_run.metrics
    tick_s = m.decode_time_s / max(1, m.decode_steps)
    # a plain decode tick records: tick B/E + sched B/E + decode B/E
    # + 2 KV counters = 8 events; per-request flow steps add one per
    # active slot and spec ticks add draft/verify spans
    events_per_tick = 14
    overhead = events_per_tick * best_ns * 1e-9 / tick_s
    assert overhead < 0.02, (
        f"tracing costs {overhead:.2%} of a {tick_s * 1e6:.0f}us decode tick "
        f"({best_ns:.0f}ns/event)")


# ---------------------------------------------------------------------------
# engine metrics: histogram percentiles, request-log capping
# ---------------------------------------------------------------------------


def test_engine_metrics_percentiles_follow_raw_log_until_dropped():
    from repro.serving.engine import EngineMetrics, RequestMetrics

    m = EngineMetrics()
    for i, t in enumerate([0.010, 0.020, 0.200]):
        m.note_request(RequestMetrics(
            rid=i, prompt_len=4, new_tokens=5, ttft_s=t,
            latency_s=t + 0.1, queue_steps=0))
    # complete log: exact nearest-rank over the raw list
    assert m.ttft_percentile(50) == pytest.approx(0.020)
    assert m.finished_requests == 3 and m.requests_dropped == 0
    # cap the log: the histogram becomes the percentile source of truth
    m2 = EngineMetrics()
    for i, t in enumerate([0.010, 0.020, 0.200]):
        m2.note_request(RequestMetrics(
            rid=i, prompt_len=4, new_tokens=5, ttft_s=t,
            latency_s=t + 0.1, queue_steps=0), 2)
    assert len(m2.requests) == 2 and m2.requests_dropped == 1
    assert m2.finished_requests == 3
    assert m2.ttft_percentile(50) == pytest.approx(
        0.020, rel=m2.ttft_hist.rel_error)
    assert "requests=3" in m2.summary()


def test_engine_as_dict_is_json_serializable(traced_run):
    d = traced_run.metrics.as_dict()
    json.dumps(d)
    assert d["requests"] == traced_run.metrics.finished_requests
    assert d["ttft_hist"]["count"] == d["requests"]
    assert d["mfu"]["phases"]["decode"]["steps"] > 0


# ---------------------------------------------------------------------------
# MFU / utilization gauges
# ---------------------------------------------------------------------------


def test_mfu_meter_accounting_and_merge():
    cfg = configs.get_smoke(ARCH)
    a = MfuMeter(cfg)
    assert a.utilization("decode") == 0.0 and a.mfu("decode") == 0.0
    a.note("decode", tokens=2, rows=4, time_s=1e-3)
    a.note("decode", tokens=2, rows=4, time_s=1e-3)
    a.note("prefill", tokens=8, rows=8, time_s=2e-3)
    assert list(a.active_phases()) == ["prefill", "decode"]
    st = a.phases["decode"]
    assert st.steps == 2 and st.tokens == 4 and st.rows == 8
    assert st.flops == pytest.approx(4 * a.flops_per_token)
    assert 0.0 < a.utilization("decode") <= 1.0 or a.utilization("decode") > 0
    assert a.mfu("decode") == pytest.approx(
        st.flops / (st.time_s * a.peak_flops))
    # bound is memoized and monotone in rows
    assert a.step_bound_s(4) == a.step_bound_s(4)
    assert a.step_bound_s(64) >= a.step_bound_s(4)
    b = MfuMeter(cfg)
    b.note("decode", tokens=1, rows=4, time_s=5e-4)
    merged = MfuMeter.merged([a, b])
    assert merged.phases["decode"].steps == 3
    assert merged.phases["decode"].tokens == 5
    assert merged.phases["prefill"].steps == 1
    assert MfuMeter.merged([]) is None
    frag = a.summary()
    assert "util[decode]=" in frag and "mfu[prefill]=" in frag
    json.dumps(a.as_dict())


def test_engine_mfu_phases_populated(traced_run):
    mfu = traced_run.mfu
    active = set(mfu.active_phases())
    assert {"prefill", "decode"} <= active
    for p in active:
        st = mfu.phases[p]
        assert st.time_s > 0 and st.steps > 0 and st.bound_s > 0
        assert 0 < mfu.utilization(p)       # CPU host: tiny but nonzero
        assert 0 < mfu.mfu(p) < 1
    assert "util[decode]=" in traced_run.metrics.summary()


# ---------------------------------------------------------------------------
# satellite counters: allocator, scheduler, drafter
# ---------------------------------------------------------------------------


def test_allocator_traffic_counters(traced_run):
    alloc = traced_run.alloc
    s = alloc.stats()
    assert s["total_allocated"] == s["total_freed"]   # drained engine
    assert s["in_use"] == 0 and s["reserved"] == 0
    assert 0 < s["peak_in_use"] <= alloc.num_blocks - 1
    assert alloc.reserved == 0


def test_scheduler_and_drafter_counters(traced_run):
    sched = traced_run.scheduler
    assert sched.admitted_total == 5
    assert sched.peak_queue_depth >= 1
    d = traced_run.drafter
    assert d.draft_calls > 0
    assert 0 <= d.draft_hits <= d.draft_calls
    assert 0.0 <= d.hit_rate <= 1.0
    if d.draft_hits:
        assert d.drafted_tokens >= d.draft_hits


# ---------------------------------------------------------------------------
# cluster: per-replica tracers in one export
# ---------------------------------------------------------------------------


def test_replica_pool_trace_multi_pid(tmp_path):
    from repro import cluster

    cfg = configs.get_smoke(ARCH)
    pool = cluster.ReplicaPool(cfg, 2, slots=2, max_seq=32, block_size=4,
                               max_chunk=8, trace=True)
    pool.warmup()
    rng = np.random.default_rng(0)
    for i in range(4):
        h = cluster.ClusterRequest(i, rng.integers(0, cfg.vocab, size=6), 3)
        pool.submit_to(i % 2, h)
    pool.run_sync(max_ticks=500)
    path = tmp_path / "cluster_trace.json"
    doc = pool.export_trace(str(path), metadata={"replicas": 2})
    evs = json.loads(path.read_text())["traceEvents"]
    pids = {e["pid"] for e in evs}
    assert pids == {0, 1}                  # one process lane per replica
    for pid in pids:                       # both replicas actually traced
        assert any(e["ph"] == "B" and e["name"] == "tick" and e["pid"] == pid
                   for e in evs)
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"
             and e["name"] == "process_name"}
    assert names == {f"replica0[{cfg.name}]", f"replica1[{cfg.name}]"}
    assert doc["metadata"]["replicas"] == 2


def test_replica_pool_without_trace_refuses_export(tmp_path):
    from repro import cluster

    cfg = configs.get_smoke(ARCH)
    pool = cluster.ReplicaPool(cfg, 1, slots=2, max_seq=32, block_size=4)
    with pytest.raises(RuntimeError, match="trace=True"):
        pool.export_trace(str(tmp_path / "x.json"))
