"""Paged flash-decode tests: kernel-vs-oracle equivalence (interpret mode),
split-K identity, the bounded fallback, int8 KV residency fidelity, engine
token identity across decode backends (dense/hybrid/recurrent, speculative
verify included), and decode-spec tuning persistence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import flash_decode as fd
from repro.kernels.registry import make_kernel, registered_kernels
from repro.models.attention import decode_attention
from repro.serving import kv_cache as kvc
from repro.serving.engine import Engine

FAMILY_ARCHS = ["gemma3-1b", "jamba-1.5-large-398b", "xlstm-1.3b"]


@pytest.fixture(autouse=True)
def _reset_decode_globals():
    """The backend/spec hooks are process-wide trace-time state; never let
    one test's binding leak into the next."""
    yield
    fd.set_decode_backend(None)
    fd.set_decode_spec(None)


# ---------------------------------------------------------------------------
# kernel-level equivalence (interpret mode on CPU)
# ---------------------------------------------------------------------------

B, BS, MAX_BLOCKS, HKV, GROUPS, D = 3, 4, 6, 2, 2, 16
LENGTHS = np.array([5, 12, MAX_BLOCKS * BS], np.int32)   # ragged, one at cap


def _make_pool(seed=0, kv_precision="float"):
    """A lived-in pool: ragged per-slot lengths, every live position written
    through ``write_kv`` (so int8 pools quantize exactly as serving does)."""
    rng = np.random.default_rng(seed)
    num_blocks = 1 + B * MAX_BLOCKS
    cache = kvc.init_paged_kv(num_blocks, BS, HKV, D, jnp.float32,
                              kv_precision=kv_precision)
    alloc = kvc.BlockAllocator(num_blocks, BS)
    tables = kvc.BlockTables(B, MAX_BLOCKS)
    for s in range(B):
        tables.ensure(s, int(LENGTHS[s]), alloc)
    bt = tables.array()
    L = int(LENGTHS.max())
    k_new = jnp.asarray(rng.normal(size=(B, L, HKV, D)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(B, L, HKV, D)), jnp.float32)
    cache = kvc.write_kv(cache, bt, k_new, v_new, 0)
    return cache, bt


def _query(sq, seed=1):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, sq, HKV * GROUPS, D)), jnp.float32)
    idx = jnp.asarray(LENGTHS - sq, jnp.int32)   # first query position
    return q, idx


def _oracle(q, cache, bt, idx, window=None):
    k, v = kvc.gather_kv(cache, bt)
    return decode_attention(q, k, v, index=idx, window=window)


@pytest.mark.parametrize("sq,window,splits", [
    (1, None, 1),     # plain decode
    (1, None, 4),     # split-K (uneven: 6 cols over 4 splits, padded tail)
    (3, None, 2),     # Sq > 1 (speculative verify width), split
    (1, 6, 1),        # sliding window
    (3, 6, 4),        # everything at once
])
def test_flash_kernel_matches_oracle(sq, window, splits):
    """The Pallas kernel (interpret mode) reproduces gather_kv +
    decode_attention across ragged lengths, GQA packing, windows, Sq > 1,
    and split-K — the exact combinations the serving step dispatches."""
    cache, bt = _make_pool()
    q, idx = _query(sq)
    want = _oracle(q, cache, bt, idx, window=window)
    got = fd.flash_decode_attention(
        q, cache, bt, idx, window=window,
        spec=fd.FlashDecodeSpec(num_splits=splits), interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("cols", [1, 3, 8])
def test_blocked_fallback_matches_oracle(cols):
    """The bounded while_loop fallback matches the oracle at every chunk
    width, including a chunk larger than the table (clamped)."""
    cache, bt = _make_pool()
    for sq, window in [(1, None), (3, None), (1, 6)]:
        q, idx = _query(sq)
        want = _oracle(q, cache, bt, idx, window=window)
        got = fd.ref_paged_decode(q, cache, bt, idx, window=window,
                                  cols_per_iter=cols)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_split_k_identity():
    """Split-K is a pure reassociation: any split factor produces the same
    output as the unsplit walk (combine stage included)."""
    cache, bt = _make_pool()
    q, idx = _query(1)
    base = fd.flash_decode_attention(
        q, cache, bt, idx, spec=fd.FlashDecodeSpec(num_splits=1),
        interpret=True)
    for splits in (2, 3, 6, 17):   # 17 > max_blocks: clamps to 6
        split = fd.flash_decode_attention(
            q, cache, bt, idx, spec=fd.FlashDecodeSpec(num_splits=splits),
            interpret=True)
        np.testing.assert_allclose(np.asarray(split), np.asarray(base),
                                   rtol=1e-6, atol=1e-6)


def test_int8_pool_kernel_and_fallback():
    """int8 residency: the in-kernel dequant reproduces the gather path's
    dequantized view tightly, stays within the w8a8 fidelity bar of the
    float pool, and actually shrinks the pool bytes."""
    cache_f, bt = _make_pool(kv_precision="float")
    cache_q, _ = _make_pool(kv_precision="int8")
    assert cache_q.quantized and not cache_f.quantized
    assert kvc.pool_bytes(cache_q) < kvc.pool_bytes(cache_f)
    for sq in (1, 3):
        q, idx = _query(sq)
        # vs the int8 gather oracle (same dequantized values): tight
        want_q = _oracle(q, cache_q, bt, idx)
        for got in (
            fd.flash_decode_attention(q, cache_q, bt, idx, interpret=True),
            fd.ref_paged_decode(q, cache_q, bt, idx, cols_per_iter=2),
        ):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want_q),
                                       rtol=1e-5, atol=1e-5)
        # vs the float pool: the quantization error bar (test_quant's bar)
        want_f = np.asarray(_oracle(q, cache_f, bt, idx))
        got = np.asarray(
            fd.flash_decode_attention(q, cache_q, bt, idx, interpret=True))
        rel = np.linalg.norm(got - want_f) / max(np.linalg.norm(want_f), 1e-9)
        assert rel < 0.15, rel


def test_registry_and_dispatcher():
    """"flash_decode" resolves through the kernel registry, and the
    dispatcher's backends all agree (interpret vs blocked vs gather)."""
    assert "flash_decode" in registered_kernels()
    cache, bt = _make_pool()
    q, idx = _query(1)
    fn = make_kernel("flash_decode", fd.FlashDecodeSpec(num_splits=2),
                     interpret=True)
    want = np.asarray(_oracle(q, cache, bt, idx))
    np.testing.assert_allclose(np.asarray(fn(q, cache, bt, idx)), want,
                               rtol=1e-5, atol=1e-5)
    for backend in ("gather", "blocked", "interpret"):
        got = fd.paged_decode_attention(q, cache, bt, idx, backend=backend)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        fd.set_decode_backend("nope")


# ---------------------------------------------------------------------------
# engine-level: token identity across decode backends
# ---------------------------------------------------------------------------

def _serve(cfg, backend, *, kv_precision="float", speculative=False):
    """Warm + serve a small deterministic workload with the decode backend
    bound at trace time (exactly how the engine binds it in production)."""
    eng = Engine(cfg, slots=2, max_seq=64, block_size=8, max_chunk=16,
                 kv_precision=kv_precision, speculative=speculative, seed=0)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (7, 19, 12)]
    with fd.decode_backend(backend):
        eng.warmup()
        for p in prompts:
            eng.submit(p, max_new=6)
        results = eng.run()
    return {rid: out.tolist() for rid, out in results.items()}, eng


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_engine_backend_token_identity(arch):
    """The bounded fallback serves token-identical streams to the legacy
    gather path across the dense, hybrid, and recurrent families — refills,
    chunked prefill, and ragged lengths included."""
    cfg = configs.get_smoke(arch)
    gather, _ = _serve(cfg, "gather")
    blocked, _ = _serve(cfg, "blocked")
    assert gather == blocked


def test_engine_speculative_token_identity():
    """Batched verification (Sq > 1 through the paged kernel path) stays
    token-identical to the gather baseline."""
    cfg = configs.get_smoke("gemma3-1b")
    gather, eg = _serve(cfg, "gather", speculative=2)
    blocked, eb = _serve(cfg, "blocked", speculative=2)
    assert gather == blocked
    # Same schedule => same speculative behavior, not just same tokens.
    assert eg.metrics.spec_accepted_tokens == eb.metrics.spec_accepted_tokens


def test_engine_int8_kv_serves_and_accounts():
    """An int8-KV engine serves every request to completion, and the metrics
    report the (smaller) pool honestly."""
    cfg = configs.get_smoke("gemma3-1b")
    toks_f, ef = _serve(cfg, "blocked", kv_precision="float")
    toks_q, eq = _serve(cfg, "blocked", kv_precision="int8")
    assert set(toks_q) == set(toks_f)
    assert all(len(v) == 6 for v in toks_q.values())
    assert eq.metrics.kv_precision == "int8"
    assert 0 < eq.metrics.kv_pool_bytes < ef.metrics.kv_pool_bytes
    assert eq.metrics.kv_slot_capacity == ef.metrics.kv_slot_capacity == 2
    s = eq.metrics.summary()
    assert "kv_pool=" in s and "int8" in s and "slots@max_seq=2" in s


# ---------------------------------------------------------------------------
# tuning: decode winners persist next to GeMM tiles
# ---------------------------------------------------------------------------

def test_decode_tuning_cache_roundtrip(tmp_path):
    """tune_decode caches its winner under a "kind"-discriminated entry that
    survives a disk round trip, and a second query is a cache hit."""
    from repro import tuning

    path = str(tmp_path / "tunecache.json")
    shape = tuning.DecodeShape(slots=2, kv_heads=2, groups=2, head_dim=16,
                               sq=1, block_size=4, max_blocks=8)
    t1 = tuning.Autotuner(cache=tuning.TuneCache(path))
    r1 = tuning.tune_decode(shape, "float32", tuner=t1)
    assert not r1.from_cache and r1.candidates > 1
    assert tuning.tune_decode(shape, "float32", tuner=t1).from_cache
    # fresh process: the winner comes back from disk with the same spec
    t2 = tuning.Autotuner(cache=tuning.TuneCache(path))
    r2 = tuning.tune_decode(shape, "float32", tuner=t2)
    assert r2.from_cache and r2.spec == r1.spec
    raw = t2.cache.dump()
    key = tuning.decode_cache_key(shape, "float32")
    assert raw[key]["kind"] == "flash_decode"
    # GeMM entries (no "kind") still decode alongside
    entry = tuning.CacheEntry.from_json(
        {"tm": 8, "tk": 128, "tn": 128, "score": 1.0, "source": "analytic"})
    assert entry.spec.tm == 8


def test_engine_warmup_binds_tuned_spec(tmp_path, monkeypatch):
    """Engine(autotune=True) tunes the decode shape during warmup and binds
    the winner through set_decode_spec before tracing (attention archs
    only — a pure-recurrent stack binds nothing)."""
    from repro import tuning

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tc.json"))
    assert fd.get_decode_spec() is None
    cfg = configs.get_smoke("gemma3-1b")
    eng = Engine(cfg, slots=2, max_seq=32, block_size=8, max_chunk=8,
                 autotune=True, seed=0)
    eng.warmup()
    spec = fd.get_decode_spec()
    assert isinstance(spec, fd.FlashDecodeSpec)
    key = tuning.decode_cache_key(
        tuning.serving_decode_shape(cfg, slots=2, block_size=8,
                                    max_blocks=eng.max_blocks_per_slot),
        cfg.dtype)
    assert tuning.get_tuner().cache.get(key).spec == spec
    tuning.disable()
    tuning.set_tuner(None)
